"""LM serving through the unified program path: compiled prefill programs
from the keyed ProgramCache, per-level engine occupancy, cache hit-rate.

Evidence lines for the model-agnostic IR (serve/engine.py + compiler):

  * the transformer prefill of each arch compiles once to an engine
    program; repeated serves (and a second engine sharing the cache) hit
    the ProgramCache instead of re-lowering / re-calibrating / re-tracing;
  * the program's level schedule exposes cross-engine concurrency (QKV
    GEMMs co-leveled on the Conv PE next to MISC norms); per-level engine
    occupancy is reported for both ASAP and ALAP leveling.

    PYTHONPATH=src python -m benchmarks.serve_lm [--summary]

--summary prints the one-line LM program-cache + occupancy summary
(scripts/check.sh appends it to the gate output).
"""
import time

import numpy as np

ARCH_NAMES = ("qwen2-1.5b", "gemma2-2b")
PROMPTS = 6
PROMPT_LEN = 8
NEW_TOKENS = 2


def _fleet(seed=0):
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import transformer as T
    from repro.models.params import init_params

    rng = np.random.default_rng(seed)
    fleet = []
    for i, name in enumerate(ARCH_NAMES):
        arch = configs.reduced(configs.get_arch(name))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(i))
        calib = [jnp.array(rng.integers(0, arch.vocab_size, (2, PROMPT_LEN))
                           .astype(np.int32))]
        prompts = [rng.integers(0, arch.vocab_size, size=PROMPT_LEN)
                   for _ in range(PROMPTS)]
        fleet.append((arch, params, calib, prompts))
    return fleet


def serve_stats():
    """Serve each arch twice through one shared ProgramCache; return the
    cache counters plus per-arch prefill schedule occupancy (asap + alap)."""
    from repro import compiler
    from repro.core.config import EngineConfig
    from repro.serve.engine import ServeEngine
    from repro.serve.program_cache import ProgramCache

    eng = EngineConfig(quant="w8a8", backend="ref")
    cache = ProgramCache(capacity=len(ARCH_NAMES) + 1)
    rows = {}
    t0 = time.perf_counter()
    for arch, params, calib, prompts in _fleet():
        engine = ServeEngine(arch, params, eng, batch_size=2, max_seq=32,
                             calib_batches=calib, cache=cache)
        engine.generate(prompts, max_new_tokens=NEW_TOKENS)   # compile+serve
        engine.generate(prompts, max_new_tokens=NEW_TOKENS)   # re-serve: hits
        program = engine.prefill_program()
        occ = compiler.engine_occupancy(program.graph, program.schedule)
        alap = compiler.level_schedule(program.graph, "alap")
        occ_alap = compiler.engine_occupancy(program.graph, alap)
        rows[arch.name] = {
            "levels": program.schedule.n_levels,
            "occupancy": occ["occupancy"],
            "occupancy_alap": occ_alap["occupancy"],
            "static": program.static,
            "f32_roundtrips": program.f32_roundtrips(),
        }
    c = cache.stats
    return {
        "archs": rows,
        "wall_s": time.perf_counter() - t0,
        "cache_hits": c.hits,
        "cache_misses": c.misses,
        "cache_hit_rate": c.hit_rate,
        "requests": c.requests,
    }


def run(measure: bool = True):
    if not measure:
        return []
    stats = serve_stats()
    out = []
    for name, r in stats["archs"].items():
        out.append((
            f"serve_lm/prefill/{name}", 0.0,
            f"levels={r['levels']},occupancy={r['occupancy']:.2f},"
            f"occupancy_alap={r['occupancy_alap']:.2f},"
            f"static={int(r['static'])},roundtrips={r['f32_roundtrips']}"))
    out.append((
        "serve_lm/trace/cached", stats["wall_s"] * 1e6,
        f"hit_rate={stats['cache_hit_rate']:.3f},"
        f"hits={stats['cache_hits']},compiles={stats['cache_misses']},"
        f"requests={stats['requests']}"))
    return out


def summary_line() -> str:
    stats = serve_stats()
    occ = np.mean([r["occupancy"] for r in stats["archs"].values()])
    occ_alap = np.mean([r["occupancy_alap"] for r in stats["archs"].values()])
    return (f"lm program-cache hit-rate: {100 * stats['cache_hit_rate']:.1f}% "
            f"({stats['cache_hits']}/{stats['requests']} hits, "
            f"{stats['cache_misses']} compiles, {len(stats['archs'])} archs); "
            f"prefill engine occupancy {100 * occ:.1f}% asap / "
            f"{100 * occ_alap:.1f}% alap")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", action="store_true",
                    help="one-line LM program-cache + occupancy summary only")
    args = ap.parse_args()
    if args.summary:
        print(summary_line())
    else:
        print("name,us_per_call,derived")
        for row_name, us, derived in run():
            print(f"{row_name},{us:.1f},{derived}")
