"""LM serving through the unified program path: compiled prefill + decode
programs from the keyed ProgramCache, continuous-batching slot refill,
per-level and time-weighted engine occupancy, cache hit-rate.

Evidence lines for the decode-as-program serve path (serve/engine.py +
compiler):

  * each arch compiles TWO programs from one calibration run -- prefill and
    the DecodeStep program -- and repeated serves (and a second engine
    sharing the cache) hit the ProgramCache instead of re-lowering /
    re-calibrating / re-tracing;
  * the decode burst executes the compiled DecodeStep program: measured
    compiled-decode vs eager-decode tokens/s, plus the continuous-batching
    slot-refill rate and slot occupancy of a queue longer than the batch;
  * the programs' level schedules expose cross-engine concurrency; both
    per-level occupancy and the TIME-WEIGHTED per-engine busy fractions
    (perf_model.lm_busy_fractions over compiler.time_weighted_occupancy)
    are reported for prefill and decode.

    PYTHONPATH=src python -m benchmarks.serve_lm [--summary|--decode-summary]

--summary prints the one-line LM program-cache + occupancy summary;
--decode-summary prints the compiled-vs-eager decode throughput one-liner
plus the w4a8-vs-w8a8 tokens/s and weight-bytes/token comparison, and
merges the numbers into BENCH_serve.json's "lm_decode" block
(scripts/check.sh appends both lines to the gate output).  --fast runs the
paged+speculative smoke (random AND repetitive-token acceptance legs) plus
the prefix-sharing shared-prompt trace (fresh blocks/request and prefill
tokens/request vs a no-sharing baseline, asserted at <=0.6x / <=0.5x),
merged under "lm_decode" / "lm_decode"."prefix_sharing".
"""
import time

import numpy as np

ARCH_NAMES = ("qwen2-1.5b", "gemma2-2b")
PROMPTS = 6
PROMPT_LEN = 8
NEW_TOKENS = 2
DECODE_STEPS = 8
MAX_SEQ = 32


def _fleet(seed=0):
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import transformer as T
    from repro.models.params import init_params

    rng = np.random.default_rng(seed)
    fleet = []
    for i, name in enumerate(ARCH_NAMES):
        arch = configs.reduced(configs.get_arch(name))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(i))
        calib = [jnp.array(rng.integers(0, arch.vocab_size, (2, PROMPT_LEN))
                           .astype(np.int32))]
        prompts = [rng.integers(0, arch.vocab_size, size=PROMPT_LEN)
                   for _ in range(PROMPTS)]
        fleet.append((arch, params, calib, prompts))
    return fleet


def serve_stats():
    """Serve each arch twice through one shared ProgramCache; return the
    cache counters plus per-arch prefill/decode schedule occupancy (both
    per-level and time-weighted)."""
    from benchmarks import perf_model as pm
    from repro import compiler
    from repro.core.config import EngineConfig
    from repro.serve.engine import ServeEngine
    from repro.serve.program_cache import ProgramCache

    eng = EngineConfig(quant="w8a8", backend="ref")
    cache = ProgramCache(capacity=2 * len(ARCH_NAMES) + 1)
    rows = {}
    t0 = time.perf_counter()
    for arch, params, calib, prompts in _fleet():
        engine = ServeEngine(arch, params, eng, batch_size=2,
                             max_seq=MAX_SEQ, calib_batches=calib,
                             cache=cache)
        engine.generate(prompts, max_new_tokens=NEW_TOKENS)   # compile+serve
        engine.generate(prompts, max_new_tokens=NEW_TOKENS)   # re-serve: hits
        program = engine.prefill_program()
        decode = engine.decode_program()
        occ = compiler.engine_occupancy(program.graph, program.schedule)
        alap = compiler.level_schedule(program.graph, "alap")
        occ_alap = compiler.engine_occupancy(program.graph, alap)
        slack = compiler.level_schedule(program.graph, "slack")
        occ_slack = compiler.engine_occupancy(program.graph, slack)
        tw_prefill = pm.lm_busy_fractions(arch, batch=2, seq=PROMPT_LEN)
        # price decode attention by the ACTUAL mean cached length over the
        # serve (prompt + half the emitted tokens), not the max_seq
        # envelope -- the envelope overstated MISC attention time 2-4x here
        tw_decode = pm.lm_busy_fractions(arch, batch=2, mode="decode",
                                         cache_len=PROMPT_LEN
                                         + NEW_TOKENS // 2)
        st = engine.stats()
        rows[arch.name] = {
            "levels": program.schedule.n_levels,
            "decode_levels": decode.schedule.n_levels,
            "occupancy": occ["occupancy"],
            "occupancy_alap": occ_alap["occupancy"],
            "occupancy_slack": occ_slack["occupancy"],
            "tw_occupancy_prefill": tw_prefill["occupancy"],
            "tw_occupancy_decode": tw_decode["occupancy"],
            "tw_conv_pe_decode": tw_decode.get("conv_pe", 0.0),
            "tw_misc_decode": tw_decode.get("misc", 0.0),
            "static": program.static,
            "decode_static": decode.static,
            "f32_roundtrips": program.f32_roundtrips(),
            "decode_f32_roundtrips": decode.f32_roundtrips(),
            "slot_refill_rate": st["slot_refill_rate"],
            "slot_occupancy": st["slot_occupancy"],
        }
    c = cache.stats
    return {
        "archs": rows,
        "wall_s": time.perf_counter() - t0,
        "cache_hits": c.hits,
        "cache_misses": c.misses,
        "cache_hit_rate": c.hit_rate,
        "requests": c.requests,
    }


def decode_stats(steps: int = DECODE_STEPS, seed: int = 0):
    """Compiled-decode vs eager-decode tokens/s on one arch, plus the
    continuous-batching slot-refill numbers (queue deeper than the batch,
    so finished slots refill between bursts)."""
    from repro.core.config import EngineConfig
    from repro.serve.engine import ServeEngine

    eng = EngineConfig(quant="w8a8", backend="ref")
    (arch, params, calib, prompts) = _fleet(seed)[0]

    def measure(compile_decode: bool):
        engine = ServeEngine(arch, params, eng, batch_size=2,
                             max_seq=MAX_SEQ, calib_batches=calib,
                             compile_decode=compile_decode,
                             prefill_len=PROMPT_LEN)
        engine.generate(prompts[:2], max_new_tokens=1)   # trace warmup
        # report the measured run only: drop the warmup's counters and
        # its (compile-heavy) latency samples
        engine.serve_stats = engine.serve_stats.__class__(
            batch=engine.serve_stats.batch)
        engine.latency = engine.latency.__class__()
        t0 = time.perf_counter()
        engine.generate(prompts, max_new_tokens=steps)
        dt = time.perf_counter() - t0
        return len(prompts) * steps / dt, engine.stats()

    tps_compiled, st = measure(True)
    tps_eager, _ = measure(False)
    return {
        "arch": arch.name,
        "tokens_per_s_compiled": tps_compiled,
        "tokens_per_s_eager": tps_eager,
        "speedup": tps_compiled / tps_eager if tps_eager else 0.0,
        "slot_refills": st["slot_refills"],
        "slot_refill_rate": st["slot_refill_rate"],
        "slot_occupancy": st["slot_occupancy"],
        "decode_steps": st["decode_steps"],
        "latency_ms": st["latency_ms"],
    }


def _proj_weight_bytes(params) -> int:
    """Decode-GEMM weight bytes read per decode step: the container bytes
    (core.quant.container_nbytes) of every projection weight the DecodeStep
    program's GEMMs consume -- the W4_KEYS set, whatever their packing
    (f32 / QTensor int8 / Q4Tensor int4)."""
    from repro.core.engine import W4_KEYS
    from repro.core.quant import container_nbytes

    total = 0

    def rec(node, name=None):
        nonlocal total
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, k)
        elif isinstance(node, (list, tuple)) and not hasattr(node, "_fields"):
            for v in node:                # NamedTuples are weight leaves
                rec(v, name)
        elif name in W4_KEYS:
            total += container_nbytes(node)

    rec(params)
    return total


def decode_quant_stats(steps: int = DECODE_STEPS, seed: int = 0):
    """w4a8 vs w8a8 compiled decode on one arch: measured tokens/s and the
    per-token projection-weight read (int4 packing must cut it to <= 0.55x
    of int8 -- packed nibbles plus f16 group scales/zeros)."""
    from repro.core.config import EngineConfig
    from repro.serve.engine import ServeEngine

    (arch, params, calib, prompts) = _fleet(seed)[0]

    def measure(quant: str):
        eng = EngineConfig(quant=quant, backend="ref")
        engine = ServeEngine(arch, params, eng, batch_size=2,
                             max_seq=MAX_SEQ, calib_batches=calib,
                             prefill_len=PROMPT_LEN)
        engine.generate(prompts[:2], max_new_tokens=1)   # trace warmup
        t0 = time.perf_counter()
        engine.generate(prompts, max_new_tokens=steps)
        dt = time.perf_counter() - t0
        return (len(prompts) * steps / dt,
                _proj_weight_bytes(engine.params))

    tps_w8, bytes_w8 = measure("w8a8")
    tps_w4, bytes_w4 = measure("w4a8")
    return {
        "arch": arch.name,
        "tokens_per_s_w8": tps_w8,
        "tokens_per_s_w4": tps_w4,
        "w4_speedup": tps_w4 / tps_w8 if tps_w8 else 0.0,
        "weight_bytes_per_token_w8": bytes_w8,
        "weight_bytes_per_token_w4": bytes_w4,
        "weight_bytes_ratio": bytes_w4 / bytes_w8 if bytes_w8 else 0.0,
    }


PAGE_SIZE = 8
DRAFT_LEN = 3


def paged_spec_stats(steps: int = DECODE_STEPS, seed: int = 0):
    """Paged-KV + speculative decode vs the dense one-token baseline on one
    arch: measured tokens/s for {dense, paged, paged+spec}, the accepted-
    draft rate and tokens/burst, measured KV bytes/slot, per-request
    latency p50/p99, and the sustainable-slot comparison at fixed memory.
    Token ids of every variant are asserted identical to the dense run --
    the bit-identity contract, enforced on the measured path itself."""
    from repro.core.config import EngineConfig
    from repro.serve.engine import ServeEngine

    eng = EngineConfig(quant="w8a8", backend="ref")
    (arch, params, calib, prompts) = _fleet(seed)[0]

    def measure(prompts=prompts, steps=steps, **kw):
        engine = ServeEngine(arch, params, eng, batch_size=2,
                             max_seq=MAX_SEQ, calib_batches=calib,
                             prefill_len=PROMPT_LEN, **kw)
        engine.generate(prompts[:2], max_new_tokens=1)   # trace warmup
        # report the measured run only: drop the warmup's counters and
        # its (compile-heavy) latency samples
        engine.serve_stats = engine.serve_stats.__class__(
            batch=engine.serve_stats.batch)
        engine.latency = engine.latency.__class__()
        t0 = time.perf_counter()
        out = engine.generate(prompts, max_new_tokens=steps)
        dt = time.perf_counter() - t0
        return len(prompts) * steps / dt, out, engine.stats()

    tps_dense, ids_dense, st_dense = measure()
    tps_paged, ids_paged, st_paged = measure(kv_layout="paged",
                                             page_size=PAGE_SIZE)
    tps_spec, ids_spec, st_spec = measure(kv_layout="paged",
                                          page_size=PAGE_SIZE,
                                          draft_len=DRAFT_LEN)
    for nm, ids in (("paged", ids_paged), ("paged+spec", ids_spec)):
        for a, b in zip(ids_dense, ids):
            assert np.array_equal(a, b), f"{nm} ids diverged from dense"
    # Repetitive-trace leg: the random-prompt acceptance (~3%) measures the
    # TRACE, not the machinery -- random ids give the n-gram drafter no
    # structure to copy.  Constant-token prompts drive greedy decode into
    # repetition the drafter predicts, so this leg shows the acceptance the
    # verify path delivers when the workload cooperates.  Ids still checked
    # against the dense run on the same trace.
    # long enough for greedy decode to settle into the cycle the n-gram
    # drafter locks onto (acceptance roughly triples from 8 to 16 steps)
    rep_steps = max(2 * steps, 2 * DECODE_STEPS)
    rep_prompts = [np.full(PROMPT_LEN, 7, np.int32) for _ in range(PROMPTS)]
    tps_rep_dense, ids_rep_dense, _ = measure(prompts=rep_prompts,
                                              steps=rep_steps)
    tps_rep, ids_rep, st_rep = measure(prompts=rep_prompts, steps=rep_steps,
                                       kv_layout="paged",
                                       page_size=PAGE_SIZE,
                                       draft_len=DRAFT_LEN)
    for a, b in zip(ids_rep_dense, ids_rep):
        assert np.array_equal(a, b), "repetitive spec ids diverged from dense"
    # sustainable slots at the DENSE memory budget: dense reserves the
    # max_seq envelope per slot; paged holds measured blocks per request
    block_bytes = st_spec["kv_block_bytes"]
    budget = st_dense["kv_bytes"]
    per_req_blocks = max(1, round(st_spec["kv_bytes_per_slot"] / block_bytes))
    slots_dense = int(budget // st_dense["kv_bytes_per_slot"])
    slots_paged = int(budget // (per_req_blocks * block_bytes))
    return {
        "arch": arch.name,
        "page_size": PAGE_SIZE,
        "draft_len": DRAFT_LEN,
        "tokens_per_s_dense": tps_dense,
        "tokens_per_s_paged": tps_paged,
        "tokens_per_s_spec": tps_spec,
        "spec_speedup": tps_spec / tps_dense if tps_dense else 0.0,
        # acceptance-corrected decomposition: speculation itself can only
        # buy tokens_per_burst x (the verified tokens a burst emits vs the
        # dense loop's 1); anything beyond that is the device-side burst
        # loop amortizing host dispatch, NOT draft acceptance.  At ~3%
        # accept the raw ~1.7x headline is almost entirely the loop's.
        "spec_speedup_from_acceptance": st_spec["tokens_per_burst"],
        "spec_speedup_from_loop": (
            (tps_spec / tps_dense) / st_spec["tokens_per_burst"]
            if tps_dense and st_spec["tokens_per_burst"] else 0.0),
        "accepted_draft_rate": st_spec["accepted_draft_rate"],
        "tokens_per_burst": st_spec["tokens_per_burst"],
        "spec_steps": st_spec["spec_steps"],
        "accepted_draft_rate_repetitive": st_rep["accepted_draft_rate"],
        "tokens_per_burst_repetitive": st_rep["tokens_per_burst"],
        "tokens_per_s_spec_repetitive": tps_rep,
        "tokens_per_s_dense_repetitive": tps_rep_dense,
        "repetitive_steps": rep_steps,
        "kv_bytes_per_slot_dense": st_dense["kv_bytes_per_slot"],
        "kv_bytes_per_slot_paged": st_spec["kv_bytes_per_slot"],
        "kv_block_utilization": st_spec["kv_blocks"]["peak_in_use"]
        / st_spec["kv_blocks"]["num_blocks"],
        "sustainable_slots_dense": slots_dense,
        "sustainable_slots_paged": slots_paged,
        "latency_ms_dense": st_dense["latency_ms"],
        "latency_ms_spec": st_spec["latency_ms"],
    }


SHARED_PREFIX_LEN = 16      # two full pages of system prompt
DISTINCT_LEN = 8            # per-request unique tail
SHARED_REQUESTS = 8         # concurrent requests sharing the prefix
SHARED_PREFILL = SHARED_PREFIX_LEN + DISTINCT_LEN


def prefix_sharing_stats(steps: int = DECODE_STEPS, seed: int = 0):
    """Prefix-sharing vs no-sharing on a shared-system-prompt trace: one
    warm request primes the index, then SHARED_REQUESTS concurrent
    requests all carry the same page-aligned 16-token prefix plus a
    distinct 8-token tail.  Measured on the concurrent wave only (stats
    reset after the warm request): fresh KV blocks/request, prefill
    tokens computed/request, tokens/s -- each with its no-sharing
    baseline and ratio.  Token ids are asserted identical to the
    baseline engine (bf16 cache: the chunk program's roundtrip is exact),
    and the paper-style wins are asserted right here so the bench IS the
    acceptance gate: blocks/request <= 0.6x and prefill-tokens/request
    <= 0.5x of no-sharing."""
    from repro.core.config import EngineConfig
    from repro.serve.engine import ServeEngine

    eng = EngineConfig(quant="none", backend="ref")
    (arch, params, _, _) = _fleet(seed)[0]
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, arch.vocab_size, size=SHARED_PREFIX_LEN)
    warm_prompt = np.concatenate(
        [prefix, rng.integers(0, arch.vocab_size, size=DISTINCT_LEN)])
    prompts = [np.concatenate(
        [prefix, rng.integers(0, arch.vocab_size, size=DISTINCT_LEN)])
        for _ in range(SHARED_REQUESTS)]

    def measure(share: bool):
        engine = ServeEngine(arch, params, eng,
                             batch_size=SHARED_REQUESTS, max_seq=MAX_SEQ,
                             kv_layout="paged", page_size=PAGE_SIZE,
                             prefill_len=SHARED_PREFILL,
                             kv_blocks=8 * SHARED_REQUESTS,
                             prefix_sharing=share)
        # two warm requests: the first primes the index (and the cold
        # whole-prompt trace), the second HITS it, tracing the tail-only
        # chunk width the measured wave reuses -- otherwise that compile
        # lands inside the clock
        engine.generate([warm_prompt], max_new_tokens=steps)
        engine.generate([warm_prompt], max_new_tokens=steps)
        engine.serve_stats = engine.serve_stats.__class__(
            batch=engine.serve_stats.batch)
        engine.latency = engine.latency.__class__()
        served0 = engine.alloc.stats.blocks_served
        t0 = time.perf_counter()
        out = engine.generate(prompts, max_new_tokens=steps)
        dt = time.perf_counter() - t0
        st = engine.stats()
        n = len(prompts)
        return {
            "tokens_per_s": n * steps / dt,
            "blocks_per_request":
                (engine.alloc.stats.blocks_served - served0) / n,
            "prefill_tokens_per_request":
                st["prefill_tokens_computed"] / n,
            "stats": st,
            "ids": out,
        }

    base = measure(False)
    shared = measure(True)
    for a, b in zip(base["ids"], shared["ids"]):
        assert np.array_equal(a, b), "shared-prefix ids diverged from baseline"
    blocks_ratio = (shared["blocks_per_request"] / base["blocks_per_request"]
                    if base["blocks_per_request"] else 0.0)
    tokens_ratio = (shared["prefill_tokens_per_request"]
                    / base["prefill_tokens_per_request"]
                    if base["prefill_tokens_per_request"] else 0.0)
    assert blocks_ratio <= 0.6, (
        f"prefix sharing saved too few blocks: {blocks_ratio:.2f}x > 0.6x")
    assert tokens_ratio <= 0.5, (
        f"prefix sharing recomputed too much prefill: "
        f"{tokens_ratio:.2f}x > 0.5x")
    ps = shared["stats"]["prefix_sharing"]
    return {
        "arch": arch.name,
        "page_size": PAGE_SIZE,
        "prefill_len": SHARED_PREFILL,
        "shared_prefix_len": SHARED_PREFIX_LEN,
        "requests": SHARED_REQUESTS,
        "tokens_per_s": shared["tokens_per_s"],
        "tokens_per_s_baseline": base["tokens_per_s"],
        "blocks_per_request": shared["blocks_per_request"],
        "blocks_per_request_baseline": base["blocks_per_request"],
        "blocks_ratio": blocks_ratio,
        "prefill_tokens_per_request": shared["prefill_tokens_per_request"],
        "prefill_tokens_per_request_baseline":
            base["prefill_tokens_per_request"],
        "prefill_tokens_ratio": tokens_ratio,
        "prefix_hits": ps["hits"],
        "prefix_shared_blocks": ps["shared_blocks"],
    }


def prefix_sharing_summary_line(steps: int = DECODE_STEPS) -> str:
    """The prefix-sharing one-liner; merges the shared-prompt trace's
    blocks/request, prefill-tokens/request, and tokens/s (plus baselines
    and ratios) under BENCH_serve.json["lm_decode"]["prefix_sharing"]."""
    p = prefix_sharing_stats(steps=steps)
    _merge_lm_decode({"prefix_sharing": {
        k: p[k] for k in (
            "arch", "page_size", "prefill_len", "shared_prefix_len",
            "requests", "tokens_per_s", "tokens_per_s_baseline",
            "blocks_per_request", "blocks_per_request_baseline",
            "blocks_ratio", "prefill_tokens_per_request",
            "prefill_tokens_per_request_baseline", "prefill_tokens_ratio",
            "prefix_hits", "prefix_shared_blocks")}})
    return (f"lm prefix-share ({p['arch']}, page={p['page_size']}, "
            f"{p['requests']} reqs x {p['shared_prefix_len']}-tok shared "
            f"prefix): {p['blocks_per_request']:.2f} blocks/req vs "
            f"{p['blocks_per_request_baseline']:.2f} "
            f"({p['blocks_ratio']:.2f}x), prefill "
            f"{p['prefill_tokens_per_request']:.1f} tok/req vs "
            f"{p['prefill_tokens_per_request_baseline']:.1f} "
            f"({p['prefill_tokens_ratio']:.2f}x), "
            f"{p['tokens_per_s']:.1f} tok/s vs "
            f"{p['tokens_per_s_baseline']:.1f} baseline")


def _merge_lm_decode(fields: dict) -> None:
    """Read-merge-write BENCH_serve.json's "lm_decode" sub-dict: the block
    is shared by the w4/w8 leg and the paged/spec leg, and write_bench_json
    merges TOP-LEVEL keys only -- a naive write would drop the other leg's
    fields."""
    import json
    import os

    from benchmarks.serve_cnn import BENCH_PATH, write_bench_json

    block = {}
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                block = json.load(f).get("lm_decode", {}) or {}
        except (json.JSONDecodeError, OSError):
            block = {}
    block.update(fields)
    write_bench_json({"lm_decode": block})


def paged_summary_line(steps: int = DECODE_STEPS) -> str:
    """The paged+speculative one-liner; merges measured tokens/s, accepted-
    draft rate, tokens/burst, KV bytes/slot, sustainable slots, and p50/p99
    latency into BENCH_serve.json["lm_decode"]."""
    p = paged_spec_stats(steps=steps)
    _merge_lm_decode({
        "page_size": p["page_size"],
        "draft_len": p["draft_len"],
        "tokens_per_s_dense": p["tokens_per_s_dense"],
        "tokens_per_s_paged": p["tokens_per_s_paged"],
        "tokens_per_s_spec": p["tokens_per_s_spec"],
        "spec_speedup": p["spec_speedup"],
        "spec_speedup_from_acceptance": p["spec_speedup_from_acceptance"],
        "spec_speedup_from_loop": p["spec_speedup_from_loop"],
        "accepted_draft_rate": p["accepted_draft_rate"],
        "tokens_per_burst": p["tokens_per_burst"],
        "accepted_draft_rate_repetitive": p["accepted_draft_rate_repetitive"],
        "tokens_per_burst_repetitive": p["tokens_per_burst_repetitive"],
        "kv_bytes_per_slot_dense": p["kv_bytes_per_slot_dense"],
        "kv_bytes_per_slot_paged": p["kv_bytes_per_slot_paged"],
        "kv_block_utilization": p["kv_block_utilization"],
        "sustainable_slots_dense": p["sustainable_slots_dense"],
        "sustainable_slots_paged": p["sustainable_slots_paged"],
        "latency_ms": p["latency_ms_spec"],
    })
    lat = p["latency_ms_spec"]
    return (f"lm paged+spec ({p['arch']}, page={p['page_size']}, "
            f"k={p['draft_len']}): spec {p['tokens_per_s_spec']:.1f} tok/s "
            f"vs dense {p['tokens_per_s_dense']:.1f} "
            f"({p['spec_speedup']:.2f}x = {p['spec_speedup_from_acceptance']:.2f}x "
            f"acceptance * {p['spec_speedup_from_loop']:.2f}x device loop), "
            f"accept-rate {100 * p['accepted_draft_rate']:.1f}% random / "
            f"{100 * p['accepted_draft_rate_repetitive']:.1f}% repetitive, "
            f"{p['tokens_per_burst']:.2f} / "
            f"{p['tokens_per_burst_repetitive']:.2f} tok/burst; KV bytes/slot "
            f"{p['kv_bytes_per_slot_paged']:.0f} vs "
            f"{p['kv_bytes_per_slot_dense']:.0f} dense, sustainable slots "
            f"{p['sustainable_slots_paged']} vs "
            f"{p['sustainable_slots_dense']}; latency p50 "
            f"{lat.get('p50_ms', 0.0):.0f}ms p99 "
            f"{lat.get('p99_ms', 0.0):.0f}ms")


def run(measure: bool = True):
    if not measure:
        return []
    stats = serve_stats()
    out = []
    for name, r in stats["archs"].items():
        out.append((
            f"serve_lm/prefill/{name}", 0.0,
            f"levels={r['levels']},occupancy={r['occupancy']:.2f},"
            f"occupancy_alap={r['occupancy_alap']:.2f},"
            f"static={int(r['static'])},roundtrips={r['f32_roundtrips']}"))
        out.append((
            f"serve_lm/decode/{name}", 0.0,
            f"levels={r['decode_levels']},"
            f"static={int(r['decode_static'])},"
            f"roundtrips={r['decode_f32_roundtrips']},"
            f"tw_occupancy={r['tw_occupancy_decode']:.2f},"
            f"tw_conv_pe={r['tw_conv_pe_decode']:.2f},"
            f"tw_misc={r['tw_misc_decode']:.2f},"
            f"refill_rate={r['slot_refill_rate']:.2f},"
            f"slot_occupancy={r['slot_occupancy']:.2f}"))
    d = decode_stats()
    out.append((
        f"serve_lm/decode_throughput/{d['arch']}", 0.0,
        f"compiled_tok_s={d['tokens_per_s_compiled']:.1f},"
        f"eager_tok_s={d['tokens_per_s_eager']:.1f},"
        f"speedup={d['speedup']:.2f}x,"
        f"slot_refill_rate={d['slot_refill_rate']:.2f}"))
    q = decode_quant_stats()
    out.append((
        f"serve_lm/decode_w4/{q['arch']}", 0.0,
        f"w4_tok_s={q['tokens_per_s_w4']:.1f},"
        f"w8_tok_s={q['tokens_per_s_w8']:.1f},"
        f"w4_speedup={q['w4_speedup']:.2f}x,"
        f"weight_bytes_ratio={q['weight_bytes_ratio']:.3f}"))
    p = paged_spec_stats()
    out.append((
        f"serve_lm/paged_spec/{p['arch']}", 0.0,
        f"spec_tok_s={p['tokens_per_s_spec']:.1f},"
        f"dense_tok_s={p['tokens_per_s_dense']:.1f},"
        f"accept_rate={p['accepted_draft_rate']:.2f},"
        f"accept_rate_rep={p['accepted_draft_rate_repetitive']:.2f},"
        f"tok_per_burst={p['tokens_per_burst']:.2f},"
        f"slots={p['sustainable_slots_paged']}v"
        f"{p['sustainable_slots_dense']}"))
    x = prefix_sharing_stats()
    out.append((
        f"serve_lm/prefix_share/{x['arch']}", 0.0,
        f"blocks_per_req={x['blocks_per_request']:.2f},"
        f"blocks_ratio={x['blocks_ratio']:.2f},"
        f"prefill_tok_per_req={x['prefill_tokens_per_request']:.1f},"
        f"prefill_ratio={x['prefill_tokens_ratio']:.2f},"
        f"tok_s={x['tokens_per_s']:.1f}"))
    out.append((
        "serve_lm/trace/cached", stats["wall_s"] * 1e6,
        f"hit_rate={stats['cache_hit_rate']:.3f},"
        f"hits={stats['cache_hits']},compiles={stats['cache_misses']},"
        f"requests={stats['requests']}"))
    return out


def summary_line() -> str:
    stats = serve_stats()
    occ = np.mean([r["occupancy"] for r in stats["archs"].values()])
    occ_alap = np.mean([r["occupancy_alap"] for r in stats["archs"].values()])
    occ_slack = np.mean([r["occupancy_slack"]
                         for r in stats["archs"].values()])
    tw = np.mean([r["tw_occupancy_decode"] for r in stats["archs"].values()])
    refill = np.mean([r["slot_refill_rate"] for r in stats["archs"].values()])
    return (f"lm program-cache hit-rate: {100 * stats['cache_hit_rate']:.1f}% "
            f"({stats['cache_hits']}/{stats['requests']} hits, "
            f"{stats['cache_misses']} compiles, {len(stats['archs'])} archs, "
            f"prefill+decode); "
            f"prefill engine occupancy {100 * occ:.1f}% asap / "
            f"{100 * occ_alap:.1f}% alap / {100 * occ_slack:.1f}% slack; "
            f"decode time-weighted occupancy {100 * tw:.1f}%; "
            f"slot-refill rate {100 * refill:.1f}%")


def decode_summary_line() -> str:
    d = decode_stats()
    q = decode_quant_stats()
    _merge_lm_decode({
        "arch": d["arch"],
        "tokens_per_s_compiled": d["tokens_per_s_compiled"],
        "tokens_per_s_eager": d["tokens_per_s_eager"],
        "speedup": d["speedup"],
        "latency_ms_compiled": d["latency_ms"],
        "tokens_per_s_w8": q["tokens_per_s_w8"],
        "tokens_per_s_w4": q["tokens_per_s_w4"],
        "w4_speedup": q["w4_speedup"],
        "weight_bytes_per_token_w8": q["weight_bytes_per_token_w8"],
        "weight_bytes_per_token_w4": q["weight_bytes_per_token_w4"],
        "weight_bytes_ratio": q["weight_bytes_ratio"],
    })
    return (f"lm decode throughput ({d['arch']}): compiled "
            f"{d['tokens_per_s_compiled']:.1f} tok/s vs eager "
            f"{d['tokens_per_s_eager']:.1f} tok/s "
            f"({d['speedup']:.2f}x, p50 "
            f"{d['latency_ms'].get('p50_ms', 0.0):.0f}ms p99 "
            f"{d['latency_ms'].get('p99_ms', 0.0):.0f}ms); slot-refill rate "
            f"{100 * d['slot_refill_rate']:.1f}%, slot occupancy "
            f"{100 * d['slot_occupancy']:.1f}%; "
            f"w4 {q['tokens_per_s_w4']:.1f} tok/s vs w8 "
            f"{q['tokens_per_s_w8']:.1f} tok/s "
            f"({q['w4_speedup']:.2f}x), weight bytes/token "
            f"{q['weight_bytes_per_token_w4']} vs "
            f"{q['weight_bytes_per_token_w8']} "
            f"({q['weight_bytes_ratio']:.3f}x)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", action="store_true",
                    help="one-line LM program-cache + occupancy summary only")
    ap.add_argument("--decode-summary", action="store_true",
                    help="one-line compiled-vs-eager decode tokens/s only")
    ap.add_argument("--fast", action="store_true",
                    help="paged+speculative smoke plus the prefix-sharing "
                         "shared-prompt trace: measured one-liners, lm_decode "
                         "fields merge-written to BENCH_serve.json")
    args = ap.parse_args()
    if args.summary:
        print(summary_line())
    elif args.decode_summary:
        print(decode_summary_line())
    elif args.fast:
        print(paged_summary_line(steps=4))
        print(prefix_sharing_summary_line(steps=4))
    else:
        print("name,us_per_call,derived")
        for row_name, us, derived in run():
            print(f"{row_name},{us:.1f},{derived}")
