"""Fig. 8 reproduction: DWC PE load-vs-MAC time across kernel/stride, plus a
measured sweep of the actual DWC kernels (CPU relative numbers)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dse
from repro.core.config import EngineConfig
from repro.kernels import ops


def run(measure: bool = True):
    rows = []
    for p in dse.fig8_sweep():
        rows.append((
            f"fig8/model/k{p.kernel}s{p.stride}", 0.0,
            f"load_cycles={p.load_cycles:.0f},mac_cycles={p.mac_cycles},"
            f"ctc={p.ctc:.2f}"))
    best = max(dse.fig8_sweep(), key=lambda p: p.ctc)
    rows.append(("fig8/best", 0.0,
                 f"k={best.kernel},s={best.stride} (paper: 7x7 highest)"))

    if measure:
        eng = EngineConfig(quant="none", backend="ref")
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1, 64, 64, 128)).astype(np.float32))
        for k in (3, 5, 7):
            w = jnp.asarray((rng.normal(size=(k, k, 128)) * 0.2
                             ).astype(np.float32))
            f = jax.jit(lambda x, w: ops.dwc2d(x, w, None, 1, "SAME",
                                               "none", eng))
            f(x, w).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(5):
                f(x, w).block_until_ready()
            us = (time.perf_counter() - t0) / 5 * 1e6
            flops = 2 * 64 * 64 * 128 * k * k
            rows.append((f"fig8/measured_cpu/k{k}s1", us,
                         f"gflops_s={flops / us / 1e3:.2f}"))
    return rows
