"""Fleet serving: ops/s vs forced host-device count {1, 2, 4, 8}.

Each device count runs in its OWN subprocess: XLA fixes the device list at
import, so the parent spawns one worker per point with
`--xla_force_host_platform_device_count=N` pinned in XLA_FLAGS before jax
loads.  The worker builds a ("data", "model") serving mesh
(serve/mesh_exec.py), serves hundreds of requests through a mesh-attached
CNNServeEngine with pump()-per-chunk arrivals (full waves only, response-
edge sync), and prints one FLEET_JSON line the parent collects.

Throughput accounting -- this host exposes ONE physical core, so forced
host devices time-slice it and raw wall-clock cannot show parallel
speedup.  Both numbers are recorded:

  * ops_measured = N / wall -- the raw serialized wall of the trace.
  * ops_derived  = N / (host_s + t_dev_total / devices) -- the fleet rate
    under perfect device overlap, from MEASURED components: t_dev_total is
    the serialized device time (per-model wave wall, calibrated by K
    repeated full-wave runs post-warmup, times the per-model execution
    count the engine records) and host_s is the non-overlappable host
    residue (scheduling, submission copies, response-edge
    materialization).  The raw residual wall - t_dev_total is reported as
    host_resid_s; when it goes NEGATIVE (the wave calibration
    over-measured on the time-sliced core) the t_wave calibration is
    rescaled to the measured wall and the point flagged t_wave_clamped,
    rather than clamping host_s to zero against an inflated device time
    (which silently overstated ops_derived at 1-2 devices).

The acceptance gate: ops_derived grows monotonically with device count and
reaches >= 3x at 8 devices vs 1; the full run records the sweep under the
"fleet" key of BENCH_serve.json (merge-write -- serve_cnn's keys survive).
A tensor-parallel LM leg decodes the same prompts on 1 device and on a
(1, 4) model-axis mesh and asserts bit-identical token ids (the whole-head
TP rule; tests/test_sharding.py pins it zoo-wide).

    PYTHONPATH=src python -m benchmarks.serve_fleet            # full sweep
    PYTHONPATH=src python -m benchmarks.serve_fleet --smoke    # CI gate

--smoke sweeps {1, 8} with the truncated --fast model and 64 requests, no
BENCH write, asserting monotonicity only (scripts/check.sh runs it).
"""
import json
import os
import sys
import time

# Worker processes must pin the forced device count BEFORE jax initializes
# -- and importing benchmarks.serve_cnn pulls repro.configs, which imports
# jax at module top.  Nothing heavier than stdlib may be imported above
# this block.
_FORCE_FLAG = "--xla_force_host_platform_device_count"


def _forced_devices_from_argv():
    for flag in ("--worker", "--lm-worker"):
        if flag in sys.argv:
            return int(sys.argv[sys.argv.index(flag) + 1])
    return None


_N_FORCED = _forced_devices_from_argv()
if _N_FORCED is not None and _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE_FLAG}={_N_FORCED}").strip()

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

DEVICES = (1, 2, 4, 8)
SMOKE_DEVICES = (1, 8)
REQUESTS = 256
SMOKE_REQUESTS = 64
CHUNK = 32                     # requests per arrival burst between pump()s
CALIB_REPS = 3                 # full-wave timings per model (min taken)
FLEET_MODELS = ("squeezenet", "mobilenetv2")
LM_TP = 4
MARKER = "FLEET_JSON "


# -- workers (one forced-device-count jax runtime each) ----------------------

def _worker_cnn(n: int, requests: int, fast: bool) -> None:
    """Serve one trace on an n-device data-parallel mesh; print the
    FLEET_JSON result line."""
    import jax
    import numpy as np

    assert len(jax.devices()) >= n, \
        f"forced {n} devices, jax sees {len(jax.devices())}"

    from benchmarks import serve_cnn as sc
    from repro.core import engine as eng_lib
    from repro.serve.base import LatencyTracker
    from repro.serve.cnn_engine import CNNServeEngine
    from repro.serve.mesh_exec import make_serve_mesh

    models = [sc.fast_cfg()] if fast else list(FLEET_MODELS)
    fleet = sc._build_fleet(models=models)
    mesh = make_serve_mesh(n_data=n)
    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=sc.WAVE,
                            cache_capacity=len(fleet) + 1, mesh=mesh)
    for cfg, params, calib in fleet:
        engine.register(cfg, params, calib_batches=[calib])

    rng = np.random.default_rng(1)

    def wave_batch(cfg):
        return rng.normal(size=(engine.wave_rows, cfg.input_hw,
                                cfg.input_hw, 3)).astype(np.float32)

    # warm outside the clock: compile + first placement per model
    for cfg, _, _ in fleet:
        engine.infer(cfg.name, wave_batch(cfg))
    # calibrate the serialized device time of one FULL wave per model: a
    # blocked mean (CALIB_REPS consecutive waves under one clock) averages
    # out per-call timer noise better than min-of-singles on a busy core
    t_wave = {}
    for cfg, _, _ in fleet:
        batch = wave_batch(cfg)
        engine.infer(cfg.name, batch)          # extra settle run
        t0 = time.perf_counter()
        for _ in range(CALIB_REPS):
            engine.infer(cfg.name, batch)
        t_wave[cfg.name] = (time.perf_counter() - t0) / CALIB_REPS

    # measured trace: chunked arrivals, continuous batching, fresh counters
    engine.latency = LatencyTracker()
    x0 = dict(engine.execs_by_model)
    s = engine._sched.stats
    d0, p0 = s.dispatched, s.padded_slots
    trace = [(fleet[i % len(fleet)][0].name,
              rng.normal(size=(fleet[i % len(fleet)][0].input_hw,
                               fleet[i % len(fleet)][0].input_hw, 3)
                         ).astype(np.float32))
             for i in rng.permutation(requests)]
    t0 = time.perf_counter()
    for lo in range(0, len(trace), CHUNK):
        for name, img in trace[lo:lo + CHUNK]:
            engine.submit(name, img)
        engine.pump()
    engine.flush()
    wall = time.perf_counter() - t0

    execs = {m: engine.execs_by_model.get(m, 0) - x0.get(m, 0)
             for m in t_wave}
    t_dev_total = sum(t_wave[m] * execs[m] for m in t_wave)
    resid = wall - t_dev_total
    clamped = resid < 0.0
    if clamped:
        # The calibrated per-wave walls over-measured (timer noise on a
        # time-sliced core): serialized device time cannot exceed the trace
        # wall it is a component of.  Silently flooring host_s at zero
        # against the INFLATED t_dev_total -- the old behavior -- kicks in
        # at 1-2 devices and overstates ops_derived; instead rescale the
        # wave calibration so t_dev_total matches the measured wall, report
        # the raw residual, and flag the point.
        scale = wall / t_dev_total if t_dev_total > 0 else 1.0
        t_wave = {m: t * scale for m, t in t_wave.items()}
        t_dev_total = wall
    host_s = max(resid, 0.0)
    slots = (s.dispatched - d0) + (s.padded_slots - p0)
    result = {
        "devices": n,
        "mesh": str(engine.mexec.topology),
        "wave_rows": engine.wave_rows,
        "requests": requests,
        "wall_s": wall,
        "ops_measured": requests / wall if wall > 0 else 0.0,
        "ops_derived": requests / (host_s + t_dev_total / n),
        "t_dev_total_s": t_dev_total,
        "host_s": host_s,
        "host_resid_s": resid,         # raw wall - t_dev_total, pre-clamp
        "t_wave_clamped": clamped,
        "t_wave_s": t_wave,
        "execs_by_model": execs,
        "fill_rate": (s.dispatched - d0) / slots if slots else 0.0,
        "pool_locality_rate": s.locality_rate,
        "latency_ms": engine.latency.percentiles(),
    }
    print(MARKER + json.dumps(result))


def _worker_lm(tp: int) -> None:
    """Decode the same prompts single-device and tensor-parallel on a
    (1, tp) mesh; print identicality + placement + walls."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.core.config import EngineConfig
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serve.engine import ServeEngine
    from repro.serve.mesh_exec import make_serve_mesh

    arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    calib = [jnp.array(rng.integers(0, arch.vocab_size,
                                    (2, 8)).astype(np.int32))]
    prompts = [rng.integers(0, arch.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]
    w8 = EngineConfig(quant="w8a8", backend="ref")

    def serve(mesh):
        eng = ServeEngine(arch, params, w8, batch_size=2, max_seq=32,
                          calib_batches=calib, prefill_len=8, mesh=mesh)
        t0 = time.perf_counter()
        out = eng.generate(prompts, max_new_tokens=6)
        return out, time.perf_counter() - t0, eng.stats()

    base, wall_base, _ = serve(None)
    tp_out, wall_tp, st = serve(make_serve_mesh(n_data=1, n_model=tp))
    identical = all(np.array_equal(a, b) for a, b in zip(base, tp_out))
    result = {
        "arch": "qwen2-1.5b(reduced)", "tp": tp,
        "identical": bool(identical),
        "tokens": int(sum(len(a) for a in base)),
        "tp_placement": st.get("tp_placement"),
        "wall_base_s": wall_base, "wall_tp_s": wall_tp,
    }
    print(MARKER + json.dumps(result))


# -- parent: spawn one worker per device count -------------------------------

def _spawn(worker_args, n: int):
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = f"{_FORCE_FLAG}={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_fleet"] + worker_args,
        capture_output=True, text=True, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fleet worker {worker_args} failed:\n{proc.stderr[-2000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith(MARKER):
            return json.loads(line[len(MARKER):])
    raise RuntimeError(f"no {MARKER.strip()} line from worker {worker_args}:"
                       f"\n{proc.stdout[-2000:]}")


def run(smoke: bool = False):
    devices = SMOKE_DEVICES if smoke else DEVICES
    requests = SMOKE_REQUESTS if smoke else REQUESTS
    sweep = []
    reps = 1 if smoke else 2     # full mode: best-of-2 rides out core noise
    for n in devices:
        args = ["--worker", str(n), "--requests", str(requests)]
        if smoke:
            args.append("--fast")
        r = max((_spawn(args, n) for _ in range(reps)),
                key=lambda x: x["ops_derived"])
        sweep.append(r)
        print(f"devices={n} wave_rows={r['wave_rows']} "
              f"ops_derived={r['ops_derived']:.1f}/s "
              f"ops_measured={r['ops_measured']:.1f}/s "
              f"t_dev={r['t_dev_total_s'] * 1e3:.0f}ms "
              f"host={r['host_s'] * 1e3:.0f}ms "
              f"host_resid={r['host_resid_s'] * 1e3:.0f}ms"
              f"{' (t_wave recalibrated)' if r['t_wave_clamped'] else ''} "
              f"fill={r['fill_rate']:.2f} "
              f"locality={r['pool_locality_rate']:.2f} "
              f"p50={r['latency_ms']['p50_ms']:.1f}ms "
              f"p99={r['latency_ms']['p99_ms']:.1f}ms")
    ops = [r["ops_derived"] for r in sweep]
    monotonic = all(b >= a for a, b in zip(ops, ops[1:]))
    speedup = ops[-1] / ops[0]
    print(f"scaling: {speedup:.2f}x at {devices[-1]} devices vs 1 "
          f"(monotonic={monotonic})")
    assert monotonic, f"ops_derived not monotonic over {devices}: {ops}"
    if not smoke:
        assert speedup >= 3.0, \
            f"need >=3x derived scaling at 8 devices, got {speedup:.2f}x"
        lm = _spawn(["--lm-worker", str(LM_TP)], LM_TP)
        assert lm["identical"], \
            f"TP decode diverged from single-device: {lm}"
        print(f"lm_tp: {lm['arch']} tp={lm['tp']} identical over "
              f"{lm['tokens']} tokens "
              f"(sharded {lm['tp_placement']['tp_sharded']}/"
              f"{lm['tp_placement']['tp_sharded'] + lm['tp_placement']['tp_replicated']} leaves)")
        from benchmarks import serve_cnn as sc
        fleet_block = {
            "devices": sweep,
            "speedup": {f"{devices[-1]}x_vs_1x": speedup},
            "monotonic": monotonic,
            "clamped_points": [r["devices"] for r in sweep
                               if r["t_wave_clamped"]],
            "lm_tp": lm,
            "accounting": (
                "single-core host: forced devices time-slice one core, so "
                "ops_derived = N / (host_s + t_dev_total/devices) is the "
                "fleet rate under perfect overlap from measured components "
                "(calibrated per-model wave wall x engine exec counts); "
                "ops_measured = N / wall is the raw serialized wall; "
                "host_resid_s is the raw wall - t_dev_total residual, and "
                "points where it went negative (wave calibration "
                "over-measured) carry t_wave_clamped=true with t_wave "
                "rescaled to the measured wall instead of host_s silently "
                "clamped against an inflated device time"),
        }
        path = sc.write_bench_json({"fleet": fleet_block})
        print(f"BENCH_serve.json: {path}")
    return sweep


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=None,
                    help="internal: serve on N forced devices, print JSON")
    ap.add_argument("--lm-worker", type=int, default=None,
                    help="internal: TP-vs-single decode parity on N devices")
    ap.add_argument("--requests", type=int, default=REQUESTS)
    ap.add_argument("--fast", action="store_true",
                    help="truncated model (serve_cnn.fast_cfg)")
    ap.add_argument("--smoke", action="store_true",
                    help="{1,8} devices, 64 requests, no BENCH write")
    args = ap.parse_args()
    if args.worker is not None:
        _worker_cnn(args.worker, args.requests, args.fast)
    elif args.lm_worker is not None:
        _worker_lm(args.lm_worker)
    else:
        run(smoke=args.smoke)
