"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json)."""
import glob
import json
import os

from repro.core import roofline as rl

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(directory: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run():
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline/missing", 0.0,
                 f"no dry-run artifacts in {DRYRUN_DIR}; run "
                 "`python -m repro.launch.dryrun --all --both-meshes`")]
    ok = skipped = err = 0
    for r in recs:
        name = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            skipped += 1
            rows.append((name, 0.0, "skipped:" + r["reason"][:60]))
            continue
        if r["status"] != "ok":
            err += 1
            rows.append((name, 0.0, "ERROR:" + r["error"][:80]))
            continue
        ok += 1
        bound_us = max(r["t_compute_s"], r["t_memory_s"],
                       r["t_collective_s"]) * 1e6
        rows.append((
            name, bound_us,
            f"bound={r['bottleneck']},roofline={100 * r['roofline_fraction']:.1f}%,"
            f"useful={r['useful_flop_ratio']:.2f},"
            f"tc={r['t_compute_s'] * 1e3:.2f}ms,"
            f"tm={r['t_memory_s'] * 1e3:.2f}ms,"
            f"tx={r['t_collective_s'] * 1e3:.2f}ms,"
            f"fit={r['bytes_per_device'] / 2**30:.1f}GB/dev"))
    rows.append(("roofline/summary", 0.0,
                 f"ok={ok},skipped={skipped},errors={err}"))
    return rows
