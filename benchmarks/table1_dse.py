"""Table I reproduction: DSE reuse requirements under bandwidth splits."""
import time

from repro.core import dse

# Paper Table I rows: (bw_f, bw_w) -> (FMReuse, WTReuse, OC, IHxIW)
PAPER = {
    (16, 16): (8, 64, 64, 64),
    (16, 32): (8, 32, 64, 32),
    (32, 16): (4, 64, 32, 64),
    (32, 32): (4, 32, 32, 32),
}


def run():
    rows = []
    t0 = time.perf_counter()
    table = dse.table1()
    us = (time.perf_counter() - t0) * 1e6 / len(table)
    matches = 0
    for r in table:
        want = PAPER[(r.bw_f, r.bw_w)]
        got = (r.fm_reuse, r.wt_reuse, r.oc, r.ihw)
        ok = got == want
        matches += ok
        rows.append((f"table1/bw_f={r.bw_f},bw_w={r.bw_w}", us,
                     f"fm={r.fm_reuse},wt={r.wt_reuse},oc={r.oc},"
                     f"ihw={r.ihw},ctc={r.ctc:.2f},paper_match={ok}"))
    choice = dse.dpuv4e_choice()
    rows.append(("table1/dpuv4e_choice", us,
                 f"bwf32_bww16_oc{choice.oc}_ihw{choice.ihw},"
                 f"match={matches}/4"))
    # Eq. 3-4: the ACC/NL buffer plan behind IH=4, IW=16.
    plan = dse.acc_buffer_plan(4, 16, 32)
    rows.append(("table1/eq3_acc_plan", 0.0,
                 f"psum={plan.psum_bytes}B,total={plan.total_bytes}B,"
                 f"fits64KB={plan.fits},iw_max={dse.max_iw()}"))
    return rows
