"""CNN serving throughput: program cache + wave batching + overlap credit.

Three evidence lines for the serving layer (serve/cnn_engine.py):

  * MODELED: the per-engine-unit overlap model (perf_model.py) -- in the
    pipelined steady state throughput is set by the busiest unit (Conv PE
    vs DWC PE vs MISC), so depthwise-heavy models gain the most from the
    Conv/DWC concurrency the schedule exposes (scheduled-vs-sequential).
  * MEASURED cache: wall-clock of a repeated-model request trace served
    cached vs uncached (capacity 0 -> every request recompiles +
    recalibrates + retraces), plus the cache hit-rate of the trace.
  * MEASURED waves: per-request latency of wave-batched vs one-by-one
    execution on the same cached program.

    PYTHONPATH=src python -m benchmarks.serve_cnn [--summary]

--summary prints the one-line program-cache hit-rate (scripts/check.sh
appends it to the gate output).
"""
import time

import numpy as np

from benchmarks import perf_model as pm
from repro.configs.cnn_zoo import CNN_ZOO

TRACE_MODELS = ("squeezenet", "mobilenetv2", "resnet50")
TRACE_LEN = 40                              # requests over the 3 models
SERVE_HW = 32                               # reduced input for CPU wall-clock
WAVE = 4


def _reduced(name):
    import dataclasses
    return dataclasses.replace(CNN_ZOO[name], input_hw=SERVE_HW)


def _build_fleet(seed=0):
    """(cfg, float params, calibration batch) per trace model."""
    import jax
    import jax.numpy as jnp

    from repro.models import cnn
    from repro.models.params import init_params

    fleet = []
    rng = np.random.default_rng(seed)
    for i, name in enumerate(TRACE_MODELS):
        cfg = _reduced(name)
        params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(i))
        calib = jnp.asarray(rng.normal(
            size=(2, cfg.input_hw, cfg.input_hw, cfg.input_ch)
        ).astype(np.float32) * 0.5)
        fleet.append((cfg, params, calib))
    return fleet


def _trace(seed=0):
    """A repeated-model request trace: each request names a model and
    carries one image.  Model repetition mirrors production traffic (a
    small working set revisited), which is what the cache monetizes."""
    rng = np.random.default_rng(seed)
    names = [TRACE_MODELS[int(i)] for i in
             rng.integers(0, len(TRACE_MODELS), TRACE_LEN)]
    sizes = {n: _reduced(n).input_hw for n in TRACE_MODELS}
    return [(n, rng.normal(size=(sizes[n], sizes[n], 3)).astype(np.float32))
            for n in names]


def _serve_trace(engine, fleet, trace):
    for cfg, params, calib in fleet:
        engine.register(cfg, params, calib_batches=[calib])
    t0 = time.perf_counter()
    for name, img in trace:
        engine.submit(name, img)
        engine.flush()                  # request-at-a-time arrival
    return time.perf_counter() - t0


def serve_stats(wave_batch: bool = True, fleet=None, trace=None):
    """Serve the standard trace through a cached engine; return its stats
    (the hit-rate + occupancy line check.sh prints comes from here)."""
    from repro import compiler
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    fleet = _build_fleet() if fleet is None else fleet
    trace = _trace() if trace is None else trace
    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                            cache_capacity=len(TRACE_MODELS) + 1)
    wall = _serve_trace(engine, fleet, trace)
    stats = engine.stats()
    stats["wall_s"] = wall
    # per-level engine occupancy of the served programs, ASAP vs ALAP
    occ, occ_alap = [], []
    for cfg, _, _ in fleet:
        program = engine.program_for(cfg.name)
        occ.append(compiler.engine_occupancy(
            program.graph, program.schedule)["occupancy"])
        occ_alap.append(compiler.engine_occupancy(
            program.graph,
            compiler.level_schedule(program.graph, "alap"))["occupancy"])
    stats["engine_occupancy"] = float(np.mean(occ))
    stats["engine_occupancy_alap"] = float(np.mean(occ_alap))
    if wave_batch:
        # the same trace arriving all at once: full waves per model
        engine2 = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                                 cache_capacity=len(TRACE_MODELS) + 1,
                                 cache=engine.cache)   # warm shared cache
        for cfg, params, calib in fleet:
            engine2.register(cfg, params, calib_batches=[calib])
        for name, img in trace:
            engine2.submit(name, img)
        t0 = time.perf_counter()
        engine2.flush()
        stats["wall_batched_s"] = time.perf_counter() - t0
        stats["batched_occupancy"] = engine2.wave_stats.occupancy
    return stats


def fill_rate_stats(fleet=None, trace=None):
    """Mixed-arrival trace, one request at a time, two batching policies:

      * pad-and-mask baseline -- flush() after every arrival: every request
        dispatches immediately in a mostly-empty padded wave;
      * continuous -- pump() after every arrival: only FULL waves dispatch,
        partial waves stay queued and REFILL from later arrivals (including
        other same-shape models, which share the slot queue); one final
        drain pads at most one wave per shape.

    The wave fill-rate (requests / physical wave slots) is the acceptance
    metric: continuous must meet or beat the baseline."""
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    fleet = _build_fleet() if fleet is None else fleet
    trace = _trace() if trace is None else trace

    base = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                          cache_capacity=len(TRACE_MODELS) + 1)
    for cfg, params, calib in fleet:
        base.register(cfg, params, calib_batches=[calib])
    for name, img in trace:
        base.submit(name, img)
        base.flush()                    # pad-and-mask per arrival

    cont = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                          cache_capacity=len(TRACE_MODELS) + 1,
                          cache=base.cache)    # warm shared cache
    for cfg, params, calib in fleet:
        cont.register(cfg, params, calib_batches=[calib])
    for name, img in trace:
        cont.submit(name, img)
        cont.pump()                     # full waves only; partials refill
    cont.flush()                        # final drain
    b, c = base.stats(), cont.stats()
    return {
        "baseline_fill_rate": b["wave_fill_rate"],
        "continuous_fill_rate": c["wave_fill_rate"],
        "baseline_waves": b["waves"],
        "continuous_waves": c["waves"],
        "refilled_waves": c["refilled_waves"],
        "program_execs": c["program_execs"],
    }


def _measure_uncached(fleet, trace):
    """capacity=0: every request misses, recompiles, and retraces."""
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                            cache_capacity=0)
    return _serve_trace(engine, fleet, trace), engine.stats()


def run(measure: bool = True):
    rows = []
    for name, cfg in CNN_ZOO.items():
        credit = pm.overlap_credit(cfg, pm.OURS)
        fps_seq = pm.modeled_fps(cfg, pm.OURS)
        fps_pipe = pm.modeled_fps_pipelined(cfg, pm.OURS)
        rows.append((
            f"serve/model/{name}", 0.0,
            f"scheduled_fps={fps_pipe:.0f},sequential_fps={fps_seq:.0f},"
            f"overlap_credit={credit:.2f}"))
    if measure:
        fleet = _build_fleet()
        trace = _trace()
        stats = serve_stats(fleet=fleet, trace=trace)
        t_uncached, _ = _measure_uncached(fleet, trace[:6])
        t_uncached_per = t_uncached / 6
        t_cached_per = stats["wall_s"] / len(trace)
        rows.append((
            f"serve/trace/cached", t_cached_per * 1e6,
            f"hit_rate={stats['cache_hit_rate']:.3f},"
            f"requests={stats['requests']},"
            f"compiles={stats['cache_misses']},"
            f"per_req={t_cached_per * 1e3:.1f}ms,"
            f"uncached_per_req={t_uncached_per * 1e3:.1f}ms,"
            f"cache_speedup={t_uncached_per / t_cached_per:.1f}x"))
        rows.append((
            f"serve/trace/waves", stats["wall_batched_s"] * 1e6,
            f"batched_wall={stats['wall_batched_s'] * 1e3:.1f}ms,"
            f"one_by_one_wall={stats['wall_s'] * 1e3:.1f}ms,"
            f"occupancy={stats['batched_occupancy']:.2f},wave={WAVE}"))
        fr = fill_rate_stats(fleet=fleet, trace=trace)
        rows.append((
            f"serve/trace/fill_rate", 0.0,
            f"continuous={fr['continuous_fill_rate']:.2f},"
            f"pad_and_mask={fr['baseline_fill_rate']:.2f},"
            f"waves={fr['continuous_waves']}vs{fr['baseline_waves']},"
            f"refilled_waves={fr['refilled_waves']}"))
    return rows


def summary_line() -> str:
    fleet, trace = _build_fleet(), _trace()
    stats = serve_stats(wave_batch=False, fleet=fleet, trace=trace)
    fr = fill_rate_stats(fleet=fleet, trace=trace)
    return (f"program-cache hit-rate: {100 * stats['cache_hit_rate']:.1f}% "
            f"({stats['cache_hits']}/{stats['cache_hits'] + stats['cache_misses']} hits, "
            f"{stats['cache_misses']} compiles over {stats['requests']} "
            f"requests, {len(TRACE_MODELS)} models); "
            f"per-level engine occupancy "
            f"{100 * stats['engine_occupancy']:.1f}% asap / "
            f"{100 * stats['engine_occupancy_alap']:.1f}% alap; "
            f"wave fill-rate {100 * fr['continuous_fill_rate']:.1f}% "
            f"continuous vs {100 * fr['baseline_fill_rate']:.1f}% "
            f"pad-and-mask")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", action="store_true",
                    help="one-line program-cache hit-rate only")
    ap.add_argument("--fast", action="store_true",
                    help="model-only rows (skip wall-clock)")
    args = ap.parse_args()
    if args.summary:
        print(summary_line())
    else:
        print("name,us_per_call,derived")
        for row_name, us, derived in run(measure=not args.fast):
            print(f"{row_name},{us:.1f},{derived}")
