"""CNN serving throughput: program cache + wave batching + fused epilogues.

Evidence lines for the serving layer (serve/cnn_engine.py):

  * MODELED: the per-engine-unit overlap model (perf_model.py) -- in the
    pipelined steady state throughput is set by the busiest unit (Conv PE
    vs DWC PE vs MISC), so depthwise-heavy models gain the most from the
    Conv/DWC concurrency the schedule exposes (scheduled-vs-sequential).
  * MEASURED cache: wall-clock of a repeated-model request trace served
    cached vs uncached (capacity 0 -> every request recompiles +
    recalibrates + retraces), plus the cache hit-rate of the trace.
  * MEASURED waves: per-request latency of wave-batched vs one-by-one
    execution on the same cached program.
  * STRUCTURAL fusion: kernel launches + materialized intermediates per
    image of the served (epilogue-fused) programs vs their unfused twins,
    and per-level / time-weighted engine occupancy of the fused graphs
    under the asap vs slack leveling policies.

    PYTHONPATH=src python -m benchmarks.serve_cnn [--summary]

--summary prints the one-line program-cache + fusion summary (scripts/
check.sh appends it to the gate output) and writes the machine-readable
BENCH_serve.json snapshot next to the repo root, so the serving perf
trajectory is tracked across PRs.
"""
import json
import os
import time

import numpy as np

from benchmarks import perf_model as pm
from repro.configs.cnn_zoo import CNN_ZOO

TRACE_MODELS = ("squeezenet", "mobilenetv2", "resnet50")
TRACE_LEN = 40                              # requests over the 3 models
FAST_LEN = 10                               # --fast: one model, short trace
SERVE_HW = 32                               # reduced input for CPU wall-clock
WAVE = 4
BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")


def _reduced(name):
    import dataclasses
    return dataclasses.replace(CNN_ZOO[name], input_hw=SERVE_HW)


def _build_fleet(seed=0, models=TRACE_MODELS):
    """(cfg, float params, calibration batch) per trace model.  `models`
    entries are zoo names or ready CNNConfig objects."""
    import jax
    import jax.numpy as jnp

    from repro.models import cnn
    from repro.models.params import init_params

    fleet = []
    rng = np.random.default_rng(seed)
    for i, m in enumerate(models):
        cfg = _reduced(m) if isinstance(m, str) else m
        params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(i))
        calib = jnp.asarray(rng.normal(
            size=(2, cfg.input_hw, cfg.input_hw, cfg.input_ch)
        ).astype(np.float32) * 0.5)
        fleet.append((cfg, params, calib))
    return fleet


def fast_cfg():
    """--fast's model: the reduced squeezenet truncated to its first
    fire stages -- same op mix (stem, pool, fire blocks), a fraction of
    the param-init + XLA-compile wall that dominates the fast budget."""
    import dataclasses
    base = _reduced("squeezenet")
    return dataclasses.replace(base, name="squeezenet-fast",
                               stages=base.stages[:3])


def _trace(seed=0, models=TRACE_MODELS, length=TRACE_LEN):
    """A repeated-model request trace: each request names a model and
    carries one image.  Model repetition mirrors production traffic (a
    small working set revisited), which is what the cache monetizes."""
    rng = np.random.default_rng(seed)
    names = [models[int(i)] for i in
             rng.integers(0, len(models), length)]
    sizes = {n: _reduced(n).input_hw for n in models}
    return [(n, rng.normal(size=(sizes[n], sizes[n], 3)).astype(np.float32))
            for n in names]


def _serve_trace(engine, fleet, trace):
    for cfg, params, calib in fleet:
        engine.register(cfg, params, calib_batches=[calib])
    t0 = time.perf_counter()
    for name, img in trace:
        engine.submit(name, img)
        engine.flush()                  # request-at-a-time arrival
    return time.perf_counter() - t0


def serve_stats(wave_batch: bool = True, fleet=None, trace=None, cache=None):
    """Serve the standard trace through a cached engine; return its stats
    (the hit-rate + occupancy line check.sh prints comes from here)."""
    from repro import compiler
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    fleet = _build_fleet() if fleet is None else fleet
    trace = _trace() if trace is None else trace
    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                            cache_capacity=len(fleet) + 1, cache=cache)
    wall = _serve_trace(engine, fleet, trace)
    stats = engine.stats()
    stats["wall_s"] = wall
    stats["requests_per_s"] = len(trace) / wall if wall > 0 else 0.0
    # served (fused) programs: per-level + time-weighted engine occupancy
    # under each leveling policy, and launches/image vs the unfused twin
    occ = {"asap": [], "alap": [], "slack": [], "cost": []}
    tw = {"asap": [], "slack": [], "cost": []}
    launches = {}
    for cfg, _, _ in fleet:
        program = engine.program_for(cfg.name)
        g = program.graph
        unfused = compiler.build_graph(cfg)
        times = pm.cnn_node_times(g, cfg)
        for policy in occ:
            if policy == "asap":
                sched = program.schedule
            elif policy == "cost":
                sched = compiler.level_schedule(g, "cost", node_times=times)
            else:
                sched = compiler.level_schedule(g, policy)
            occ[policy].append(
                compiler.engine_occupancy(g, sched)["occupancy"])
            if policy in tw:
                tw[policy].append(compiler.time_weighted_occupancy(
                    g, sched, times)["occupancy"])
        fs = compiler.fusion_stats(g)
        launches[cfg.name] = {
            "unfused": compiler.launch_count(unfused),
            "fused": fs["launches"],
            "fused_ops": fs["fused_ops"],
            "materialized_edges": fs["materialized_edges"],
            "materialized_unfused":
                compiler.fusion_stats(unfused)["materialized_edges"],
        }
    stats["engine_occupancy"] = float(np.mean(occ["asap"]))
    stats["engine_occupancy_alap"] = float(np.mean(occ["alap"]))
    stats["engine_occupancy_slack"] = float(np.mean(occ["slack"]))
    stats["engine_occupancy_cost"] = float(np.mean(occ["cost"]))
    stats["tw_occupancy"] = float(np.mean(tw["asap"]))
    stats["tw_occupancy_slack"] = float(np.mean(tw["slack"]))
    stats["tw_occupancy_cost"] = float(np.mean(tw["cost"]))
    stats["launches"] = launches
    if wave_batch:
        # the same trace arriving all at once: full waves per model
        engine2 = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                                 cache_capacity=len(fleet) + 1,
                                 cache=engine.cache)   # warm shared cache
        for cfg, params, calib in fleet:
            engine2.register(cfg, params, calib_batches=[calib])
        for name, img in trace:
            engine2.submit(name, img)
        t0 = time.perf_counter()
        engine2.flush()
        stats["wall_batched_s"] = time.perf_counter() - t0
        stats["batched_occupancy"] = engine2.wave_stats.occupancy
    return stats


def fill_rate_stats(fleet=None, trace=None, cache=None):
    """Mixed-arrival trace, one request at a time, two batching policies:

      * pad-and-mask baseline -- flush() after every arrival: every request
        dispatches immediately in a mostly-empty padded wave;
      * continuous -- pump() after every arrival: only FULL waves dispatch,
        partial waves stay queued and REFILL from later arrivals (including
        other same-shape models, which share the slot queue); one final
        drain pads at most one wave per shape.

    The wave fill-rate (requests / physical wave slots) is the acceptance
    metric: continuous must meet or beat the baseline."""
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    fleet = _build_fleet() if fleet is None else fleet
    trace = _trace() if trace is None else trace

    base = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                          cache_capacity=len(fleet) + 1, cache=cache)
    for cfg, params, calib in fleet:
        base.register(cfg, params, calib_batches=[calib])
    for name, img in trace:
        base.submit(name, img)
        base.flush()                    # pad-and-mask per arrival

    cont = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                          cache_capacity=len(fleet) + 1,
                          cache=base.cache)    # warm shared cache
    for cfg, params, calib in fleet:
        cont.register(cfg, params, calib_batches=[calib])
    for name, img in trace:
        cont.submit(name, img)
        cont.pump()                     # full waves only; partials refill
    cont.flush()                        # final drain
    b, c = base.stats(), cont.stats()
    return {
        "baseline_fill_rate": b["wave_fill_rate"],
        "continuous_fill_rate": c["wave_fill_rate"],
        "baseline_waves": b["waves"],
        "continuous_waves": c["waves"],
        "refilled_waves": c["refilled_waves"],
        "program_execs": c["program_execs"],
    }


def _measure_uncached(fleet, trace):
    """capacity=0: every request misses, recompiles, and retraces."""
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                            cache_capacity=0)
    return _serve_trace(engine, fleet, trace), engine.stats()


def run(measure: bool = True):
    rows = []
    for name, cfg in CNN_ZOO.items():
        credit = pm.overlap_credit(cfg, pm.OURS)
        fps_seq = pm.modeled_fps(cfg, pm.OURS)
        fps_pipe = pm.modeled_fps_pipelined(cfg, pm.OURS)
        rows.append((
            f"serve/model/{name}", 0.0,
            f"scheduled_fps={fps_pipe:.0f},sequential_fps={fps_seq:.0f},"
            f"overlap_credit={credit:.2f}"))
    zoo = zoo_fusion_occupancy()
    for name, z in zoo.items():
        rows.append((
            f"serve/fusion/{name}", 0.0,
            f"launches={z['launches_fused']}vs{z['launches_unfused']}"
            f"(-{100 * z['launch_reduction']:.0f}%),"
            f"fused_ops={z['fused_ops']},"
            f"occ_asap={z['occupancy']['asap']:.2f},"
            f"occ_slack={z['occupancy']['slack']:.2f},"
            f"tw_occ_slack={z['tw_occupancy_slack']:.2f}"))
    if measure:
        fleet = _build_fleet()
        trace = _trace()
        stats = serve_stats(fleet=fleet, trace=trace)
        t_uncached, _ = _measure_uncached(fleet, trace[:6])
        t_uncached_per = t_uncached / 6
        t_cached_per = stats["wall_s"] / len(trace)
        rows.append((
            f"serve/trace/cached", t_cached_per * 1e6,
            f"hit_rate={stats['cache_hit_rate']:.3f},"
            f"requests={stats['requests']},"
            f"compiles={stats['cache_misses']},"
            f"per_req={t_cached_per * 1e3:.1f}ms,"
            f"uncached_per_req={t_uncached_per * 1e3:.1f}ms,"
            f"cache_speedup={t_uncached_per / t_cached_per:.1f}x"))
        rows.append((
            f"serve/trace/waves", stats["wall_batched_s"] * 1e6,
            f"batched_wall={stats['wall_batched_s'] * 1e3:.1f}ms,"
            f"one_by_one_wall={stats['wall_s'] * 1e3:.1f}ms,"
            f"occupancy={stats['batched_occupancy']:.2f},wave={WAVE}"))
        fr = fill_rate_stats(fleet=fleet, trace=trace)
        rows.append((
            f"serve/trace/fill_rate", 0.0,
            f"continuous={fr['continuous_fill_rate']:.2f},"
            f"pad_and_mask={fr['baseline_fill_rate']:.2f},"
            f"waves={fr['continuous_waves']}vs{fr['baseline_waves']},"
            f"refilled_waves={fr['refilled_waves']}"))
        path = write_bench_json(bench_payload(fleet=fleet, trace=trace,
                                              stats=stats, fr=fr,
                                              zoo=zoo)[0])
        rows.append((f"serve/bench_json", 0.0, f"path={path}"))
    return rows


def zoo_fusion_occupancy():
    """Structural (no-execution) zoo-wide fusion + scheduling evidence:
    per model, launches/image fused vs unfused and per-level occupancy
    under asap/alap/slack on the FUSED graph.  The acceptance gate: slack
    occupancy >= asap on every model, and the ResNet-style launch drop."""
    from repro import compiler

    out = {}
    for name, cfg in CNN_ZOO.items():
        g = compiler.build_graph(cfg)
        fg, _ = compiler.fuse_epilogues(g)
        times = pm.cnn_node_times(fg, cfg)
        scheds = {p: compiler.level_schedule(fg, p)
                  for p in ("asap", "alap", "slack")}
        scheds["cost"] = compiler.level_schedule(fg, "cost",
                                                 node_times=times)
        occ = {p: compiler.engine_occupancy(fg, s)["occupancy"]
               for p, s in scheds.items()}
        unf, fus = compiler.launch_count(g), compiler.launch_count(fg)
        out[name] = {
            "launches_unfused": unf,
            "launches_fused": fus,
            "launch_reduction": 1.0 - fus / unf,
            "fused_ops": compiler.fusion_stats(fg)["fused_ops"],
            "occupancy": occ,
            "modeled_makespan_cost":
                scheds["cost"].stats.get("modeled_makespan", 0.0),
            "tw_occupancy_slack": compiler.time_weighted_occupancy(
                fg, scheds["slack"], times)["occupancy"],
            "tw_occupancy_cost": compiler.time_weighted_occupancy(
                fg, scheds["cost"], times)["occupancy"],
        }
    return out


def bench_payload(fleet=None, trace=None, stats=None, fr=None, zoo=None):
    """The machine-readable serving snapshot written to BENCH_serve.json:
    ops/s, fill rate, launches-per-image fused vs unfused, occupancy --
    the per-PR perf trajectory record.  Pass precomputed stats/fr/zoo to
    avoid re-serving the trace or re-sweeping the zoo."""
    fleet = _build_fleet() if fleet is None else fleet
    trace = _trace() if trace is None else trace
    if stats is None:
        stats = serve_stats(wave_batch=False, fleet=fleet, trace=trace)
    if fr is None:
        fr = fill_rate_stats(fleet=fleet, trace=trace)
    if zoo is None:
        zoo = zoo_fusion_occupancy()
    return {
        "trace": {"models": [cfg.name for cfg, _, _ in fleet],
                  "requests": len(trace),
                  "wave_size": WAVE, "input_hw": SERVE_HW},
        "ops_per_s": stats["requests_per_s"],
        "wall_s": stats["wall_s"],
        "latency_ms": stats["latency_ms"],
        "cache_hit_rate": stats["cache_hit_rate"],
        "fill_rate": {"continuous": fr["continuous_fill_rate"],
                      "pad_and_mask": fr["baseline_fill_rate"]},
        "launches_per_image": stats["launches"],
        "occupancy": {
            "per_level_asap": stats["engine_occupancy"],
            "per_level_alap": stats["engine_occupancy_alap"],
            "per_level_slack": stats["engine_occupancy_slack"],
            "per_level_cost": stats["engine_occupancy_cost"],
            "time_weighted_asap": stats["tw_occupancy"],
            "time_weighted_slack": stats["tw_occupancy_slack"],
            "time_weighted_cost": stats["tw_occupancy_cost"],
        },
        "zoo": zoo,
    }, stats, fr


def write_bench_json(payload, path: str = BENCH_PATH) -> str:
    """Merge-write the snapshot: top-level keys other writers own (e.g.
    serve_fleet's "fleet" block) survive a serve_cnn rewrite and vice
    versa, so the cross-PR trajectory file accretes instead of thrashing."""
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(payload)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def fast_payload():
    """--fast: a measured sub-10s trace subset that still emits the full
    BENCH_serve.json schema.  One model, short trace, ONE engine: the
    serve pass is flush-per-arrival (exactly the pad-and-mask baseline),
    then a second pump-per-arrival pass on the same warm engine measures
    continuous batching as scheduler-stat deltas -- no extra engines, so
    no re-tracing.  Printed to stdout; it does NOT overwrite the snapshot
    the full run records."""
    from repro.core import engine as eng_lib
    from repro.serve.cnn_engine import CNNServeEngine

    fleet = _build_fleet(models=[fast_cfg()])
    rng = np.random.default_rng(0)
    cfg0 = fleet[0][0]
    trace = [(cfg0.name,
              rng.normal(size=(cfg0.input_hw, cfg0.input_hw, 3)
                         ).astype(np.float32)) for _ in range(FAST_LEN)]
    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE,
                            cache_capacity=len(fleet) + 1)
    wall = _serve_trace(engine, fleet, trace)     # pad-and-mask arrivals
    stats = engine.stats()
    stats["wall_s"] = wall
    stats["requests_per_s"] = len(trace) / wall if wall > 0 else 0.0
    base_fill = stats["wave_fill_rate"]
    base_waves = stats["waves"]
    # continuous pass on the same warm engine: full waves only, deltas
    s = engine._sched.stats
    d0, p0, r0, x0 = s.dispatched, s.padded_slots, s.refilled_waves, \
        engine.wave_stats.program_execs
    for name, img in trace:
        engine.submit(name, img)
        engine.pump()
    engine.flush()
    slots = (s.dispatched - d0) + (s.padded_slots - p0)
    fr = {
        "baseline_fill_rate": base_fill,
        "continuous_fill_rate": (s.dispatched - d0) / slots if slots else 0.0,
        "baseline_waves": base_waves,
        "continuous_waves": engine.wave_stats.waves - base_waves,
        "refilled_waves": s.refilled_waves - r0,
        "program_execs": engine.wave_stats.program_execs - x0,
    }
    # occupancy / launch stats for the one traced model
    from repro import compiler
    cfg = fleet[0][0]
    program = engine.program_for(cfg.name)
    g = program.graph
    unfused = compiler.build_graph(cfg)
    times = pm.cnn_node_times(g, cfg)
    slack = compiler.level_schedule(g, "slack")
    alap = compiler.level_schedule(g, "alap")
    cost = compiler.level_schedule(g, "cost", node_times=times)
    fs = compiler.fusion_stats(g)
    stats["engine_occupancy"] = compiler.engine_occupancy(
        g, program.schedule)["occupancy"]
    stats["engine_occupancy_alap"] = compiler.engine_occupancy(
        g, alap)["occupancy"]
    stats["engine_occupancy_slack"] = compiler.engine_occupancy(
        g, slack)["occupancy"]
    stats["engine_occupancy_cost"] = compiler.engine_occupancy(
        g, cost)["occupancy"]
    stats["tw_occupancy"] = compiler.time_weighted_occupancy(
        g, program.schedule, times)["occupancy"]
    stats["tw_occupancy_slack"] = compiler.time_weighted_occupancy(
        g, slack, times)["occupancy"]
    stats["tw_occupancy_cost"] = compiler.time_weighted_occupancy(
        g, cost, times)["occupancy"]
    stats["launches"] = {cfg.name: {
        "unfused": compiler.launch_count(unfused),
        "fused": fs["launches"],
        "fused_ops": fs["fused_ops"],
        "materialized_edges": fs["materialized_edges"],
        "materialized_unfused":
            compiler.fusion_stats(unfused)["materialized_edges"],
    }}
    zoo = zoo_fusion_occupancy()
    payload, _, _ = bench_payload(fleet=fleet, trace=trace, stats=stats,
                                  fr=fr, zoo=zoo)
    payload["fast"] = True
    return payload


def summary_line() -> str:
    payload, stats, fr = bench_payload()
    path = write_bench_json(payload)
    rn = stats["launches"].get("resnet50")
    fused_part = ""
    if rn:
        drop = 1.0 - rn["fused"] / rn["unfused"]
        fused_part = (f"fused launches/img resnet50 {rn['fused']} vs "
                      f"{rn['unfused']} unfused (-{100 * drop:.0f}%); ")
    return (f"program-cache hit-rate: {100 * stats['cache_hit_rate']:.1f}% "
            f"({stats['cache_hits']}/{stats['cache_hits'] + stats['cache_misses']} hits, "
            f"{stats['cache_misses']} compiles over {stats['requests']} "
            f"requests, {len(TRACE_MODELS)} models); "
            f"{fused_part}"
            f"per-level engine occupancy "
            f"{100 * stats['engine_occupancy']:.1f}% asap / "
            f"{100 * stats['engine_occupancy_alap']:.1f}% alap / "
            f"{100 * stats['engine_occupancy_slack']:.1f}% slack / "
            f"{100 * stats['engine_occupancy_cost']:.1f}% cost "
            f"(time-weighted {100 * stats['tw_occupancy']:.1f}% asap -> "
            f"{100 * stats['tw_occupancy_slack']:.1f}% slack -> "
            f"{100 * stats['tw_occupancy_cost']:.1f}% cost); "
            f"wave fill-rate {100 * fr['continuous_fill_rate']:.1f}% "
            f"continuous vs {100 * fr['baseline_fill_rate']:.1f}% "
            f"pad-and-mask; BENCH_serve.json: {path}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", action="store_true",
                    help="one-line program-cache hit-rate only")
    ap.add_argument("--fast", action="store_true",
                    help="measured sub-10s trace subset; prints the "
                         "BENCH_serve.json schema to stdout")
    args = ap.parse_args()
    if args.summary:
        print(summary_line())
    elif args.fast:
        print(json.dumps(fast_payload(), indent=2, sort_keys=True))
    else:
        print("name,us_per_call,derived")
        for row_name, us, derived in run(measure=True):
            print(f"{row_name},{us:.1f},{derived}")
