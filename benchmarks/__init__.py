"""Benchmark harness: one module per paper table/figure.

  table1_dse       -- Table I  (DSE reuse requirements)
  table2_resources -- Table II (resource budget analog: VMEM/MXU per engine)
  table3_e2e       -- Table III (end-to-end CNN throughput + ratios)
  table4_mlperf    -- Table IV (ResNet50 latency/throughput + low-channel)
  fig8_dwc         -- Fig. 8  (DWC CTC vs kernel/stride)
  roofline         -- EXPERIMENTS.md roofline table from dry-run artifacts

`python -m benchmarks.run` executes all and prints `name,us_per_call,derived`
CSV rows.
"""
