"""Analytic TPU-v5e performance model for the CNN zoo.

Mirrors the paper's own modeling methodology (Section IV-A/IV-C: per-engine
CTC analysis) with TPU constants.  Per layer:

    t = max(effective_ops / engine_peak, bytes / HBM_BW)

where effective_ops folds the utilization penalties the paper identifies:
  * standard conv on the Conv PE: MXU utilization from contraction/output
    channel alignment (DSE model);
  * depthwise conv on the DWC PE: VPU-bound (no MXU reduction available);
  * depthwise conv WITHOUT the DWC engine (XVDPU-analog baseline): dense
    diagonalized GEMM -> ops inflated by the channel count;
  * stage-0 conv with/without the Low-Channel unit: window folding vs raw
    IC=3 against the 128-deep MXU contraction.

The model returns per-image seconds; ratios between engine configs are the
reproduction of Table III/IV's ratio columns.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.compiler.schedule import CONV_PE, DWC_PE, LOW_CHANNEL, MISC
from repro.core import dse
from repro.core.config import CNNConfig

PEAK_INT8 = dse.PEAK_INT8_OPS      # MXU int8
PEAK_VPU = 5.0e12                  # VPU int ops/s (8x128 lanes, ~1 GHz, FMA)
HBM = dse.HBM_BW


@dataclass
class EngineModel:
    # dwc_mode: "engine" (DWC PE: tiled VPU + fused requant),
    #           "vpu"    (TPU-native XLA grouped conv: VPU, lower efficiency),
    #           "dense"  (XVDPU-analog: depthwise on the GEMM engine --
    #                     channel-diagonalized, ops x C inflation; this is
    #                     what our baseline code path actually executes)
    dwc_mode: str = "engine"
    use_low_channel: bool = True
    fused_epilogue: bool = True    # MISC on engine: no extra eltwise pass
    # static_act: calibrated static scales -> activations stay int8 between
    # engines (the compiled engine-program path).  False = the dynamic-f32
    # pipeline: every edge is carried at f32 and re-quantized per call (an
    # extra read-f32/write-int8 pass in front of every engine).
    static_act: bool = True

    @property
    def use_dwc_engine(self):
        return self.dwc_mode == "engine"

    @property
    def act_bytes(self) -> int:
        return 1 if self.static_act else 4


# Paper Section V-B: measured Conv-PE utilization on ResNet50 stage 0.  Used
# as the stage-0 utilization of the no-low-channel-unit baseline (the
# XVDPU-analog); our unit reaches the window-folded MXU coverage instead.
STAGE0_BASELINE_UTIL = 0.131
VPU_NATIVE_EFF = 0.4               # XLA grouped-conv VPU efficiency


def _conv_time(px: int, ic: int, oc: int, k: int, eng: EngineModel,
               first_layer: bool = False) -> float:
    """One standard conv: px output pixels, k x k window."""
    ops = 2.0 * px * ic * oc * k * k
    # The engine always reads int8 (static edges, or the int8 the dynamic
    # requant pass just wrote); dynamic additionally pays that pass (read
    # f32 + write int8) and emits its output at f32.
    in_bytes = px * ic            # stride-adjusted approx
    w_bytes = k * k * ic * oc
    out_bytes = px * oc * eng.act_bytes
    # Both pipelines quantize the f32 input image once at the boundary;
    # only the dynamic pipeline repeats the pass at every layer.
    quant_bytes = (px * ic * 5
                   if (first_layer or not eng.static_act) else 0)
    if first_layer:
        if eng.use_low_channel:
            # window folding (contraction = ic*k*k) + concurrency: the unit
            # runs while the main engines proceed (paper Section V-B), so
            # only its memory traffic remains on the critical path.
            return (in_bytes + w_bytes + out_bytes + quant_bytes) / HBM
        util = STAGE0_BASELINE_UTIL
    else:
        util = dse.mxu_utilization(min(ic, 128), min(oc, 128), kk=1)
    util = max(util, 1e-3)
    t_compute = ops / (PEAK_INT8 * util)
    t_mem = (in_bytes + w_bytes + out_bytes + quant_bytes) / HBM
    if not eng.fused_epilogue:
        t_mem += 2.0 * px * oc * 4 / HBM       # i32 psum round-trip
    return max(t_compute, t_mem)


def _dwc_time(px: int, c: int, k: int, eng: EngineModel) -> float:
    ops = 2.0 * px * c * k * k
    # int8 engine read + act_bytes output write (see _conv_time)
    byts = px * c * (1 + eng.act_bytes) + k * k * c
    if not eng.static_act:
        byts += px * c * 5            # dynamic requant pass: read f32/write i8
    if eng.dwc_mode == "engine":
        t_compute = ops / PEAK_VPU
    elif eng.dwc_mode == "vpu":
        t_compute = ops / (PEAK_VPU * VPU_NATIVE_EFF)
    else:
        # "dense": diagonalized GEMM on the MXU (ops x C inflation,
        # utilization capped by the 128-lane contraction)
        dense_ops = 2.0 * px * c * c * k * k
        util = dse.mxu_utilization(min(c, 128), min(c, 128))
        t_compute = dense_ops / (PEAK_INT8 * max(util, 1e-3))
        byts += k * k * c * c                  # dense weight reads
    t_mem = byts / HBM
    if not eng.fused_epilogue:
        t_mem += 2.0 * px * c * 4 / HBM
    return max(t_compute, t_mem)


def _eltwise_time(px: int, c: int, eng: EngineModel) -> float:
    if eng.fused_epilogue:
        return 0.0                 # fused into the producing kernel
    # separate read-read-write pass at the pipeline's activation width
    return 3.0 * px * c * eng.act_bytes / HBM


# Engine units for the overlap model come from the scheduler pass (ops on
# different units run concurrently in a pipelined steady state).  Unlike
# schedule.engine_unit -- which maps nodes structurally -- the assignment
# here is gated by the EngineModel's feature set: a disabled Low-Channel
# unit or a diagonalized DWC falls back onto the Conv PE and contends there.

def _dwc_unit(eng: EngineModel) -> str:
    # "dense" diagonalizes the depthwise conv onto the GEMM engine, so it
    # contends with standard convs; "engine"/"vpu" run on the VPU datapath.
    return CONV_PE if eng.dwc_mode == "dense" else DWC_PE


def _layer_contribs(cfg: CNNConfig, eng: EngineModel):
    """Yield (engine_unit, seconds) per layer -- the walk behind both the
    sequential time (sum) and the overlap model (per-unit sums)."""
    hw = cfg.input_hw
    hw_out = -(-hw // cfg.stem_stride)
    yield (LOW_CHANNEL if eng.use_low_channel else CONV_PE,
           _conv_time(hw_out * hw_out, cfg.input_ch, cfg.stem_ch,
                      cfg.stem_kernel, eng, first_layer=True))
    hw, ch = hw_out, cfg.stem_ch
    for st in cfg.stages:
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            if st.kind == "pool":
                stride = 1                  # pool handled below
            hw_out = -(-hw // stride)
            px = hw_out * hw_out
            if st.kind == "conv":
                yield CONV_PE, _conv_time(px, ch, st.out_ch, st.kernel, eng)
                ch = st.out_ch
            elif st.kind == "bottleneck":
                mid = st.out_ch // 4
                yield CONV_PE, _conv_time(px, ch, mid, 1, eng)
                yield CONV_PE, _conv_time(px, mid, mid, st.kernel, eng)
                yield CONV_PE, _conv_time(px, mid, st.out_ch, 1, eng)
                if ch != st.out_ch or stride != 1:
                    yield CONV_PE, _conv_time(px, ch, st.out_ch, 1, eng)
                yield MISC, _eltwise_time(px, st.out_ch, eng)
                ch = st.out_ch
            elif st.kind == "inverted":
                mid = ch * st.expand
                yield CONV_PE, _conv_time(px, ch, mid, 1, eng)
                yield _dwc_unit(eng), _dwc_time(px, mid, st.kernel, eng)
                yield CONV_PE, _conv_time(px, mid, st.out_ch, 1, eng)
                yield MISC, _eltwise_time(px, st.out_ch, eng)
                ch = st.out_ch
            elif st.kind == "dwsep":
                yield _dwc_unit(eng), _dwc_time(px, ch, st.kernel, eng)
                yield CONV_PE, _conv_time(px, ch, st.out_ch, 1, eng)
                ch = st.out_ch
            elif st.kind == "fire":
                sq = st.out_ch // 8
                yield CONV_PE, _conv_time(px, ch, sq, 1, eng)
                yield CONV_PE, _conv_time(px, sq, st.out_ch // 2, 1, eng)
                yield CONV_PE, _conv_time(px, sq, st.out_ch // 2, 3, eng)
                ch = st.out_ch
            hw = hw_out
            if st.kind == "pool":
                hw = -(-hw // st.stride)
    yield CONV_PE, 2.0 * ch * cfg.num_classes / PEAK_INT8


def model_inference_time(cfg: CNNConfig, eng: EngineModel) -> float:
    """Seconds per image on one v5e chip (engines strictly sequential)."""
    return sum(t for _, t in _layer_contribs(cfg, eng))


def model_engine_times(cfg: CNNConfig, eng: EngineModel) -> dict:
    """Per-engine-unit busy seconds per image."""
    out: dict = {}
    for unit, t in _layer_contribs(cfg, eng):
        out[unit] = out.get(unit, 0.0) + t
    return out


def model_overlap_time(cfg: CNNConfig, eng: EngineModel) -> float:
    """Steady-state seconds per image with the engines pipelined.

    With requests streaming through (the serving waves of
    serve/cnn_engine.py), each engine unit works on a different image's
    layers concurrently -- the way the paper's Low-Channel unit already runs
    alongside the Conv PEs -- so throughput is set by the busiest unit, not
    the sum over units."""
    return max(model_engine_times(cfg, eng).values())


def overlap_credit(cfg: CNNConfig, eng: EngineModel) -> float:
    """Throughput multiplier the concurrent schedule buys (>= 1)."""
    return model_inference_time(cfg, eng) / model_overlap_time(cfg, eng)


def modeled_fps(cfg: CNNConfig, eng: EngineModel) -> float:
    return 1.0 / model_inference_time(cfg, eng)


def modeled_fps_pipelined(cfg: CNNConfig, eng: EngineModel) -> float:
    return 1.0 / model_overlap_time(cfg, eng)


# ---------------------------------------------------------------------------
# CNN program node times: the GRAPH walk (prices fused programs)
# ---------------------------------------------------------------------------

def _shape_of(schema, path):
    from repro.compiler import get_param
    return get_param(schema, path).shape


def cnn_node_times(graph, cfg: CNNConfig, eng: EngineModel = None) -> dict:
    """Modeled seconds per op of a CNN program graph ({node_id: seconds}).

    Unlike `_layer_contribs` -- which walks the CNNConfig and therefore
    always prices the UNFUSED op list -- this walks the compiled graph
    itself, so epilogue-fused programs are priced as what they execute: a
    fused node costs its conv/dwc launch plus the residual operand read,
    while the absorbed MISC add/pool passes (their read-read-write HBM
    traffic) disappear.  Feeds compiler.time_weighted_occupancy, which is
    what `serve_cnn --summary` reports for the fused graph.

    Channel/spatial shapes come from the model schema (cnn_schema) + stride
    propagation, so the walk needs no parameter values.
    """
    from repro.compiler import graph as G
    from repro.models.cnn import cnn_schema

    eng = eng or OURS
    schema = cnn_schema(cfg)
    hw: dict = {}
    ch: dict = {}
    out: dict = {}
    for n in graph.nodes:
        if isinstance(n, G.InputOp):
            hw[n.id], ch[n.id] = cfg.input_hw, cfg.input_ch
            out[n.id] = 0.0
            continue
        src = n.inputs[0] if n.inputs else None
        if isinstance(n, G.ConvOp):
            k, _, ic, oc = _shape_of(schema, n.w)
            h = -(-hw[src] // n.stride)
            px = h * h
            t = _conv_time(px, ic, oc, k, eng, first_layer=n.first_layer)
            ep = n.epilogue
            if ep is not None and ep.add:
                t += px * oc * eng.act_bytes / HBM     # residual operand read
            hw[n.id], ch[n.id] = h, oc
            if ep is not None and ep.pool != "none":
                hw[n.id] = _pool_hw(h, ep.pool, ep.pool_kernel,
                                    ep.pool_stride)
            out[n.id] = t
        elif isinstance(n, G.DwcOp):
            k, _, c = _shape_of(schema, n.w)
            h = -(-hw[src] // n.stride)
            px = h * h
            t = _dwc_time(px, c, k, eng)
            ep = n.epilogue
            if ep is not None and ep.add:
                t += px * c * eng.act_bytes / HBM
            hw[n.id], ch[n.id] = h, c
            if ep is not None and ep.pool != "none":
                hw[n.id] = _pool_hw(h, ep.pool, ep.pool_kernel,
                                    ep.pool_stride)
            out[n.id] = t
        elif isinstance(n, G.AddOp):
            px = hw[src] * hw[src]
            c = ch[src]
            # a standalone MISC add is a read-read-write pass at the
            # pipeline's activation width (what fusion eliminates)
            out[n.id] = 3.0 * px * c * eng.act_bytes / HBM
            hw[n.id], ch[n.id] = hw[src], c
        elif isinstance(n, G.PoolOp):
            h_out = _pool_hw(hw[src], n.pool, n.kernel, n.stride)
            c = ch[src]
            out[n.id] = ((hw[src] * hw[src] + h_out * h_out)
                         * c * eng.act_bytes / HBM)
            hw[n.id], ch[n.id] = h_out, c
        elif isinstance(n, G.ConcatOp):
            hw[n.id] = hw[src]
            ch[n.id] = sum(ch[i] for i in n.inputs)
            out[n.id] = 0.0                    # bank interleave
        elif isinstance(n, G.LinearOp):
            ci, co = _shape_of(schema, n.w)
            out[n.id] = 2.0 * ci * co / PEAK_INT8
            hw[n.id], ch[n.id] = 1, co
        else:
            out[n.id] = 0.0
            hw[n.id], ch[n.id] = hw.get(src, 1), ch.get(src, 1)
    return out


def _pool_hw(h: int, pool: str, k: int, stride: int) -> int:
    """VALID-window output size -- the math the executor and the fused
    kernels actually run (kernels/_epilogue.pooled_hw)."""
    if pool == "global":
        return 1
    return max((h - k) // max(stride, 1) + 1, 1)


def cnn_busy_fractions(cfg: CNNConfig, eng: EngineModel = None,
                       policy: str = "asap", fuse: bool = True) -> dict:
    """Time-weighted per-engine busy fractions of a CNN program graph
    (compiler.time_weighted_occupancy over cnn_node_times) -- structural,
    no execution."""
    from repro import compiler

    g = compiler.build_graph(cfg)
    if fuse:
        g, _ = compiler.fuse_epilogues(g)
    sched = compiler.level_schedule(g, policy)
    times = cnn_node_times(g, cfg, eng)
    return compiler.time_weighted_occupancy(g, sched, times)


# ---------------------------------------------------------------------------
# LM program node times (time-weighted busy fractions for serve_lm)
# ---------------------------------------------------------------------------

PEAK_F32_VPU = PEAK_VPU / 4            # f32 VPU ops/s (MISC float domain)


def _gemm_time(m: int, k: int, n: int, act_bytes: int = 1) -> float:
    """One int8 Conv-PE GEMM: [m, k] @ [k, n]."""
    ops = 2.0 * m * k * n
    util = max(dse.mxu_utilization(min(k, 128), min(n, 128)), 1e-3)
    byts = m * k * act_bytes + k * n + m * n * act_bytes
    return max(ops / (PEAK_INT8 * util), byts / HBM)


def _eltwise_f32_time(elems: int, n_in: int = 1) -> float:
    """A MISC-core f32 elementwise pass: n_in reads + 1 write."""
    return (n_in + 1) * elems * 4 / HBM


def lm_node_times(graph, arch, batch: int, seq: int,
                  cache_len: int = 0) -> dict:
    """Modeled seconds per op of an LM program graph.

    `seq` is the query length (1 for a DecodeStep program); `cache_len` the
    ACTUAL cached length attention reads for decode (the slots' mean
    position, NOT max_seq -- pricing update-mode by the worst-case envelope
    overstated attention cost for short sequences).  Block-paged AttnOps
    (n.page_size > 0) round that span up to a page multiple: a request
    occupies -- and the gather moves -- whole blocks.  Feeds
    compiler.time_weighted_occupancy: per-engine busy fractions weighted by
    modeled time, not per-level presence -- the ROADMAP's missing LM cost
    model.  Linear dims come from the param-path suffix the lowering wrote
    (wq/wk/wv/wo/wg/wu/wd), so the same walk prices prefill and decode.
    """
    from repro.compiler import graph as G

    d, ff, v = arch.d_model, arch.d_ff, arch.vocab_size
    nh, nkv, hd = arch.n_heads, arch.n_kv_heads, arch.head_dim
    span = cache_len if cache_len else seq
    m = batch * seq
    dims = {"wq": (d, nh * hd), "wk": (d, nkv * hd), "wv": (d, nkv * hd),
            "wo": (nh * hd, d), "wg": (d, ff), "wu": (d, ff), "wd": (ff, d)}
    out: dict = {}
    for n in graph.nodes:
        if isinstance(n, G.LinearGroupOp):
            # One fused launch over the N-concatenated members: same MACs
            # and A-read as the members, one A-fetch instead of len(ws)
            kns = [dims.get(p[-1] if p else "", (d, d)) for p in n.ws]
            out[n.id] = _gemm_time(m, kns[0][0], sum(kn[1] for kn in kns))
        elif isinstance(n, G.LinearOp):
            kn = dims.get(n.w[-1] if n.w else "", (d, d))
            out[n.id] = _gemm_time(m, *kn)
        elif isinstance(n, G.HeadOp):
            rows = batch * (1 if n.last_only else seq)
            out[n.id] = _gemm_time(rows, d, v, act_bytes=4)
        elif isinstance(n, G.AttnOp):
            aspan = span
            if n.mode == "update" and n.page_size:
                aspan = -(-aspan // n.page_size) * n.page_size
            window = min(n.window, aspan) if n.window else aspan
            flops = 4.0 * batch * seq * window * nh * hd    # qk + pv
            byts = (2 * batch * window * nkv * hd * 2        # kv reads (bf16)
                    + 3 * m * nh * hd * 4)                   # q in, ctx out
            out[n.id] = max(flops / PEAK_F32_VPU, byts / HBM)
        elif isinstance(n, (G.NormOp, G.MulOp, G.AddOp)):
            out[n.id] = _eltwise_f32_time(m * d, n_in=len(n.inputs))
        elif isinstance(n, G.EmbedOp):
            out[n.id] = m * d * 4 / HBM                      # row gather
        else:                                               # InputOp etc.
            out[n.id] = 0.0
    return out


def lm_busy_fractions(arch, batch: int = 1, seq: int = 128,
                      mode: str = "prefill", cache_len: int = 0,
                      policy: str = "asap", page_size: int = 0) -> dict:
    """Time-weighted per-engine busy fractions of a compiled LM program
    (compiler.time_weighted_occupancy over lm_node_times).  `page_size`
    (decode only) prices the block-paged DecodeStep variant."""
    from repro import compiler

    prog = compiler.compile_lm(arch, mode=mode, policy=policy,
                               page_size=page_size if mode == "decode"
                               else 0)
    qseq = 1 if mode == "decode" else seq
    times = lm_node_times(prog.graph, arch, batch, qseq,
                          cache_len=cache_len or seq)
    return compiler.time_weighted_occupancy(prog.graph, prog.schedule, times)


OURS = EngineModel()                       # compiled static-int8 pipeline
# Same engines, but the eager dynamic-f32 pipeline: every edge round-trips
# through f32 with a per-call requant pass (what cnn_forward without a
# calibrated program executes).
OURS_DYNAMIC = EngineModel(static_act=False)
# XVDPU-analog: dense-diag DWC, no low-channel unit, unfused epilogues.
# Stays static_act=True -- the paper's comparison DPU is also instruction-
# driven with Vitis-AI static scales, so Table III ratios isolate the
# engine features; the static-vs-dynamic pipeline gap is OURS_DYNAMIC's job.
BASELINE = EngineModel(dwc_mode="dense", use_low_channel=False,
                       fused_epilogue=False)
# TPU-native middle baseline: XLA grouped conv on the VPU, still no unit
# or fusion -- the fairest "what you'd get without this framework" line.
TPU_NATIVE = EngineModel(dwc_mode="vpu", use_low_channel=False,
                         fused_epilogue=False)
NO_LOWPE = EngineModel(use_low_channel=False)
NO_DWC = EngineModel(dwc_mode="dense")
