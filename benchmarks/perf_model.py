"""Analytic TPU-v5e performance model for the CNN zoo.

Mirrors the paper's own modeling methodology (Section IV-A/IV-C: per-engine
CTC analysis) with TPU constants.  Per layer:

    t = max(effective_ops / engine_peak, bytes / HBM_BW)

where effective_ops folds the utilization penalties the paper identifies:
  * standard conv on the Conv PE: MXU utilization from contraction/output
    channel alignment (DSE model);
  * depthwise conv on the DWC PE: VPU-bound (no MXU reduction available);
  * depthwise conv WITHOUT the DWC engine (XVDPU-analog baseline): dense
    diagonalized GEMM -> ops inflated by the channel count;
  * stage-0 conv with/without the Low-Channel unit: window folding vs raw
    IC=3 against the 128-deep MXU contraction.

The model returns per-image seconds; ratios between engine configs are the
reproduction of Table III/IV's ratio columns.

The tile pricing itself lives in `repro.compiler.cost` (it also drives the
cost-aware scheduler: level_schedule(policy="cost") and merge_schedules);
this module re-exports it and keeps the CNNConfig-walking Table-III/IV
models on top.
"""
from __future__ import annotations

# Re-exported pricing core (moved to the compiler so the scheduler can be
# cost-driven; every existing `pm.` consumer keeps working).
from repro.compiler.cost import (BASELINE, HBM, NO_DWC, NO_LOWPE, OURS,
                                 OURS_DYNAMIC, PEAK_F32_VPU, PEAK_INT8,
                                 PEAK_VPU, STAGE0_BASELINE_UTIL, TPU_NATIVE,
                                 VPU_NATIVE_EFF, EngineModel, _conv_time,
                                 _dwc_time, _eltwise_f32_time, _eltwise_time,
                                 _gemm_time, _pool_hw, cnn_node_times,
                                 default_node_times, lm_node_times)
from repro.compiler.schedule import CONV_PE, DWC_PE, LOW_CHANNEL, MISC
from repro.core.config import CNNConfig

__all__ = [
    "BASELINE", "EngineModel", "HBM", "NO_DWC", "NO_LOWPE", "OURS",
    "OURS_DYNAMIC", "PEAK_F32_VPU", "PEAK_INT8", "PEAK_VPU", "TPU_NATIVE",
    "cnn_busy_fractions", "cnn_node_times", "default_node_times",
    "lm_busy_fractions", "lm_node_times", "model_engine_times",
    "model_inference_time", "model_overlap_time", "modeled_fps",
    "modeled_fps_pipelined", "overlap_credit",
]


# Engine units for the overlap model come from the scheduler pass (ops on
# different units run concurrently in a pipelined steady state).  Unlike
# schedule.engine_unit -- which maps nodes structurally -- the assignment
# here is gated by the EngineModel's feature set: a disabled Low-Channel
# unit or a diagonalized DWC falls back onto the Conv PE and contends there.

def _dwc_unit(eng: EngineModel) -> str:
    # "dense" diagonalizes the depthwise conv onto the GEMM engine, so it
    # contends with standard convs; "engine"/"vpu" run on the VPU datapath.
    return CONV_PE if eng.dwc_mode == "dense" else DWC_PE


def _layer_contribs(cfg: CNNConfig, eng: EngineModel):
    """Yield (engine_unit, seconds) per layer -- the walk behind both the
    sequential time (sum) and the overlap model (per-unit sums)."""
    hw = cfg.input_hw
    hw_out = -(-hw // cfg.stem_stride)
    yield (LOW_CHANNEL if eng.use_low_channel else CONV_PE,
           _conv_time(hw_out * hw_out, cfg.input_ch, cfg.stem_ch,
                      cfg.stem_kernel, eng, first_layer=True))
    hw, ch = hw_out, cfg.stem_ch
    for st in cfg.stages:
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            if st.kind == "pool":
                stride = 1                  # pool handled below
            hw_out = -(-hw // stride)
            px = hw_out * hw_out
            if st.kind == "conv":
                yield CONV_PE, _conv_time(px, ch, st.out_ch, st.kernel, eng)
                ch = st.out_ch
            elif st.kind == "bottleneck":
                mid = st.out_ch // 4
                yield CONV_PE, _conv_time(px, ch, mid, 1, eng)
                yield CONV_PE, _conv_time(px, mid, mid, st.kernel, eng)
                yield CONV_PE, _conv_time(px, mid, st.out_ch, 1, eng)
                if ch != st.out_ch or stride != 1:
                    yield CONV_PE, _conv_time(px, ch, st.out_ch, 1, eng)
                yield MISC, _eltwise_time(px, st.out_ch, eng)
                ch = st.out_ch
            elif st.kind == "inverted":
                mid = ch * st.expand
                yield CONV_PE, _conv_time(px, ch, mid, 1, eng)
                yield _dwc_unit(eng), _dwc_time(px, mid, st.kernel, eng)
                yield CONV_PE, _conv_time(px, mid, st.out_ch, 1, eng)
                yield MISC, _eltwise_time(px, st.out_ch, eng)
                ch = st.out_ch
            elif st.kind == "dwsep":
                yield _dwc_unit(eng), _dwc_time(px, ch, st.kernel, eng)
                yield CONV_PE, _conv_time(px, ch, st.out_ch, 1, eng)
                ch = st.out_ch
            elif st.kind == "fire":
                sq = st.out_ch // 8
                yield CONV_PE, _conv_time(px, ch, sq, 1, eng)
                yield CONV_PE, _conv_time(px, sq, st.out_ch // 2, 1, eng)
                yield CONV_PE, _conv_time(px, sq, st.out_ch // 2, 3, eng)
                ch = st.out_ch
            hw = hw_out
            if st.kind == "pool":
                hw = -(-hw // st.stride)
    yield CONV_PE, 2.0 * ch * cfg.num_classes / PEAK_INT8


def model_inference_time(cfg: CNNConfig, eng: EngineModel) -> float:
    """Seconds per image on one v5e chip (engines strictly sequential)."""
    return sum(t for _, t in _layer_contribs(cfg, eng))


def model_engine_times(cfg: CNNConfig, eng: EngineModel) -> dict:
    """Per-engine-unit busy seconds per image."""
    out: dict = {}
    for unit, t in _layer_contribs(cfg, eng):
        out[unit] = out.get(unit, 0.0) + t
    return out


def model_overlap_time(cfg: CNNConfig, eng: EngineModel) -> float:
    """Steady-state seconds per image with the engines pipelined.

    With requests streaming through (the serving waves of
    serve/cnn_engine.py), each engine unit works on a different image's
    layers concurrently -- the way the paper's Low-Channel unit already runs
    alongside the Conv PEs -- so throughput is set by the busiest unit, not
    the sum over units."""
    return max(model_engine_times(cfg, eng).values())


def overlap_credit(cfg: CNNConfig, eng: EngineModel) -> float:
    """Throughput multiplier the concurrent schedule buys (>= 1)."""
    return model_inference_time(cfg, eng) / model_overlap_time(cfg, eng)


def modeled_fps(cfg: CNNConfig, eng: EngineModel) -> float:
    return 1.0 / model_inference_time(cfg, eng)


def modeled_fps_pipelined(cfg: CNNConfig, eng: EngineModel) -> float:
    return 1.0 / model_overlap_time(cfg, eng)


def cnn_busy_fractions(cfg: CNNConfig, eng: EngineModel = None,
                       policy: str = "asap", fuse: bool = True) -> dict:
    """Time-weighted per-engine busy fractions of a CNN program graph
    (compiler.time_weighted_occupancy over cnn_node_times) -- structural,
    no execution."""
    from repro import compiler

    g = compiler.build_graph(cfg)
    if fuse:
        g, _ = compiler.fuse_epilogues(g)
    times = cnn_node_times(g, cfg, eng)
    sched = compiler.level_schedule(g, policy, node_times=times)
    return compiler.time_weighted_occupancy(g, sched, times)


def lm_busy_fractions(arch, batch: int = 1, seq: int = 128,
                      mode: str = "prefill", cache_len: int = 0,
                      policy: str = "asap", page_size: int = 0) -> dict:
    """Time-weighted per-engine busy fractions of a compiled LM program
    (compiler.time_weighted_occupancy over lm_node_times).  `page_size`
    (decode only) prices the block-paged DecodeStep variant."""
    from repro import compiler

    prog = compiler.compile_lm(arch, mode=mode, policy=policy,
                               page_size=page_size if mode == "decode"
                               else 0)
    qseq = 1 if mode == "decode" else seq
    times = lm_node_times(prog.graph, arch, batch, qseq,
                          cache_len=cache_len or seq)
    return compiler.time_weighted_occupancy(prog.graph, prog.schedule, times)
