"""Multi-tenant co-serving: CNN waves + LM decode bursts on one fabric.

Evidence lines for the cost-driven scheduler + fabric interleaver
(compiler/schedule.py::merge_schedules, executor.execute_interleaved,
serve/base.py::FabricPump):

  * MEASURED co-tenancy: the same CNN image trace and LM prompt trace
    served through the FabricPump twice at EQUAL WORK -- interleaved (each
    fabric tick is ONE fused jitted call executing a CNN wave's levels
    zipped with an LM decode step's) vs serialized (the same tick issues
    the two programs as separate dispatches).  Reported: wall-clock,
    ops/s (CNN images), tokens/s (LM), per-request p50/p99.
  * BIT-IDENTITY on the measured path: CNN logits and LM token ids of
    both legs are asserted identical to each other and to isolated
    per-engine execution.
  * STRUCTURAL zoo sweep: per zoo model, the merged-schedule occupancy of
    the cost DP alignment vs the naive in-order (asap) zip against the LM
    DecodeStep program -- the `policy="cost"` time-weighted occupancy win
    the count-based slack leveling could never show.

    PYTHONPATH=src python -m benchmarks.serve_mixed [--summary|--fast]

--summary merges the "mixed" block into BENCH_serve.json and prints the
one-liner; --fast runs a smaller trace with the same schema.
"""
import time

import numpy as np

from benchmarks import perf_model as pm
from benchmarks.serve_cnn import (SERVE_HW, WAVE, _build_fleet, _reduced,
                                  write_bench_json)
from repro.configs.cnn_zoo import CNN_ZOO

CNN_MODEL = "squeezenet"
LM_ARCH = "qwen2-1.5b"
# 32 images = 8 waves of WAVE=4 next to 4 prompts x 8 tokens in one
# batch-4 admission round = 8 decode ticks: every tick of the co-tenant
# trace has both a wave and a decode step to fuse
MIXED_IMAGES = 32
MIXED_PROMPTS = 4
PROMPT_LEN = 8
NEW_TOKENS = 8
LM_BATCH = 4
MAX_SEQ = 32
FAST_IMAGES = 16      # 4 waves, matching 4 prompts x 4 tokens / batch 4
FAST_PROMPTS = 4
FAST_NEW_TOKENS = 4
# wall-clock is min over REPS timed repeats of the identical workload
# (both legs, same protocol): the traces are tens of ms, so a single
# sample is scheduler noise
REPS = 5


def _tenants(seed=0, fast=False):
    """(cnn fleet entry, lm arch/params/calib, image trace, prompt trace)."""
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import transformer as T
    from repro.models.params import init_params

    (cfg, params, calib), = _build_fleet(seed=seed, models=[CNN_MODEL])
    arch = configs.reduced(configs.get_arch(LM_ARCH))
    lm_params = init_params(T.lm_schema(arch), jax.random.PRNGKey(7))
    rng = np.random.default_rng(seed)
    lm_calib = [jnp.array(rng.integers(0, arch.vocab_size, (2, PROMPT_LEN))
                          .astype(np.int32))]
    n_img = FAST_IMAGES if fast else MIXED_IMAGES
    n_prm = FAST_PROMPTS if fast else MIXED_PROMPTS
    images = [rng.normal(size=(cfg.input_hw, cfg.input_hw, cfg.input_ch)
                         ).astype(np.float32) for _ in range(n_img)]
    prompts = [rng.integers(0, arch.vocab_size, size=PROMPT_LEN)
               .astype(np.int32) for _ in range(n_prm)]
    return (cfg, params, calib), (arch, lm_params, lm_calib), images, prompts


def _build_pump(cnn_entry, lm_entry, interleave: bool, merge_policy="cost"):
    from repro.core import engine as eng_lib
    from repro.core.config import EngineConfig
    from repro.serve.base import FabricPump
    from repro.serve.cnn_engine import CNNServeEngine
    from repro.serve.engine import ServeEngine

    cfg, params, calib = cnn_entry
    arch, lm_params, lm_calib = lm_entry
    cnn = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE)
    cnn.register(cfg, params, calib_batches=[calib])
    lm = ServeEngine(arch, lm_params, EngineConfig(quant="w8a8",
                                                   backend="ref"),
                     batch_size=LM_BATCH, max_seq=MAX_SEQ,
                     calib_batches=lm_calib, prefill_len=PROMPT_LEN)
    return FabricPump(cnn, lm, merge_policy=merge_policy,
                      interleave=interleave)


def mixed_stats(fast: bool = False, seed: int = 0):
    """Serve the same two-tenant trace interleaved and serialized at equal
    work; assert output bit-identity against isolated engines; return the
    measured comparison (the BENCH "mixed" block's core)."""
    cnn_entry, lm_entry, images, prompts = _tenants(seed=seed, fast=fast)
    cfg = cnn_entry[0]
    new_tokens = FAST_NEW_TOKENS if fast else NEW_TOKENS

    def leg(interleave: bool):
        pump = _build_pump(cnn_entry, lm_entry, interleave)
        # warmup: the full workload once -- traces the prefill, decode,
        # fused-tick and solo-wave executables, then drop its clocks
        pump.run(cfg.name, images, prompts, max_new_tokens=new_tokens)
        pump.latency = pump.latency.__class__()
        pump.cnn.latency = pump.cnn.latency.__class__()
        pump.lm.latency = pump.lm.latency.__class__()
        ticks0 = pump.stats()["ticks"]
        fused0 = pump.stats()["fused_ticks"]
        walls = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            logits, tokens = pump.run(cfg.name, images, prompts,
                                      max_new_tokens=new_tokens)
            walls.append(time.perf_counter() - t0)
        wall = min(walls)
        return {
            "wall_s": wall,
            "wall_s_all": walls,
            "ops_per_s": len(images) / wall,
            "tokens_per_s": len(prompts) * new_tokens / wall,
            "latency_ms": pump.latency.percentiles(),
            "ticks": (pump.stats()["ticks"] - ticks0) // REPS,
            "fused_ticks": (pump.stats()["fused_ticks"] - fused0) // REPS,
        }, logits, tokens, pump

    inter, il_logits, il_tokens, pump = leg(True)
    serial, sr_logits, sr_tokens, _ = leg(False)

    # isolated execution: each engine alone, same requests
    iso = _build_pump(cnn_entry, lm_entry, interleave=True)
    iso_logits = [np.asarray(r) for r in
                  iso.cnn.infer(cfg.name, np.stack(images))]
    iso_tokens = list(iso.lm.generate(list(prompts),
                                      max_new_tokens=new_tokens))
    identical = True
    for a, b, c in zip(iso_logits, il_logits, sr_logits):
        identical &= bool(np.array_equal(a, b) and np.array_equal(a, c))
    for a, b, c in zip(iso_tokens, list(il_tokens.values()),
                       list(sr_tokens.values())):
        identical &= bool(np.array_equal(a, b) and np.array_equal(a, c))
    assert identical, "interleaved/serialized outputs diverged from isolated"

    merged = pump.stats().get("merged", {})
    return {
        "trace": {"cnn_model": cfg.name, "lm_arch": lm_entry[0].name,
                  "images": len(images), "prompts": len(prompts),
                  "new_tokens": new_tokens, "wave_size": WAVE,
                  "lm_batch": LM_BATCH, "input_hw": SERVE_HW},
        "interleaved": inter,
        "serialized": serial,
        "speedup": serial["wall_s"] / inter["wall_s"],
        "identical_outputs": identical,
        "merged_schedule": merged,
    }


def fabric_occupancy(lm_arch: str = LM_ARCH):
    """Structural zoo sweep: per CNN zoo model, the merged-schedule
    makespan + time-weighted occupancy of cost-DP alignment vs the naive
    in-order zip against the LM DecodeStep program.  The acceptance gate:
    cost occupancy strictly above asap's on >= 3 zoo models."""
    from repro import compiler, configs

    arch = configs.reduced(configs.get_arch(lm_arch))
    dec = compiler.compile_lm(arch, mode="decode")
    times_b = pm.lm_node_times(dec.graph, arch, LM_BATCH, 1,
                               cache_len=PROMPT_LEN + NEW_TOKENS // 2)
    out = {}
    for name in CNN_ZOO:
        cfg = _reduced(name)
        prog = compiler.compile_cnn(cfg, policy="cost")
        times_a = pm.cnn_node_times(prog.graph, cfg)
        occ = {}
        for policy in ("asap", "cost"):
            m = compiler.merge_schedules(prog.graph, prog.schedule,
                                         dec.graph, dec.schedule,
                                         times_a, times_b, policy=policy)
            occ[policy] = {"occupancy": m.stats["occupancy"],
                           "makespan": m.stats["makespan"],
                           "ticks": m.stats["ticks"]}
        out[name] = {
            "asap": occ["asap"]["occupancy"],
            "cost": occ["cost"]["occupancy"],
            "makespan_asap": occ["asap"]["makespan"],
            "makespan_cost": occ["cost"]["makespan"],
            "serialized_makespan": m.stats["serialized_makespan"],
            "cost_wins": occ["cost"]["occupancy"] > occ["asap"]["occupancy"],
        }
    return out


def bench_block(fast: bool = False):
    """The "mixed" block merged into BENCH_serve.json."""
    block = mixed_stats(fast=fast)
    fo = fabric_occupancy()
    block["fabric_occupancy"] = fo
    block["cost_beats_asap_models"] = sum(
        1 for v in fo.values() if v["cost_wins"])
    return block


def summary_line(fast: bool = False) -> str:
    block = bench_block(fast=fast)
    # the fast smoke rides its own key: it is a different trace shape, so
    # letting it overwrite "mixed" would make cross-run comparisons
    # (scripts/bench_guard.py) apples-to-oranges
    write_bench_json({"mixed_fast" if fast else "mixed": block})
    i, s = block["interleaved"], block["serialized"]
    wins = block["cost_beats_asap_models"]
    return (f"mixed co-tenancy ({block['trace']['cnn_model']}+"
            f"{block['trace']['lm_arch']}): interleaved "
            f"{i['ops_per_s']:.1f} img/s + {i['tokens_per_s']:.1f} tok/s "
            f"vs serialized {s['ops_per_s']:.1f} + {s['tokens_per_s']:.1f} "
            f"({block['speedup']:.2f}x wall), p99 "
            f"{i['latency_ms'].get('p99_ms', 0.0):.0f}ms vs "
            f"{s['latency_ms'].get('p99_ms', 0.0):.0f}ms, bit-identical "
            f"outputs={int(block['identical_outputs'])}; merged cost "
            f"occupancy beats asap zip on {wins}/{len(CNN_ZOO)} zoo models")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", action="store_true",
                    help="one-line co-tenancy summary; merges the 'mixed' "
                         "block into BENCH_serve.json")
    ap.add_argument("--fast", action="store_true",
                    help="smaller trace, same schema")
    args = ap.parse_args()
    if args.summary:
        print(summary_line(fast=args.fast))
    else:
        print(json.dumps(bench_block(fast=args.fast), indent=2,
                         sort_keys=True))
