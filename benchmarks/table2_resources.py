"""Table II analog: per-engine scarce-resource budget.

The paper's scarce resources are DSP/LUT/FF/BRAM slices; ours are VMEM bytes
(operand blocks + the PsumStack scratch), MXU lane occupancy, and the count
of epilogue passes eliminated by fusion (the analog of the 95.8% DSP saving:
every fused epilogue is an HBM round-trip that never becomes a separate op).
"""
import time

import numpy as np

from repro.core import dse
from repro.core.config import EngineConfig
from repro.kernels import ops


# Representative layers: (name, M, K, N) -- conv-as-GEMM shapes.
LAYERS = [
    ("resnet50_3x3_256", 3136, 2304, 256),
    ("resnet50_1x1_1024", 3136, 256, 1024),
    ("mobilenet_pw_512", 784, 512, 512),
    ("lm_qkv_4096", 4096, 4096, 6144),
    ("lm_ffn_14336", 4096, 4096, 14336),
]


def run():
    rows = []
    for name, m, k, n in LAYERS:
        t0 = time.perf_counter()
        t = dse.solve_conv_blocks(m, n, k, in_dtype_bytes=1)
        us = (time.perf_counter() - t0) * 1e6
        psum = t.bm * t.bn * 4
        operands = 2 * (t.bm * t.bk + t.bk * t.bn)
        rows.append((
            f"table2/conv_pe/{name}", us,
            f"blocks={t.bm}x{t.bn}x{t.bk},vmem={t.vmem_bytes}B,"
            f"psum={psum}B,operands={operands}B,"
            f"mxu_util={t.mxu_util:.2f},ctc={t.ctc:.2f}"))

    # DWC engine: VMEM per (batch, channel-block) cell.
    for hw, c in [(112, 128), (56, 128), (28, 128)]:
        in_bytes = (hw + 2) * (hw + 2) * 128       # int8 input tile + halo
        out_bytes = hw * hw * 128 * 4
        rows.append((
            f"table2/dwc_pe/{hw}x{hw}x{c}", 0.0,
            f"in_tile={in_bytes}B,acc={out_bytes}B,"
            f"fits_vmem={in_bytes + out_bytes <= dse.VMEM_TARGET}"))

    # Low-channel unit: stage-0 footprint.
    img = 230 * 230 * 4
    acc = 112 * 112 * 64 * 4
    rows.append((
        "table2/low_channel/resnet_stage0", 0.0,
        f"img={img}B,acc={acc}B,fits={img + acc <= dse.VMEM_TARGET},"
        f"util_folded={dse.mxu_utilization(3, 64, 49):.3f},"
        f"util_plain={dse.mxu_utilization(3, 64, 1):.4f}"))

    # Fusion savings (the DSP-saving analog): epilogue ops that never hit HBM
    # as separate passes, counted over ResNet50.
    n_convs = 53
    n_eltwise = 16
    saved = n_convs + n_eltwise        # bias/act fused + residual adds fused
    rows.append((
        "table2/fusion_savings/resnet50", 0.0,
        f"fused_epilogues={n_convs},fused_eltwise={n_eltwise},"
        f"separate_passes_eliminated={saved} (paper: DSP -95.8%)"))
    return rows
