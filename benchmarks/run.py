"""Run every benchmark; print ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--fast]

--fast skips the CPU wall-clock measurements (model-only rows).
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip CPU wall-clock measurements")
    args = ap.parse_args()

    from benchmarks import (fig8_dwc, pipeline_int8, roofline, serve_cnn,
                            serve_lm, table1_dse, table2_resources,
                            table3_e2e, table4_mlperf)

    suites = [
        ("table1", lambda: table1_dse.run()),
        ("table2", lambda: table2_resources.run()),
        ("table3", lambda: table3_e2e.run(measure=not args.fast)),
        ("table4", lambda: table4_mlperf.run()),
        ("fig8", lambda: fig8_dwc.run(measure=not args.fast)),
        ("pipeline", lambda: pipeline_int8.run(measure=not args.fast)),
        ("serve", lambda: serve_cnn.run(measure=not args.fast)),
        ("serve_lm", lambda: serve_lm.run(measure=not args.fast)),
        ("roofline", lambda: roofline.run()),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
