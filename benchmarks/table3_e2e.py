"""Table III reproduction: end-to-end CNN throughput, ours vs the
XVDPU-analog baseline.

Two evidence lines per model:
  * MODELED: the analytic TPU-v5e per-layer engine model (perf_model.py) --
    FPS for our engine config and the baseline config; `ratio` reproduces
    the paper's "Ratio" column (their 6PE+DWC / XVDPU).
  * MEASURED: CPU wall-clock of the actual jitted engine paths (quantized,
    ref backend) at reduced resolution on the DWC-heaviest and the
    conv-heaviest model -- relative speedups only (this container has no
    TPU); full-resolution measurement is a one-line change.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import perf_model as pm
from repro.configs.cnn_zoo import CNN_ZOO, PAPER_TABLE3
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import cnn
from repro.models.params import init_params

MEASURE = ("mobilenetv2", "squeezenet")     # DWC-heavy + conv-only
MEASURE_HW = 64                             # reduced input for CPU wall-clock


def _measure_cpu(cfg, eng: EngineConfig, reps: int = 3) -> float:
    import dataclasses
    cfg = dataclasses.replace(cfg, input_hw=MEASURE_HW)
    schema = cnn.cnn_schema(cfg)
    params = init_params(schema, jax.random.PRNGKey(0))
    qparams = eng_lib.quantize_params(params, eng)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, cfg.input_hw, cfg.input_hw, cfg.input_ch)).astype(np.float32))
    fwd = jax.jit(lambda p, x: cnn.cnn_forward(p, x, cfg, eng))
    fwd(qparams, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fwd(qparams, x).block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(measure: bool = True):
    rows = []
    for name, cfg in CNN_ZOO.items():
        ours = pm.modeled_fps(cfg, pm.OURS)
        base = pm.modeled_fps(cfg, pm.BASELINE)
        native = pm.modeled_fps(cfg, pm.TPU_NATIVE)
        paper = PAPER_TABLE3.get(name)
        dwc_frac = cnn.dwc_op_fraction(cfg)
        rows.append((
            f"table3/model/{name}", 0.0,
            f"modeled_fps={ours:.0f},xvdpu_analog_fps={base:.0f},"
            f"tpu_native_fps={native:.0f},"
            f"ratio_vs_analog={ours / base:.2f},"
            f"ratio_vs_native={ours / native:.2f},"
            f"paper_ratio={paper[5] if paper else 0},"
            f"dwc_frac={dwc_frac:.2f},gops={cfg.gops}"))
    if measure:
        for name in MEASURE:
            cfg = CNN_ZOO[name]
            eng_ours = EngineConfig(quant="w8a8", backend="ref")
            eng_base = EngineConfig(quant="w8a8", backend="ref",
                                    baseline=True).resolved()
            t_ours = _measure_cpu(cfg, eng_ours)
            t_base = _measure_cpu(cfg, eng_base)
            rows.append((
                f"table3/measured_cpu/{name}", t_ours * 1e6,
                f"ours={t_ours * 1e3:.1f}ms,baseline={t_base * 1e3:.1f}ms,"
                f"speedup={t_base / t_ours:.2f}x(hw={MEASURE_HW})"))
    # Trend check: the paper's key claim -- DWC-heavy models gain more.
    dwc_models = ["mobilenetv1", "mobilenetv2", "efficientnet", "yolov5n"]
    std_models = ["resnet50", "resnet152", "yolov3", "squeezenet"]
    def _avg(names):
        return float(np.mean([pm.modeled_fps(CNN_ZOO[n], pm.OURS)
                              / pm.modeled_fps(CNN_ZOO[n], pm.BASELINE)
                              for n in names]))
    rows.append((
        "table3/trend", 0.0,
        f"avg_ratio_dwc_models={_avg(dwc_models):.2f}(paper 1.78),"
        f"avg_ratio_std_models={_avg(std_models):.2f}(paper 1.26),"
        f"dwc_gain_larger={_avg(dwc_models) > _avg(std_models)}"))
    return rows
