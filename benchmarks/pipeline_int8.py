"""Dynamic-f32 vs static-int8 pipeline comparison.

The tentpole claim of the compiler layer: with calibrated static scales the
engine program keeps activations int8 edge-to-edge (requant fused into each
PE's NL/RACNL epilogue), while the eager path round-trips every edge through
f32 and re-quantizes per call.  Two evidence lines per model:

  * MODELED: the analytic per-layer engine model (perf_model.py) with
    `static_act` on vs off -- the memory-traffic ratio the fused requant
    buys on an HBM-bound pipeline.
  * MEASURED: CPU wall-clock of the jitted compiled static program vs the
    jitted eager dynamic path (ref backend, reduced resolution), plus the
    program's structural evidence: f32 round-trip edge counts from the
    requant-folding pass.  Note the CPU line under-sells the static path:
    this container emulates int8 MACs in f32, so the extra requant rounding
    costs cycles while the halved activation traffic (the thing the fused
    epilogue actually buys on HBM-bound hardware) is free here anyway.  The
    structural counts + the modeled line carry the hardware claim.
"""
import time

import numpy as np

from benchmarks import perf_model as pm
from repro.configs.cnn_zoo import CNN_ZOO

MEASURE = ("mobilenetv2", "resnet50")       # DWC-heavy + residual-heavy
MEASURE_HW = 32                             # reduced input for CPU wall-clock


def _measure_cpu(name: str, reps: int = 3):
    """Wall-clock eager-dynamic vs compiled-static on the ref backend."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro import compiler
    from repro.core import engine as eng_lib
    from repro.core.config import EngineConfig
    from repro.models import cnn
    from repro.models.params import init_params

    cfg = dataclasses.replace(CNN_ZOO[name], input_hw=MEASURE_HW)
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, cfg.input_hw, cfg.input_hw, cfg.input_ch)).astype(np.float32))
    eng = EngineConfig(quant="w8a8", backend="ref")
    qparams = eng_lib.quantize_params(params, eng)

    t0 = time.perf_counter()
    prog = compiler.compile_calibrated(cfg, params, [x])
    t_compile = time.perf_counter() - t0

    dyn_prog = compiler.compile_cnn(cfg)

    def _clock(fn):
        fn(qparams, x).block_until_ready()          # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(qparams, x).block_until_ready()
        return (time.perf_counter() - t0) / reps

    t_dyn = _clock(jax.jit(lambda p, im: cnn.cnn_forward(p, im, cfg, eng)))
    t_static = _clock(jax.jit(lambda p, im: compiler.execute(prog, p, im, eng)))
    return {
        "t_dyn": t_dyn, "t_static": t_static, "t_compile": t_compile,
        "nodes": len(prog.graph.nodes),
        "f32_rt_static": prog.f32_roundtrips(),
        "f32_rt_dynamic": dyn_prog.f32_roundtrips(),
        "folded": prog.plan.stats["folded_requants"],
        "launches": compiler.launch_count(prog.graph),
        "launches_unfused": compiler.launch_count(compiler.build_graph(cfg)),
        "fused_ops": prog.plan.stats.get("fused_ops", 0),
    }


def run(measure: bool = True):
    rows = []
    for name, cfg in CNN_ZOO.items():
        fps_static = pm.modeled_fps(cfg, pm.OURS)
        fps_dyn = pm.modeled_fps(cfg, pm.OURS_DYNAMIC)
        rows.append((
            f"pipeline/model/{name}", 0.0,
            f"static_int8_fps={fps_static:.0f},dynamic_f32_fps={fps_dyn:.0f},"
            f"static_speedup={fps_static / fps_dyn:.2f}"))
    if measure:
        for name in MEASURE:
            m = _measure_cpu(name)
            rows.append((
                f"pipeline/measured_cpu/{name}", m["t_static"] * 1e6,
                f"static={m['t_static'] * 1e3:.1f}ms,"
                f"dynamic={m['t_dyn'] * 1e3:.1f}ms,"
                f"speedup={m['t_dyn'] / m['t_static']:.2f}x,"
                f"compile={m['t_compile'] * 1e3:.0f}ms,"
                f"nodes={m['nodes']},"
                f"f32_roundtrips={m['f32_rt_static']}"
                f"(dynamic {m['f32_rt_dynamic']}),"
                f"folded_requants={m['folded']},"
                f"launches={m['launches']}vs{m['launches_unfused']}unfused,"
                f"fused_ops={m['fused_ops']}(hw={MEASURE_HW})"))
    return rows
