"""Table IV reproduction: ResNet50 (MLPerf-style) latency/throughput and the
Low-Channel Conv Unit ablation.

Paper claims checked:
  * 8PE+LowPE vs 8PE: +1.14x throughput, -7.5% latency (Section V-B/VI-D);
  * stage-0 utilization collapse without the specialized unit (13.1%);
  * single-batch latency is bandwidth-limited (their DDR4 argument; ours is
    the HBM memory term).
"""
import dataclasses
import time

import numpy as np

from benchmarks import perf_model as pm
from repro.configs.cnn_zoo import RESNET50
from repro.core import dse


def run():
    rows = []
    t_ours = pm.model_inference_time(RESNET50, pm.OURS)
    t_nolow = pm.model_inference_time(RESNET50, pm.NO_LOWPE)
    t_base = pm.model_inference_time(RESNET50, pm.BASELINE)

    # batch-1 latency and batch-8 (paper's batch) throughput; weights are
    # amortized across the batch in the memory term, approximated by the
    # compute-bound limit at batch 8.
    fps1 = 1.0 / t_ours
    rows.append((
        "table4/resnet50_v5e_modeled", t_ours * 1e6,
        f"latency_b1={t_ours * 1e3:.3f}ms,fps_b1={fps1:.0f},"
        f"paper_8pe_latency=1.75ms,paper_8pe_fps=4568"))

    thr_gain = t_nolow / t_ours
    lat_cut = 1.0 - t_ours / t_nolow
    rows.append((
        "table4/low_channel_ablation", 0.0,
        f"throughput_gain={thr_gain:.3f}x(paper 1.14x),"
        f"latency_cut={100 * lat_cut:.1f}%(paper 7.5%)"))

    stage0_util_plain = dse.mxu_utilization(3, 64, kk=1)
    stage0_util_fold = dse.mxu_utilization(3, 64, kk=49)
    rows.append((
        "table4/stage0_utilization", 0.0,
        f"plain={stage0_util_plain:.4f},folded={stage0_util_fold:.3f},"
        f"paper_conv_pe_util=0.131"))

    rows.append((
        "table4/baseline_comparison", 0.0,
        f"ours_vs_xvdpu_analog={t_base / t_ours:.2f}x"
        f"(paper 8PE vs XV-C32B8: 1.13x at iso-clock)"))

    # TOPS/W analog: report modeled TOPS utilization per engine config
    # (power is not measurable here; the paper's 8.6x/1.4x TOPS/W claims are
    # resource-efficiency claims, whose TPU analog is useful-flops ratio).
    gops = RESNET50.gops * 1e9
    rows.append((
        "table4/efficiency", 0.0,
        f"useful_tops_ours={gops / t_ours / 1e12:.1f},"
        f"useful_tops_baseline={gops / t_base / 1e12:.1f},"
        f"efficiency_gain={t_base / t_ours:.2f}x"))
    return rows
