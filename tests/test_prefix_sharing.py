"""Prefix-sharing paged KV invariants.

Two layers of evidence for the refcounted copy-on-write block design:

  * PROPERTY tests over BlockAllocator's refcounting -- arbitrary
    alloc/share/free interleavings never double-free, never leak
    (`in_use + free_blocks == num_blocks` is conserved at every step),
    and the peak-occupancy watermark is monotone;
  * GOLDEN tests over the serving engine -- on qwen2 under quant="none"
    (compute dtype == cache dtype, so the chunk program's
    store-then-attend roundtrip is the identity) sharing returns
    BIT-IDENTICAL greedy token ids to private whole-prompt prefill on
    both backends, while measurably allocating fewer fresh blocks; a
    warm index changes nothing but the hit counters; gemma2's local
    attention layers (dense ring KV, no page boundary) record a blocker
    and fall back to private prefill, still bit-identical to the
    no-sharing engine.

Runs with or without `hypothesis` installed: the offline container
replays each property over the _hypothesis_compat rotation.
"""
import numpy as np
import pytest
import jax

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro import configs
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.kv_alloc import BlockAllocator

PAGE = 8
PLEN = 24            # pinned prefill width: 2 full shared pages + tail
SHARED = 16          # page-aligned shared system-prompt length
NEW = 4


def _setup(name, seed=0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(seed))
    return arch, params


def _shared_prompts(arch, n, seed=0):
    """n full-width prompts agreeing on the first SHARED tokens."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, arch.vocab_size, size=SHARED)
    return [np.concatenate([
        head, rng.integers(0, arch.vocab_size, size=PLEN - SHARED)
    ]).astype(np.int32) for _ in range(n)]


def _engine(arch, params, backend="ref", sharing=True, **kw):
    eng = EngineConfig(quant="none", backend=backend, interpret=True)
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_seq", 32)
    kw.setdefault("kv_blocks", 16)
    return ServeEngine(arch, params, eng, kv_layout="paged",
                       page_size=PAGE, prefill_len=PLEN,
                       prefix_sharing=sharing, **kw)


# ---------------------------------------------------------------------------
# BlockAllocator refcounting: conservation properties
# ---------------------------------------------------------------------------

class TestRefcountProperties:
    @settings(deadline=None)
    @given(num_blocks=st.integers(min_value=4, max_value=16),
           seed=st.integers(min_value=0, max_value=9))
    def test_interleavings_conserve_the_pool(self, num_blocks, seed):
        """Arbitrary alloc/share/free interleavings: the pool is conserved
        at every step (no leak, no double-count), the peak watermark is
        monotone, and releasing every live handle returns the allocator to
        pristine (all refcounts zero)."""
        rng = np.random.default_rng(seed)
        a = BlockAllocator(num_blocks)
        held = []                       # one entry per live owner handle
        peak_seen = 0
        for _ in range(200):
            op = int(rng.integers(0, 3))
            if op == 0:
                n = int(rng.integers(0, num_blocks + 1))
                if a.can_allocate(n):
                    held.append(a.alloc(n))
            elif op == 1 and held:      # a second table joins a prefix
                src = held[int(rng.integers(len(held)))]
                held.append(a.share(src))
            elif op == 2 and held:      # one owner releases
                a.free(held.pop(int(rng.integers(len(held)))))
            assert a.in_use + a.free_blocks == a.num_blocks
            assert a.stats.peak_in_use >= max(peak_seen, a.in_use)
            peak_seen = a.stats.peak_in_use
        for h in held:
            a.free(h)
        assert a.in_use == 0 and a.free_blocks == num_blocks
        assert all(a.refcount(b) == 0 for b in range(num_blocks))

    @settings(deadline=None)
    @given(owners=st.integers(min_value=2, max_value=6))
    def test_shared_block_frees_only_at_zero(self, owners):
        """A block with k owners survives k-1 frees and returns to the
        pool exactly on the k-th; the k+1-th is a detected double free."""
        a = BlockAllocator(4)
        blocks = a.alloc(2)
        for _ in range(owners - 1):
            assert a.share(blocks) == blocks
        for i in range(owners - 1):
            a.free(blocks)
            assert a.in_use == 2                  # still owned
            assert a.refcount(blocks[0]) == owners - 1 - i
        a.free(blocks)
        assert a.in_use == 0 and a.free_blocks == 4
        with pytest.raises(ValueError, match="double free"):
            a.free(blocks)

    def test_share_of_free_block_rejected(self):
        """Sharing a freed block means the caller's index held a stale
        pointer -- loud failure, not silent aliasing."""
        a = BlockAllocator(4)
        blocks = a.alloc(1)
        a.free(blocks)
        with pytest.raises(ValueError, match="free block"):
            a.share(blocks)
        with pytest.raises(ValueError, match="out of range"):
            a.share([7])

    @settings(deadline=None)
    @given(n1=st.integers(min_value=1, max_value=8),
           n2=st.integers(min_value=1, max_value=8))
    def test_peak_watermark_is_monotone(self, n1, n2):
        a = BlockAllocator(8)
        r1 = a.alloc(n1)
        assert a.stats.peak_in_use == n1
        a.free(r1)
        a.alloc(n2)
        assert a.stats.peak_in_use == max(n1, n2)

    def test_share_accounting(self):
        a = BlockAllocator(8)
        blocks = a.alloc(3)
        a.share(blocks)
        a.share(blocks[:1])
        assert a.stats.shares == 2
        assert a.stats.shared_blocks == 4
        assert a.share([]) == [] and a.stats.shares == 2   # no-op join


# ---------------------------------------------------------------------------
# Golden: shared serving is bit-identical and cheaper
# ---------------------------------------------------------------------------

class TestGoldenSharing:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_bit_identical_with_fewer_fresh_blocks(self, backend):
        """Sharing vs private serving of one shared-prefix trace under
        quant="none": token ids match bitwise on both backends, the index
        records hits, and strictly fewer fresh blocks are allocated."""
        arch, params = _setup("qwen2-1.5b")
        prompts = _shared_prompts(arch, 4)
        base = _engine(arch, params, backend, sharing=False)
        want = base.generate(prompts, max_new_tokens=NEW)
        eng = _engine(arch, params, backend, sharing=True)
        got = eng.generate(prompts, max_new_tokens=NEW)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ps = eng.stats()["prefix_sharing"]
        assert ps["enabled"] and ps["hits"] >= 1
        assert ps["shared_blocks"] >= 1 and ps["index_nodes"] >= 1
        assert (eng.alloc.stats.blocks_served
                < base.alloc.stats.blocks_served)
        # pool conservation holds through serving too
        assert eng.alloc.in_use + eng.alloc.free_blocks == \
            eng.alloc.num_blocks

    def test_warm_index_is_invariant(self):
        """A warm index (prefix bits cached from an earlier run) changes
        hit counters, never token ids: cold-engine output == warm-engine
        output, request for request."""
        arch, params = _setup("qwen2-1.5b")
        prompts = _shared_prompts(arch, 3)
        cold = _engine(arch, params, sharing=True)
        want = cold.generate(prompts, max_new_tokens=NEW)
        warm = _engine(arch, params, sharing=True)
        warm.generate(prompts[:1], max_new_tokens=NEW)   # seeds the index
        warm_hits0 = warm.stats()["prefix_sharing"]["hits"]
        got = warm.generate(prompts, max_new_tokens=NEW)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # every warm-run prompt matched the seeded prefix
        assert (warm.stats()["prefix_sharing"]["hits"] - warm_hits0
                >= len(prompts))

    def test_gemma2_local_layers_record_blocker_and_fall_back(self):
        """Local-attention archs cannot share (dense ring KV has no page
        boundary): the engine disables sharing with a recorded blocker and
        serves bit-identically to an explicit no-sharing engine."""
        arch, params = _setup("gemma2-2b")
        eng = _engine(arch, params, sharing=True)
        assert not eng.prefix_sharing
        assert any("local" in b for b in eng.prefix_sharing_blockers)
        prompts = _shared_prompts(arch, 2)
        want = _engine(arch, params, sharing=False).generate(
            prompts, max_new_tokens=NEW)
        got = eng.generate(prompts, max_new_tokens=NEW)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ps = eng.stats()["prefix_sharing"]
        assert ps["enabled"] is False and ps["blockers"]

    def test_config_validation(self):
        arch, params = _setup("qwen2-1.5b")
        eng = EngineConfig(quant="none", backend="ref")
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(arch, params, eng, batch_size=2, max_seq=32,
                        prefix_sharing=True, prefill_len=PLEN)
        with pytest.raises(ValueError, match="prefill_len"):
            ServeEngine(arch, params, eng, batch_size=2, max_seq=32,
                        kv_layout="paged", page_size=PAGE,
                        prefix_sharing=True)
