"""Chunked linear recurrences: mamba selective scan + RG-LRU."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.core.config import EngineConfig
from repro.models import ssm as S
from repro.models.params import init_params

ENG = EngineConfig(quant="none", backend="ref")


def naive_scan(a, b, h0):
    """Reference O(L) sequential recurrence."""
    hs = []
    h = h0.astype(np.float64)
    for t in range(a.shape[1]):
        h = a[:, t].astype(np.float64) * h + b[:, t].astype(np.float64)
        hs.append(h.copy())
    return np.stack(hs, 1), h


class TestChunkedScan:
    @pytest.mark.parametrize("l,chunk", [(16, 4), (32, 8), (24, 24), (64, 16)])
    def test_matches_naive(self, rng, l, chunk):
        a = rng.uniform(0.5, 0.99, (2, l, 8)).astype(np.float32)
        b = rng.normal(size=(2, l, 8)).astype(np.float32)
        h0 = rng.normal(size=(2, 8)).astype(np.float32)
        got, hlast = S.linear_scan_chunked(jnp.array(a), jnp.array(b),
                                           jnp.array(h0), chunk)
        want, hwant = naive_scan(a, b, h0)
        np.testing.assert_allclose(np.array(got), want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.array(hlast), hwant, rtol=1e-4,
                                   atol=1e-4)

    @settings(deadline=None, max_examples=15)
    @given(l=st.sampled_from([8, 16, 32]), d=st.integers(1, 16),
           seed=st.integers(0, 100))
    def test_chunk_invariance(self, l, d, seed):
        """Property: the result must not depend on the chunk size."""
        rng = np.random.default_rng(seed)
        a = rng.uniform(0.0, 1.0, (1, l, d)).astype(np.float32)
        b = rng.normal(size=(1, l, d)).astype(np.float32)
        h0 = np.zeros((1, d), np.float32)
        outs = []
        for chunk in (l, l // 2, max(l // 4, 1)):
            if l % chunk:
                continue
            y, _ = S.linear_scan_chunked(jnp.array(a), jnp.array(b),
                                         jnp.array(h0), chunk)
            outs.append(np.array(y))
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-5)


class TestMamba:
    def _setup(self, rng, l=16):
        arch = reduced(ARCHS["falcon-mamba-7b"])
        p = init_params(S.mamba_schema(arch), jax.random.PRNGKey(0))
        x = jnp.array(rng.normal(size=(2, l, arch.d_model)).astype(np.float32))
        return arch, p, x

    def test_full_vs_stepwise(self, rng):
        """The chunked scan path == the O(1) decode recurrence, stepwise."""
        arch, p, x = self._setup(rng, l=8)
        full, _ = S.mamba_apply(p, x, arch, ENG, chunk=4)
        state = S.mamba_init_state(arch, 2)
        outs = []
        for t in range(8):
            o, state = S.mamba_decode(p, x[:, t:t + 1], arch, ENG, state)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.array(full), np.array(step),
                                   rtol=5e-3, atol=5e-3)

    def test_prefill_state_continuation(self, rng):
        """State returned by the full pass continues correctly."""
        arch, p, x = self._setup(rng, l=12)
        full, _ = S.mamba_apply(p, x, arch, ENG, chunk=4)
        pre, st = S.mamba_apply(p, x[:, :8], arch, ENG,
                                state=S.mamba_init_state(arch, 2), chunk=4)
        outs = [pre]
        for t in range(8, 12):
            o, st = S.mamba_decode(p, x[:, t:t + 1], arch, ENG, st)
            outs.append(o)
        np.testing.assert_allclose(np.array(jnp.concatenate(outs, 1)),
                                   np.array(full), rtol=5e-3, atol=5e-3)

    def test_causality(self, rng):
        arch, p, x = self._setup(rng, l=16)
        y1, _ = S.mamba_apply(p, x, arch, ENG, chunk=8)
        x2 = x.at[:, 10:].add(3.0)
        y2, _ = S.mamba_apply(p, x2, arch, ENG, chunk=8)
        np.testing.assert_allclose(np.array(y1)[:, :10],
                                   np.array(y2)[:, :10], rtol=1e-4, atol=1e-5)


class TestRGLRU:
    def _setup(self, rng, l=12):
        arch = reduced(ARCHS["recurrentgemma-2b"])
        p = init_params(S.rglru_schema(arch), jax.random.PRNGKey(0))
        x = jnp.array(rng.normal(size=(2, l, arch.d_model)).astype(np.float32))
        return arch, p, x

    def test_full_vs_stepwise(self, rng):
        arch, p, x = self._setup(rng, l=8)
        full, _ = S.rglru_apply(p, x, arch, ENG, chunk=4)
        state = S.rglru_init_state(arch, 2)
        outs = []
        for t in range(8):
            o, state = S.rglru_decode(p, x[:, t:t + 1], arch, ENG, state)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.array(full), np.array(step),
                                   rtol=5e-3, atol=5e-3)

    def test_stability(self, rng):
        """|a| <= 1 by construction -> bounded state on long inputs."""
        arch, p, x = self._setup(rng, l=64)
        y, st = S.rglru_apply(p, x, arch, ENG, state=S.rglru_init_state(arch, 2),
                              chunk=16)
        assert np.isfinite(np.array(y)).all()
        assert np.abs(np.array(st["rec"])).max() < 1e3
