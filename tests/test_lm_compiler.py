"""Model-agnostic engine IR, LM side: transformer prefill lowers through the
compiler, calibrates to a static-int8 program whose GEMM inputs all carry
compile-time scales, matches the eager T.forward/T.prefill paths on both
backends, and serves from the keyed ProgramCache."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro import compiler, configs
from repro.compiler import passes
from repro.compiler.graph import (AddOp, AttnOp, EmbedOp, HeadOp, InputOp,
                                  LinearOp, MulOp, NormOp)
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models.params import init_params, is_spec

ENG = EngineConfig(quant="none", backend="ref")
W8 = EngineConfig(quant="w8a8", backend="ref")

# archs the IR lowers (attention-only mixers); the rest stay eager
LOWERABLE = ["qwen2-1.5b", "gemma2-2b", "minitron-4b", "granite-8b"]
EAGER_ONLY = ["falcon-mamba-7b", "recurrentgemma-2b", "grok-1-314b",
              "whisper-tiny"]

B, L = 2, 12


def _setup(name, seed=0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(seed))
    toks = jnp.array(np.random.default_rng(seed).integers(
        0, arch.vocab_size, (B, L)).astype(np.int32))
    return arch, params, toks


def _cache(arch, batch, seq, eng):
    return jtu.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                        T.cache_schema(arch, batch, seq, eng),
                        is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

class TestLowerTransformer:
    @pytest.mark.parametrize("name", LOWERABLE)
    def test_structure_and_param_paths(self, name):
        arch, params, _ = _setup(name)
        g = compiler.lower_transformer(arch)
        assert g.count(InputOp) == 1 and g.count(EmbedOp) == 1
        assert g.count(AttnOp) == arch.n_layers
        assert g.count(AddOp) == 2 * arch.n_layers
        # qkv + wo + mlp per layer
        per_layer = 4 + (3 if arch.mlp_gated else 2)
        assert g.count(LinearOp) == per_layer * arch.n_layers
        assert g.count(MulOp) == (arch.n_layers if arch.mlp_gated else 0)
        assert g.count(HeadOp) == 1
        assert isinstance(g.nodes[g.output], HeadOp)
        assert g.nodes[g.output].tied == arch.tie_embeddings
        for n in g.nodes:                    # topological, paths resolve
            assert all(i < n.id for i in n.inputs)
            for path in (getattr(n, "w", None), getattr(n, "b", None)):
                if path:
                    leaf = compiler.get_param(params, path)
                    assert hasattr(leaf, "shape"), (name, path)

    @pytest.mark.parametrize("name", EAGER_ONLY)
    def test_unsupported_archs_refuse(self, name):
        arch = configs.reduced(configs.get_arch(name))
        assert not compiler.can_lower(arch)
        assert compiler.lowering_blockers(arch)
        with pytest.raises(NotImplementedError):
            compiler.lower_transformer(arch)

    def test_qkv_colevel_on_conv_pe(self):
        """The concurrency the IR exposes: a block's three QKV projections
        dispatch in one Conv PE wave, and the SwiGLU gate/up pair does too."""
        arch, _, _ = _setup("qwen2-1.5b")
        g = compiler.lower_transformer(arch)
        s = compiler.level_schedule(g)
        level_of = {i: k for k, lv in enumerate(s.levels) for i in lv}
        for n in g.nodes:
            if isinstance(n, AttnOp):
                assert len({level_of[i] for i in n.inputs}) == 1
        assert s.stats["max_width"] >= 3


# ---------------------------------------------------------------------------
# Compiled dynamic program == eager forward (float path, bit-level)
# ---------------------------------------------------------------------------

class TestDynamicParity:
    @pytest.mark.parametrize("name", LOWERABLE)
    def test_float_forward_exact(self, name):
        arch, params, toks = _setup(name)
        prog = compiler.compile_lm(arch)
        out = compiler.execute(prog, params, toks, ENG)
        want, _ = T.forward(params, {"tokens": toks}, arch, ENG,
                            compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.array(out), np.array(want),
                                   rtol=1e-5, atol=1e-5)

    def test_dynamic_program_memoized(self):
        arch, _, _ = _setup("qwen2-1.5b")
        assert compiler.compile_lm(arch) is compiler.compile_lm(arch)
        # the prefill variant is a distinct cached program
        p = compiler.compile_lm(arch, prefill=True)
        assert p is not compiler.compile_lm(arch)
        assert compiler.compile_lm(arch, prefill=True) is p


# ---------------------------------------------------------------------------
# Static int8 plan: every GEMM input carries a compile-time scale
# ---------------------------------------------------------------------------

class TestStaticPlan:
    def test_linear_inputs_int8_rest_float(self):
        arch, params, toks = _setup("qwen2-1.5b")
        prog = compiler.compile_lm_calibrated(arch, params, [toks])
        g, plan = prog.graph, prog.plan
        # zero f32 edges into GEMM engines
        assert passes.f32_roundtrip_edges(g, plan) == []
        assert prog.f32_roundtrips() == 0
        for n in g.nodes:
            if isinstance(n, LinearOp):      # every ops.linear input static
                # a fused residual-add epilogue appends the residual edge,
                # which rides the f32 MISC stream by design
                ins = (n.inputs[:-1] if n.epilogue is not None
                       and n.epilogue.add else n.inputs)
                assert all(plan.emit_int8[i] for i in ins), n
            if isinstance(n, (EmbedOp, HeadOp)):
                assert not plan.emit_int8[n.id]
        # the residual stream stays f32 on the MISC core
        for n in g.nodes:
            if isinstance(n, AddOp):
                assert not plan.emit_int8[n.id]

    def test_calibration_covers_every_edge(self):
        arch, params, toks = _setup("gemma2-2b")
        g = compiler.lower_transformer(arch)
        scales = compiler.calibrate(g, params, [toks], arch)
        assert set(scales) == {n.id for n in g.nodes}
        assert all(s > 0 for s in scales.values())


# ---------------------------------------------------------------------------
# Golden compiled-vs-eager parity, >=2 zoo configs x {ref, pallas}
# ---------------------------------------------------------------------------

# Max |static - dynamic| logit gap as a fraction of max |dynamic logit|
# (the CNN golden-test criterion, test_compiler.GOLDEN_GAP_FRAC): the
# requant-rounding drift of per-tensor static scales vs per-token dynamic
# quantization at reduced scale, ~2.5x the measured gap at seed 0.
GOLDEN_GAP_FRAC = {
    "qwen2-1.5b": 0.25,
    "gemma2-2b": 0.25,
    "minitron-4b": 0.30,
}


@pytest.fixture(scope="module")
def lm_golden():
    """One calibration + compile per arch, shared by both backends."""
    cache = {}

    def get(name):
        if name not in cache:
            arch, params, toks = _setup(name)
            prog = compiler.compile_lm_calibrated(arch, params, [toks])
            f, _ = T.forward(params, {"tokens": toks}, arch, ENG,
                             compute_dtype=jnp.float32)
            cache[name] = (arch, params, toks, prog, np.array(f))
        return cache[name]

    return get


class TestGoldenPrefillParity:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("name", sorted(GOLDEN_GAP_FRAC))
    def test_static_vs_eager_gap_bounded(self, name, backend, lm_golden):
        """The compiled static-int8 prefill program tracks the eager dynamic
        w8a8 forward within the golden bound and correlates with the float
        reference, on both kernel backends."""
        arch, params, toks, prog, f = lm_golden(name)
        eng = EngineConfig(quant="w8a8", backend=backend, interpret=True)
        qparams = eng_lib.quantize_params(params, eng)
        dyn = np.array(T.forward(qparams, {"tokens": toks}, arch, eng,
                                 compute_dtype=jnp.float32)[0])
        stat = np.array(compiler.execute(prog, qparams, toks, eng))
        assert np.isfinite(stat).all() and np.isfinite(dyn).all()
        gap = np.max(np.abs(stat - dyn))
        bound = GOLDEN_GAP_FRAC[name] * np.max(np.abs(dyn))
        assert gap <= bound, (name, backend, gap, bound)
        assert np.corrcoef(f.ravel(), stat.ravel())[0, 1] > 0.9


# ---------------------------------------------------------------------------
# Prefill program: last-token logits + collected KV == eager T.prefill
# ---------------------------------------------------------------------------

class TestPrefillProgram:
    @pytest.mark.parametrize("name", ["qwen2-1.5b", "gemma2-2b"])
    def test_logits_and_kv_match_eager_prefill(self, name):
        arch, params, toks = _setup(name)
        prog = compiler.compile_lm(arch, prefill=True)
        kvs = {}
        lp = compiler.execute(prog, params, toks, ENG, collect=kvs)
        cache = _cache(arch, B, L, ENG)
        elp, ecache = T.prefill(params, cache, {"tokens": toks}, arch, ENG,
                                compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.array(lp), np.array(elp),
                                   rtol=1e-5, atol=1e-5)
        assert sorted(kvs) == list(range(arch.n_layers))
        for i in range(arch.n_layers):
            k, v = kvs[i]
            entry = ecache["layers"][i]
            w = entry["k"].shape[1]
            np.testing.assert_allclose(
                np.array(k[:, -w:].astype(entry["k"].dtype)),
                np.array(entry["k"][:, :min(w, L)]), rtol=1e-2, atol=1e-2)
            np.testing.assert_allclose(
                np.array(v[:, -w:].astype(entry["v"].dtype)),
                np.array(entry["v"][:, :min(w, L)]), rtol=1e-2, atol=1e-2)

    def test_decode_continues_from_compiled_prefill(self):
        """Compiled prefill -> eager decode == full forward teacher forcing
        (the serving invariant, through the program path)."""
        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(3)
        EXTRA = 3
        toks = jnp.array(rng.integers(0, arch.vocab_size,
                                      (B, L + EXTRA)).astype(np.int32))
        full, _ = T.forward(params, {"tokens": toks}, arch, ENG,
                            compute_dtype=jnp.float32)
        prog = compiler.compile_lm(arch, prefill=True)
        kvs = {}
        lp = compiler.execute(prog, params, toks[:, :L], ENG, collect=kvs)
        cache = _cache(arch, B, L + EXTRA, ENG)
        layers = []
        for i in range(arch.n_layers):
            k, v = kvs[i]
            layers.append(T._kv_store(cache["layers"][i], k, v, 0, ENG))
        cache = {"layers": layers, "pos": jnp.asarray(L, jnp.int32)}
        np.testing.assert_allclose(np.array(lp[:, 0]),
                                   np.array(full[:, L - 1]),
                                   rtol=2e-2, atol=2e-2)
        for t in range(EXTRA):
            ld, cache = T.decode(params, cache, toks[:, L + t:L + t + 1],
                                 arch, ENG, compute_dtype=jnp.float32)
            np.testing.assert_allclose(np.array(ld[:, 0]),
                                       np.array(full[:, L + t]),
                                       rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Serving: ServeEngine prefill through the ProgramCache
# ---------------------------------------------------------------------------

class TestServeEnginePrograms:
    def test_compiled_prefill_matches_eager_prefill(self):
        from repro.serve.engine import ServeEngine
        arch, params, toks = _setup("qwen2-1.5b")
        se = ServeEngine(arch, params, ENG, batch_size=B, max_seq=L + 8)
        assert se.compiled
        cache = se._empty_cache()
        lp, c2 = se._prefill_exec()(se.params, cache, {"tokens": toks})
        elp, ec = T.prefill(params, _cache(arch, B, L + 8, ENG),
                            {"tokens": toks}, arch, ENG,
                            compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.array(lp), np.array(elp),
                                   rtol=1e-4, atol=1e-4)
        for i in range(arch.n_layers):
            np.testing.assert_allclose(np.array(c2["layers"][i]["k"]),
                                       np.array(ec["layers"][i]["k"]),
                                       rtol=1e-2, atol=1e-2)
        assert int(c2["pos"]) == L

    def test_program_cache_hits_on_reserve(self):
        """The acceptance invariant: re-serving an arch hits the
        ProgramCache, including across engines sharing one cache."""
        from repro.serve.engine import ServeEngine
        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(0)
        calib = [jnp.array(rng.integers(0, arch.vocab_size,
                                        (2, 8)).astype(np.int32))]
        se = ServeEngine(arch, params, W8, batch_size=2, max_seq=32,
                         calib_batches=calib)
        prompts = [rng.integers(0, arch.vocab_size, size=6)
                   for _ in range(2)]
        se.generate(prompts, max_new_tokens=2)
        # one compile per program: prefill + decode, from ONE calibration
        assert se.cache.stats.misses == 2
        p1 = se.prefill_program()
        d1 = se.decode_program()
        assert p1.static and d1.static        # calibrated static programs
        assert d1.kind == "decode" and p1.kind == "forward"
        se.generate(prompts, max_new_tokens=2)
        assert se.cache.stats.misses == 2     # no recompile on re-serve
        assert se.cache.stats.hits >= 2
        assert se.prefill_program() is p1
        # a second engine on the same fabric shares the compiled programs
        se2 = ServeEngine(arch, params, W8, batch_size=2, max_seq=32,
                          calib_batches=calib, cache=se.cache)
        assert se2.prefill_program() is p1
        assert se2.decode_program() is d1
        assert se.cache.stats.misses == 2
        st = se.stats()
        assert st["compiled_prefill"] and st["prefill_levels"] > 0
        assert 0 < st["prefill_occupancy"] <= 1
        assert st["compiled_decode"] and st["decode_levels"] > 0
        assert st["lowering_blockers"] == []

    def test_calibrator_method_keys_distinct_programs(self):
        """absmax and percentile calibrations never share a cache entry."""
        from repro.serve.engine import ServeEngine
        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(0)
        calib = [jnp.array(rng.integers(0, arch.vocab_size,
                                        (2, 8)).astype(np.int32))]
        from repro.serve.program_cache import ProgramCache
        shared = ProgramCache(capacity=4)
        sa = ServeEngine(arch, params, W8, batch_size=2, max_seq=32,
                         calib_batches=calib, cache=shared)
        sp = ServeEngine(arch, params, W8, batch_size=2, max_seq=32,
                         calib_batches=calib, calibrator="p99.9",
                         cache=shared)
        pa, pp = sa.prefill_program(), sp.prefill_program()
        assert pa is not pp
        assert shared.stats.misses == 2
        assert sa.calib_id != sp.calib_id

    def test_greedy_generation_deterministic(self):
        from repro.serve.engine import ServeEngine
        arch, params, _ = _setup("gemma2-2b")
        rng = np.random.default_rng(1)
        se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=48)
        prompts = [rng.integers(0, arch.vocab_size, size=5)
                   for _ in range(3)]
        a = se.generate(prompts, max_new_tokens=3)
        b = se.generate(prompts, max_new_tokens=3)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Percentile calibrator
# ---------------------------------------------------------------------------

class TestPercentileCalibrator:
    def test_outlier_robustness(self):
        """One huge outlier wastes the absmax range but barely moves p99.9."""
        from repro.compiler.calibrate import PercentileCalibrator
        from repro.core.quant import Calibrator
        rng = np.random.default_rng(0)
        x = rng.normal(size=100_000).astype(np.float32)
        x[0] = 1e4
        ab, pc = Calibrator(), PercentileCalibrator(q=99.9)
        ab.observe("e", jnp.asarray(x))
        pc.observe("e", jnp.asarray(x))
        s_ab, s_pc = ab.scales()["e"], pc.scales()["e"]
        assert s_pc < s_ab / 100          # outlier ignored
        assert s_pc > 0

    def test_tracks_absmax_without_outliers(self):
        from repro.compiler.calibrate import PercentileCalibrator
        from repro.core.quant import Calibrator
        rng = np.random.default_rng(1)
        ab, pc = Calibrator(), PercentileCalibrator(q=100.0)
        for _ in range(3):                # streaming, with range growth
            x = jnp.asarray(rng.normal(size=4096).astype(np.float32)
                            * rng.uniform(0.5, 4.0))
            ab.observe("e", x)
            pc.observe("e", x)
        s_ab, s_pc = ab.scales()["e"], pc.scales()["e"]
        assert abs(s_pc - s_ab) / s_ab < 0.05   # p100 ~ absmax (bin width)

    def test_method_string_parsing(self):
        from repro.compiler.calibrate import make_calibrator
        assert make_calibrator("p99.9").q == 99.9
        with pytest.raises(ValueError):
            make_calibrator("median")

    def test_percentile_calibrated_program_still_accurate(self):
        arch, params, toks = _setup("qwen2-1.5b")
        prog = compiler.compile_lm_calibrated(arch, params, [toks],
                                              method="p99.9")
        qparams = eng_lib.quantize_params(params, W8)
        stat = np.array(compiler.execute(prog, qparams, toks, W8))
        f = np.array(T.forward(params, {"tokens": toks}, arch, ENG,
                               compute_dtype=jnp.float32)[0])
        assert np.isfinite(stat).all()
        assert np.corrcoef(f.ravel(), stat.ravel())[0, 1] > 0.9
