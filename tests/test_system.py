"""End-to-end behaviour tests: the training driver (checkpoint/resume/
preemption protocol), the serving driver, and loss convergence."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_module(mod, args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-m", mod] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


class TestTrainDriver:
    def test_loss_improves(self, tmp_path):
        out = _run_module("repro.launch.train", [
            "--arch", "qwen2-1.5b", "--smoke", "--steps", "8",
            "--batch", "4", "--seq", "64",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
        assert out.returncode == 0, out.stderr[-2000:]
        assert "improved=True" in out.stdout

    def test_checkpoint_resume_continues(self, tmp_path):
        out1 = _run_module("repro.launch.train", [
            "--arch", "qwen2-1.5b", "--smoke", "--steps", "6",
            "--batch", "4", "--seq", "64",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
        assert out1.returncode == 0, out1.stderr[-2000:]
        out2 = _run_module("repro.launch.train", [
            "--arch", "qwen2-1.5b", "--smoke", "--steps", "9",
            "--batch", "4", "--seq", "64",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3", "--resume"])
        assert out2.returncode == 0, out2.stderr[-2000:]
        assert "resumed from step 6" in out2.stdout
        assert "step     6" in out2.stdout
        assert "step     5" not in out2.stdout     # no rework

    def test_straggler_watchdog_aborts_with_checkpoint(self, tmp_path):
        # An impossible step budget forces the watchdog path.
        out = _run_module("repro.launch.train", [
            "--arch", "qwen2-1.5b", "--smoke", "--steps", "5",
            "--batch", "4", "--seq", "64",
            "--ckpt-dir", str(tmp_path), "--step-timeout", "0.0001"])
        assert out.returncode == 75                # EX_TEMPFAIL: reschedule
        assert "STRAGGLER" in out.stdout
        assert any(d.startswith("step_") for d in os.listdir(tmp_path))


class TestServeDriver:
    @pytest.mark.parametrize("quant", ["none", "w8a8"])
    def test_serves_requests(self, quant):
        out = _run_module("repro.launch.serve", [
            "--arch", "qwen2-1.5b", "--smoke", "--requests", "4",
            "--batch", "2", "--new-tokens", "4", "--prompt-len", "8",
            "--quant", quant])
        assert out.returncode == 0, out.stderr[-2000:]
        assert "served 4 requests" in out.stdout

    def test_int8_kv(self):
        out = _run_module("repro.launch.serve", [
            "--arch", "granite-8b", "--smoke", "--requests", "2",
            "--batch", "2", "--new-tokens", "3", "--prompt-len", "8",
            "--quant", "w8", "--kv", "int8"])
        assert out.returncode == 0, out.stderr[-2000:]
        assert "kv=int8" in out.stdout


class TestConvergence:
    def test_100_step_loss_curve(self, tmp_path):
        """A ~1M-param model trained 60 steps must show a real loss drop
        (the scaled-down version of the 100M example)."""
        out = _run_module("repro.launch.train", [
            "--arch", "granite-moe-1b-a400m", "--smoke", "--steps", "40",
            "--batch", "8", "--seq", "64", "--lr", "1e-3",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "100"],
            timeout=1800)
        assert out.returncode == 0, out.stderr[-2000:]
        losses = [float(l.split("loss")[1].split()[0])
                  for l in out.stdout.splitlines() if l.startswith("step")]
        assert len(losses) == 40
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first * 0.8, (first, last)
