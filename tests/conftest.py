import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dryrun.py-only, per the assignment).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# The tests dir itself must be importable for the _hypothesis_compat shim
# (pytest's rootdir insertion covers this in most, but not all, invocations).
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
