"""Int4 weight-only decode GEMMs + fused QKV / gate-up projections.

Pins the PR's tentpole invariants:

  * Q4Tensor packing: nibble layout, per-group f16 scale/zero, bounded
    roundtrip error, and the container-bytes win (<= 0.55x of the int8
    layout on every golden arch's projection set).
  * The int4 GEMM value stream has ONE definition (ref.int4_group_dot):
    the Pallas Conv-PE kernel's MAC core agrees with the ref oracle
    bitwise; the float epilogue (a_scale/bias) may fuse into FMAs under
    the kernel's jit, so end-to-end outputs are pinned to one-ulp.
  * fuse_projections rewrites q/k/v (and gate/up) LinearOps into one
    LinearGroupOp launch + free ViewOps, the fused dynamic program stays
    bitwise-identical to the unfused one, and launch counts drop 3 -> 1.
  * w4a8 compiled decode tracks the w8-calibrated static full program
    within the golden logit-gap bound, zoo-wide x {ref, pallas}.
  * The grouped-conv baseline (no DWC engine) lowers through the
    depthwise taps, matching the DWC-engine path and a direct per-channel
    conv."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro import compiler, configs
from repro.compiler import passes
from repro.compiler.graph import (AttnOp, LinearGroupOp, LinearOp, MulOp,
                                  ViewOp)
from repro.core import engine as eng_lib
from repro.core import quant as Q
from repro.core.config import EngineConfig
from repro.kernels import conv_pe, ops, ref
from repro.models import transformer as T
from repro.models.params import init_params, is_spec

GOLDEN = ["qwen2-1.5b", "gemma2-2b"]
B, L = 2, 8

ENG = EngineConfig(quant="none", backend="ref")
W8 = EngineConfig(quant="w8a8", backend="ref")
W4 = EngineConfig(quant="w4a8", backend="ref")


def _setup(name, seed=0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(seed))
    toks = jnp.array(np.random.default_rng(seed).integers(
        0, arch.vocab_size, (B, L)).astype(np.int32))
    return arch, params, toks


def _cache(arch, batch, seq, eng):
    return jtu.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                        T.cache_schema(arch, batch, seq, eng),
                        is_leaf=is_spec)


def _proj_bytes(params):
    total = 0

    def rec(node, name=None):
        nonlocal total
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, k)
        elif isinstance(node, (list, tuple)) and not hasattr(node, "_fields"):
            for v in node:
                rec(v, name)
        elif name in eng_lib.W4_KEYS:
            total += Q.container_nbytes(node)

    rec(params)
    return total


# ---------------------------------------------------------------------------
# Q4Tensor packing
# ---------------------------------------------------------------------------

class TestQ4Packing:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(128, 48)).astype(np.float32))
        q4 = Q.pack_int4(w, group_size=32)
        assert q4.packed.dtype == jnp.uint8
        assert q4.packed.shape == (64, 48)
        assert q4.scale.dtype == jnp.float16 and q4.scale.shape == (4, 48)
        assert q4.zero.dtype == jnp.float16 and q4.zero.shape == (4, 48)
        assert q4.shape == (128, 48) and q4.group_size == 32
        codes = np.asarray(Q.unpack_int4(q4.packed))
        assert codes.min() >= 0 and codes.max() <= 15
        # codes are chosen against the STORED f16 scale/zero, so the
        # dequant error is at most half a step per element (plus the
        # clipping slack at group extremes from f16-rounding the scale)
        err = np.abs(np.asarray(q4.dequant()) - np.asarray(w))
        step = np.asarray(q4.scale, np.float32)
        step = np.repeat(step, 32, axis=0)
        assert np.all(err <= 0.55 * step + 1e-5), float(err.max())

    def test_group_size_snaps_to_divisor(self):
        assert Q.snap_group_size(128, 64) == 64
        assert Q.snap_group_size(96, 64) == 32
        assert Q.snap_group_size(64, 256) == 64
        with pytest.raises(ValueError):
            Q.snap_group_size(33, 8)
        q4 = Q.pack_int4(jnp.ones((96, 8)), group_size=64)
        assert q4.group_size == 32

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError):
            Q.pack_int4(jnp.ones((4, 4, 4)))

    @pytest.mark.parametrize("name", GOLDEN)
    def test_projection_container_bytes_ratio(self, name):
        """The acceptance bar: w4a8 projection containers price at
        <= 0.55x of the w8a8 int8 layout, zoo-wide."""
        arch, params, _ = _setup(name)
        b8 = _proj_bytes(eng_lib.quantize_params(params, W8))
        b4 = _proj_bytes(eng_lib.quantize_params(params, W4))
        assert b8 > 0 and b4 > 0
        assert b4 / b8 <= 0.55, (name, b4 / b8)

    def test_w4_quantize_params_packs_projections_only(self):
        arch, params, _ = _setup("qwen2-1.5b")
        qp = eng_lib.quantize_params(params, W4)
        seen = {"q4": 0, "q8": 0}

        def rec(node, name=None):
            if isinstance(node, dict):
                for k, v in node.items():
                    rec(v, k)
            elif isinstance(node, (list, tuple)) \
                    and not hasattr(node, "_fields"):
                for v in node:
                    rec(v, name)
            elif isinstance(node, Q.Q4Tensor):
                assert name in eng_lib.W4_KEYS, name
                seen["q4"] += 1
            elif isinstance(node, Q.QTensor):
                assert name not in eng_lib.W4_KEYS, name
                seen["q8"] += 1

        rec(qp)
        assert seen["q4"] == 7 * arch.n_layers
        assert seen["q8"] > 0               # embed/head stay int8


# ---------------------------------------------------------------------------
# The int4 GEMM: ref oracle == pallas kernel, bitwise
# ---------------------------------------------------------------------------

class TestInt4GEMM:
    def _inputs(self, m=8, k=128, n=64, gs=32, seed=0):
        rng = np.random.default_rng(seed)
        a_q = jnp.asarray(rng.integers(-127, 128, (m, k)).astype(np.int8))
        a_scale = jnp.asarray(
            rng.uniform(0.01, 0.1, (m, 1)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        q4 = Q.pack_int4(w, gs)
        bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        return a_q, a_scale, q4, bias

    def test_mac_core_bitwise(self):
        """The group-dot value stream itself -- int32 partial sums +
        per-group f32 combine -- is bit-identical inside and outside the
        kernel (no a_scale/bias, so no FMA fusion in play)."""
        a_q, _, q4, _ = self._inputs()
        ones = jnp.ones((8, 1), jnp.float32)
        want = ref.matmul_int4_fused(a_q, q4.packed, ones, q4.scale,
                                     q4.zero, None, "none")
        got = conv_pe.matmul_int4_fused(a_q, q4.packed, ones, q4.scale,
                                        q4.zero, None, "none",
                                        bm=8, bn=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    @pytest.mark.parametrize("act", ["none", "relu"])
    def test_pallas_matches_ref_one_ulp(self, act):
        a_q, a_scale, q4, bias = self._inputs()
        want = ref.matmul_int4_fused(a_q, q4.packed, a_scale, q4.scale,
                                     q4.zero, bias, act)
        got = conv_pe.matmul_int4_fused(a_q, q4.packed, a_scale, q4.scale,
                                        q4.zero, bias, act,
                                        bm=8, bn=64, interpret=True)
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   rtol=1e-6, atol=1e-4)

    def test_pallas_fused_residual_matches_ref_chain(self):
        a_q, a_scale, q4, bias = self._inputs(seed=1)
        r = jnp.asarray(np.random.default_rng(2).normal(
            size=(8, 64)).astype(np.float32))
        base = ref.matmul_int4_fused(a_q, q4.packed, a_scale, q4.scale,
                                     q4.zero, bias, "none")
        got = conv_pe.matmul_int4_fused(a_q, q4.packed, a_scale, q4.scale,
                                        q4.zero, bias, "none",
                                        residual=r, res_scale=1.0,
                                        bm=8, bn=64, interpret=True)
        np.testing.assert_allclose(np.asarray(base + r), np.asarray(got),
                                   rtol=1e-6, atol=1e-4)

    def test_linear_dispatch_ref_vs_pallas(self):
        """ops.linear on a Q4Tensor weight: the dynamic w4a8 path agrees
        across backends to one-ulp (one GEMM definition; only the float
        epilogue's FMA fusion differs)."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
        q4 = Q.pack_int4(w, 64)
        bias = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        a = ops.linear(x, q4, bias, "gelu", W4)
        b = ops.linear(x, q4, bias, "gelu",
                       EngineConfig(quant="w4a8", backend="pallas",
                                    interpret=True))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-4)

    def test_q4_weight_rejected_outside_w4a8(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
        q4 = Q.pack_int4(jnp.asarray(
            rng.normal(size=(128, 64)).astype(np.float32)), 64)
        with pytest.raises(ValueError):
            ops.linear(x, q4, None, "none", W8)


# ---------------------------------------------------------------------------
# Fused QKV / gate-up projections
# ---------------------------------------------------------------------------

class TestFusedProjections:
    @pytest.mark.parametrize("name", GOLDEN)
    def test_rewrites_qkv_and_gate_up(self, name):
        arch, _, _ = _setup(name)
        g = compiler.lower_transformer(arch)
        fg, _ = passes.fuse_projections(g)
        nl = arch.n_layers
        assert fg.count(LinearGroupOp) == 2 * nl    # qkv + gate/up per layer
        assert fg.count(ViewOp) == 5 * nl
        members = [len(n.ws) for n in fg.nodes if isinstance(n, LinearGroupOp)]
        assert sorted(set(members)) == [2, 3]
        # the 3 q/k/v launches and 2 gate/up launches become 1 each
        assert passes.launch_count(fg) == passes.launch_count(g) - 3 * nl
        stats = passes.fusion_stats(fg)
        assert stats["fused_projections"] == 2 * nl
        assert stats["projection_members"] == 5 * nl
        # every AttnOp reads three views of one group; every MulOp two
        views = {n.id: n for n in fg.nodes if isinstance(n, ViewOp)}
        for n in fg.nodes:
            if isinstance(n, AttnOp):
                assert [views[i].index for i in n.inputs[:3]] == [0, 1, 2]
            if isinstance(n, MulOp) and all(i in views for i in n.inputs):
                assert [views[i].index for i in n.inputs] == [0, 1]

    @pytest.mark.parametrize("name", GOLDEN)
    def test_fused_dynamic_program_bitwise(self, name):
        """fuse=True compiles member-wise float composition on the ref
        path, so fused and unfused programs agree bit for bit."""
        arch, params, toks = _setup(name)
        fused = compiler.compile_lm(arch)
        plain = compiler.compile_lm(arch, fuse=False)
        assert fused is not plain
        assert fused.graph.count(LinearGroupOp) > 0
        assert plain.graph.count(LinearGroupOp) == 0
        a = compiler.execute(fused, params, toks, ENG)
        b = compiler.execute(plain, params, toks, ENG)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_full_and_decode_graphs_fuse_identically(self):
        """Calibration transfers by node id: the fused decode graph must
        mirror the fused full graph node for node."""
        arch, _, _ = _setup("qwen2-1.5b")
        full, _ = passes.fuse_projections(compiler.lower_transformer(arch))
        dec, _ = passes.fuse_projections(
            compiler.lower_transformer(arch, mode="decode"))
        assert len(full.nodes) == len(dec.nodes)
        for f, d in zip(full.nodes, dec.nodes):
            assert type(f) is type(d) and f.inputs == d.inputs

    def test_group_launch_is_one_concat_gemm_when_quantized(self):
        """On the pallas int8 path linear_group concatenates the members
        into ONE launch; its sliced outputs equal the member-wise calls."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
        ws, bs = [], []
        for n in (64, 32, 32):
            w = jnp.asarray(rng.normal(size=(128, n)).astype(np.float32))
            ws.append(Q.quantize(w, axis=1))
            bs.append(jnp.asarray(rng.normal(size=(n,)).astype(np.float32)))
        cfg = EngineConfig(quant="w8a8", backend="pallas", interpret=True)
        fused = ops.linear_group(x, ws, bs, ("none", "none", "none"), cfg)
        single = tuple(ops.linear(x, w, b, "none", cfg)
                       for w, b in zip(ws, bs))
        for f, s in zip(fused, single):
            np.testing.assert_array_equal(np.asarray(f), np.asarray(s))


# ---------------------------------------------------------------------------
# w4a8 compiled decode: golden logit-gap bound, zoo x {ref, pallas}
# ---------------------------------------------------------------------------

class TestW4Decode:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("name", GOLDEN)
    def test_w4_decode_tracks_w8_static_full(self, name, backend):
        """Teacher-forced w4a8 compiled decode tracks the static full
        programs.  The sharp check is self-consistency: decode vs the
        w4-quantized full program (same weights) must sit inside the
        golden drift bound.  Against the w8 full program only a coarse
        bound applies -- on the reduced arch (d_model=128) the int4
        weight error itself is ~2x the w8 drift budget, so a tight
        w8-vs-w4 bound would fail for reasons unrelated to the decode
        path (measured: w4-full vs w8-full gap ~0.30 at max|logit|
        ~0.96, while decode vs w4-full stays under 0.05)."""
        arch, params, _ = _setup(name)
        EXTRA = 3
        rng = np.random.default_rng(3)
        toks = jnp.array(rng.integers(0, arch.vocab_size,
                                      (B, L + EXTRA)).astype(np.int32))
        scales = compiler.calibrate_lm(arch, params, [toks])
        w8 = EngineConfig(quant="w8a8", backend=backend, interpret=True)
        w4 = EngineConfig(quant="w4a8", backend=backend, interpret=True)
        fprog = compiler.compile_lm(arch, scales=scales)
        pprog = compiler.compile_lm(arch, scales=scales, mode="prefill")
        dprog = compiler.compile_lm(arch, scales=scales, mode="decode")
        qp8 = eng_lib.quantize_params(params, w8)
        qp4 = eng_lib.quantize_params(params, w4)
        full8 = np.asarray(compiler.execute(fprog, qp8, toks, w8))
        full4 = np.asarray(compiler.execute(fprog, qp4, toks, w4))
        kvs = {}
        compiler.execute(pprog, qp4, toks[:, :L], w4, collect=kvs)
        cache = _cache(arch, B, L + EXTRA, w4)
        layers = [T._kv_store(cache["layers"][i], *kvs[i], 0, w4)
                  for i in range(arch.n_layers)]
        cache = {"layers": layers, "pos": jnp.asarray(L, jnp.int32)}
        sharp = 0.15 * np.max(np.abs(full8))
        coarse = 0.60 * np.max(np.abs(full8))
        for t in range(EXTRA):
            ld, cache = compiler.execute_decode(
                dprog, qp4, cache, toks[:, L + t:L + t + 1], w4)
            assert np.isfinite(np.asarray(ld)).all()
            ld0 = np.asarray(ld[:, 0])
            gap4 = float(np.max(np.abs(ld0 - full4[:, L + t])))
            gap8 = float(np.max(np.abs(ld0 - full8[:, L + t])))
            assert gap4 <= sharp, (name, backend, t, gap4, sharp)
            assert gap8 <= coarse, (name, backend, t, gap8, coarse)

    def test_serve_engine_w4_roundtrip(self):
        """ServeEngine under w4a8: both programs compile static, the w4
        calib id differs from w8 (distinct ProgramCache lines), and the
        served ids match the eager float reference's shape contract."""
        from repro.serve.engine import ServeEngine

        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(0)
        calib = [jnp.array(rng.integers(0, arch.vocab_size,
                                        (2, 8)).astype(np.int32))]
        prompts = [rng.integers(0, arch.vocab_size, size=6)
                   for _ in range(2)]
        se4 = ServeEngine(arch, params, W4, batch_size=2, max_seq=32,
                          calib_batches=calib, prefill_len=6)
        se8 = ServeEngine(arch, params, W8, batch_size=2, max_seq=32,
                          calib_batches=calib, prefill_len=6)
        assert se4.calib_id != se8.calib_id
        assert se4.calib_id.endswith(":w4g64")
        outs = se4.generate(prompts, max_new_tokens=3)
        assert se4.cache.stats.misses == 2          # prefill + decode
        d = se4.decode_program()
        assert d.static and d.kind == "decode"
        assert len(outs) == 2 and all(len(o) == 3 for o in outs)


# ---------------------------------------------------------------------------
# Grouped conv == depthwise: the baseline path (satellite)
# ---------------------------------------------------------------------------

class TestGroupedConvBaseline:
    def _inputs(self, c=8, hw=8, k=3, seed=0):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(2, hw, hw, c)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, k, c)).astype(np.float32))
        bias = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
        return x, w, bias

    def test_baseline_matches_direct_depthwise(self):
        """The no-DWC-engine lowering now walks the depthwise taps; its
        values equal the naive per-channel conv (dropping the diagonal
        GEMM's structural zeros is IEEE-exact)."""
        x, w, bias = self._inputs()
        cfg = EngineConfig(quant="none", backend="ref",
                           use_dwc_engine=False)
        got = np.asarray(ops.dwc2d(x, w, bias, 1, "SAME", "none", cfg))
        want = np.asarray(jax.lax.conv_general_dilated(
            x, w[:, :, None, :], (1, 1), "SAME",
            feature_group_count=x.shape[-1],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))) + np.asarray(bias)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("stride", [1, 2])
    def test_baseline_matches_dwc_engine_path(self, stride):
        x, w, bias = self._inputs(seed=1)
        base = EngineConfig(quant="none", backend="ref",
                            use_dwc_engine=False)
        dwc = EngineConfig(quant="none", backend="ref")
        a = np.asarray(ops.dwc2d(x, w, bias, stride, "SAME", "relu", base))
        b = np.asarray(ops.dwc2d(x, w, bias, stride, "SAME", "relu", dwc))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_quantized_baseline_matches_dwc_engine_path(self):
        x, w, bias = self._inputs(seed=2)
        wq = Q.quantize(w, axis=2)
        base = EngineConfig(quant="w8a8", backend="ref",
                            use_dwc_engine=False)
        dwc = EngineConfig(quant="w8a8", backend="ref")
        a = np.asarray(ops.dwc2d(x, wq, bias, 1, "SAME", "none", base))
        b = np.asarray(ops.dwc2d(x, wq, bias, 1, "SAME", "none", dwc))
        # the baseline pays no activation quantization (float math over
        # dequantized weights) while the engine quantizes dynamically;
        # the gap is bounded by the int8 step accumulated over k*k taps
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=2e-1)
