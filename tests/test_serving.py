"""Serving invariants: prefill+decode == full forward (teacher forcing),
int8 KV cache accuracy, ring-buffer local attention, quantized engines."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro import configs
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.params import init_params, is_spec

ENG = EngineConfig(quant="none", backend="ref")
DENSE = ["granite-8b", "qwen2-1.5b", "gemma2-2b", "minitron-4b",
         "recurrentgemma-2b", "falcon-mamba-7b"]
MOE = ["grok-1-314b", "granite-moe-1b-a400m"]


def _cache(schema):
    return jtu.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), schema,
                        is_leaf=is_spec)


def _run_consistency(name, rng, eng=ENG, atol=2e-2, allow_frac=0.0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    B, L, EXTRA = 2, 12, 4
    tokens = jnp.array(rng.integers(0, arch.vocab_size,
                                    (B, L + EXTRA)).astype(np.int32))
    full, _ = T.forward(params, {"tokens": tokens}, arch, ENG,
                        compute_dtype=jnp.float32)
    cache = _cache(T.cache_schema(arch, B, L + EXTRA, eng))
    lp, cache = T.prefill(params, cache, {"tokens": tokens[:, :L]}, arch, eng,
                          compute_dtype=jnp.float32)
    preds = [np.array(lp[:, 0])]
    want = [np.array(full[:, L - 1])]
    for t in range(EXTRA):
        ld, cache = T.decode(params, cache, tokens[:, L + t:L + t + 1],
                             arch, eng, compute_dtype=jnp.float32)
        preds.append(np.array(ld[:, 0]))
        want.append(np.array(full[:, L + t]))
    got, want = np.stack(preds), np.stack(want)
    bad = np.abs(got - want) > (atol + atol * np.abs(want))
    frac = bad.mean()
    assert frac <= allow_frac, f"{name}: {frac:.4%} elements out of tol"


@pytest.mark.parametrize("name", DENSE)
def test_prefill_decode_consistency(name, rng):
    _run_consistency(name, rng)


@pytest.mark.parametrize("name", MOE)
def test_prefill_decode_consistency_moe(name, rng):
    # top-k routing has measure-zero ties that flip under different program
    # fusions; allow a vanishing mismatch fraction.
    _run_consistency(name, rng, allow_frac=0.005)


def test_consistency_under_w8a8(rng):
    """Quantized serving drifts from the f32 oracle only boundedly."""
    arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    eng = EngineConfig(quant="w8a8", backend="ref")
    qparams = eng_lib.quantize_params(params, eng)
    B, L = 2, 12
    tokens = jnp.array(rng.integers(0, arch.vocab_size,
                                    (B, L)).astype(np.int32))
    full, _ = T.forward(params, {"tokens": tokens}, arch, ENG,
                        compute_dtype=jnp.float32)
    cache = _cache(T.cache_schema(arch, B, L, eng))
    lp, _ = T.prefill(qparams, cache, {"tokens": tokens}, arch, eng,
                      compute_dtype=jnp.float32)
    # rank agreement on the top prediction is the serving-level criterion
    agree = (np.argmax(np.array(lp[:, 0]), -1)
             == np.argmax(np.array(full[:, -1]), -1)).mean()
    assert agree >= 0.5
    rel = (np.abs(np.array(lp[:, 0]) - np.array(full[:, -1])).mean()
           / np.abs(np.array(full[:, -1])).mean())
    assert rel < 0.25


def test_int8_kv_cache_close(rng):
    arch = configs.reduced(configs.get_arch("granite-8b"))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    eng8 = EngineConfig(quant="none", backend="ref", kv_cache_dtype="int8")
    B, L = 2, 12
    tokens = jnp.array(rng.integers(0, arch.vocab_size,
                                    (B, L)).astype(np.int32))
    full, _ = T.forward(params, {"tokens": tokens}, arch, ENG,
                        compute_dtype=jnp.float32)
    cache = _cache(T.cache_schema(arch, B, L, eng8))
    lp, cache = T.prefill(params, cache, {"tokens": tokens}, arch, eng8,
                          compute_dtype=jnp.float32)
    err = np.abs(np.array(lp[:, 0]) - np.array(full[:, -1])).max()
    assert err < 0.5
    assert cache["layers"][0]["k"].dtype == jnp.int8


def test_ring_buffer_wraps(rng):
    """Local-attention ring cache: decoding past the window stays causal and
    consistent with full attention over the window."""
    import dataclasses
    arch = dataclasses.replace(
        configs.reduced(configs.get_arch("gemma2-2b")), local_window=8)
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    B, total = 1, 20
    tokens = jnp.array(rng.integers(0, arch.vocab_size,
                                    (B, total)).astype(np.int32))
    full, _ = T.forward(params, {"tokens": tokens}, arch, ENG,
                        compute_dtype=jnp.float32)
    cache = _cache(T.cache_schema(arch, B, total, ENG))
    lp, cache = T.prefill(params, cache, {"tokens": tokens[:, :4]}, arch, ENG,
                          compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.array(lp[:, 0]), np.array(full[:, 3]),
                               rtol=2e-2, atol=2e-2)
    for t in range(4, total):               # decode well past the window
        ld, cache = T.decode(params, cache, tokens[:, t:t + 1], arch, ENG,
                             compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.array(ld[:, 0]), np.array(full[:, t]),
                                   rtol=3e-2, atol=3e-2)


def test_whisper_serving(rng):
    arch = configs.reduced(configs.get_arch("whisper-tiny"))
    params = init_params(W.whisper_schema(arch, max_dec_pos=64),
                         jax.random.PRNGKey(0))
    B, L, EXTRA = 2, 8, 3
    enc = jnp.array(rng.normal(size=(B, arch.encoder_seq,
                                     arch.d_model)).astype(np.float32))
    tok = jnp.array(rng.integers(0, arch.vocab_size,
                                 (B, L + EXTRA)).astype(np.int32))
    full, _ = W.forward(params, {"enc_embeds": enc, "tokens": tok}, arch, ENG,
                        compute_dtype=jnp.float32)
    cache = _cache(W.whisper_cache_schema(arch, B, L + EXTRA, ENG))
    lp, cache = W.prefill(params, cache,
                          {"enc_embeds": enc, "tokens": tok[:, :L]},
                          arch, ENG, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.array(lp[:, 0]), np.array(full[:, L - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(EXTRA):
        ld, cache = W.decode(params, cache, tok[:, L + t:L + t + 1], arch,
                             ENG, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.array(ld[:, 0]),
                                   np.array(full[:, L + t]),
                                   rtol=2e-2, atol=2e-2)


def test_serve_engine_end_to_end(rng):
    from repro.serve.engine import ServeEngine
    arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    eng = EngineConfig(quant="w8a8", backend="ref")
    se = ServeEngine(arch, params, eng, batch_size=2, max_seq=48)
    prompts = [rng.integers(0, arch.vocab_size, size=5) for _ in range(3)]
    outs = se.generate(prompts, max_new_tokens=4)
    assert len(outs) == 3
    assert all(len(o) == 4 for o in outs)
    # greedy decoding is deterministic
    outs2 = se.generate(prompts, max_new_tokens=4)
    for a, b in zip(outs, outs2):
        np.testing.assert_array_equal(a, b)
