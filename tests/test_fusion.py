"""Epilogue fusion: the graph rewrite, fused-kernel parity, and the
contention-aware slack leveling.

The contract under test:

  * `passes.fuse_epilogues` collapses every single-consumer Conv/DWC ->
    {residual Add, avg/global/max pool} chain into ONE fused node, and the
    rewritten graph is a valid renumbered topological op list;
  * fused execution is BIT-IDENTICAL to the unfused program on the static
    int8 path (the kernels quantize-dequantize in-register at the absorbed
    edges' scales) and within golden tolerance on the dynamic f32 path,
    across the CNN zoo x {ref, pallas} and under random property configs;
  * `level_schedule(policy="slack")` produces valid levelings that never
    raise the worst per-level same-unit op count above ASAP's and never
    lower per-level engine occupancy below ASAP's;
  * the launch accounting behind the serving benchmark: kernel dispatches
    per ResNet-style image drop >= 25% after fusion.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro import compiler
from repro.compiler import passes
from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, Epilogue,
                                  Graph, InputOp, LinearOp, PoolOp)
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.core.config import CNNConfig, ConvSpec as C, EngineConfig
from repro.models import cnn
from repro.models.params import init_params

# fused-chain-bearing kinds first so the shim's prefix sampling hits them
KINDS = ("bottleneck", "inverted", "conv", "pool", "dwsep", "fire")


def _stage(kind: str, out_ch: int, stride: int) -> C:
    if kind == "pool":
        return C("pool", kernel=2, stride=2)
    if kind == "inverted":
        return C(kind, out_ch=out_ch, kernel=3, stride=stride, repeat=1,
                 expand=2)
    return C(kind, out_ch=out_ch, kernel=3, stride=stride, repeat=1)


def _random_cfg(kinds, stem_ch: int, out_ch: int, stride: int) -> CNNConfig:
    stages = tuple(_stage(k, out_ch, stride) for k in kinds)
    name = f"fuse_{'-'.join(kinds)}_{stem_ch}_{out_ch}_{stride}"
    return CNNConfig(name=name, input_hw=32, input_ch=3, stem_kernel=3,
                     stem_stride=2, stem_ch=stem_ch, stages=stages,
                     num_classes=8)


def _setup(cfg: CNNConfig, batch: int = 2, seed: int = 0):
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(batch, cfg.input_hw, cfg.input_hw, cfg.input_ch)
    ).astype(np.float32) * 0.5)
    return params, x


# ---------------------------------------------------------------------------
# The rewrite itself
# ---------------------------------------------------------------------------

class TestFuseEpilogues:
    def test_resnet_chains_collapse(self):
        """Every bottleneck add, the stem max-pool tail and the GAP tail
        fuse; the rewritten graph has no standalone adds left."""
        g = compiler.build_graph(CNN_ZOO["resnet50"])
        fg, _ = compiler.fuse_epilogues(g)
        s = compiler.fusion_stats(fg)
        assert s["misc_adds"] == 0
        assert s["fused_adds"] == 16              # 3+4+6+3 bottlenecks
        assert s["fused_pools"] == 2              # stem->maxpool + add->GAP
        assert fg.count(PoolOp) == 0
        # valid renumbered topological graph
        assert all(n.id == i for i, n in enumerate(fg.nodes))
        for n in fg.nodes:
            assert all(i < n.id for i in n.inputs)
        compiler.validate_schedule(fg, compiler.level_schedule(fg))

    def test_resnet_launch_drop_at_least_25_percent(self):
        """The acceptance gate: kernel dispatches per ResNet-style image
        drop >= 25% (fused chains execute as single launches)."""
        for name in ("resnet50", "resnet152"):
            g = compiler.build_graph(CNN_ZOO[name])
            fg, _ = compiler.fuse_epilogues(g)
            unf = compiler.launch_count(g)
            fus = compiler.launch_count(fg)
            assert 1.0 - fus / unf >= 0.25, (name, fus, unf)
            st = compiler.fusion_stats(fg)
            assert st["materialized_edges"] < \
                compiler.fusion_stats(g)["materialized_edges"]

    def test_residual_operand_is_last_input(self):
        g = compiler.build_graph(CNN_ZOO["resnet50"])
        fg, _ = compiler.fuse_epilogues(g)
        for n in fg.nodes:
            if getattr(n, "epilogue", None) is not None and n.epilogue.add:
                assert len(n.inputs) == 2
                assert isinstance(n, (ConvOp, DwcOp))

    def test_multi_consumer_edges_do_not_fuse(self):
        """A conv whose output feeds two consumers keeps its launch: the
        fire squeeze conv (feeding both expand convs) never fuses."""
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        fg, _ = compiler.fuse_epilogues(g)
        # all fire-module convs survive; only the stem->maxpool chain fuses
        assert compiler.fusion_stats(fg)["fused_ops"] == 1
        stem = fg.nodes[1]
        assert isinstance(stem, ConvOp) and stem.first_layer
        assert stem.epilogue is not None and stem.epilogue.pool == "max"

    def test_scales_remap_and_interiors_baked(self):
        cfg = dataclasses.replace(CNN_ZOO["resnet50"], input_hw=32)
        params, x = _setup(cfg)
        g = compiler.build_graph(cfg)
        scales = compiler.calibrate(g, params, [x], cfg)
        fg, fscales = compiler.fuse_epilogues(g, scales)
        assert set(fscales) == {n.id for n in fg.nodes}
        for n in fg.nodes:
            ep = getattr(n, "epilogue", None)
            if ep is None:
                continue
            assert ep.mid_scale > 0.0
            if ep.add and ep.pool != "none":
                assert ep.add_scale > 0.0
            if ep.pool == "max":
                # scale-preserving tail: output edge inherits pre-pool scale
                pre = ep.add_scale if ep.add else ep.mid_scale
                assert fscales[n.id] == pre

    def test_dynamic_program_cache_distinguishes_fuse_flag(self):
        cfg = dataclasses.replace(CNN_ZOO["squeezenet"], input_hw=32)
        fused = compiler.compile_cnn(cfg)
        unfused = compiler.compile_cnn(cfg, fuse=False)
        assert fused.graph is not unfused.graph
        assert compiler.fusion_stats(fused.graph)["fused_ops"] > 0
        assert compiler.fusion_stats(unfused.graph)["fused_ops"] == 0
        # and the cache returns the right one on re-request
        assert compiler.compile_cnn(cfg).graph is fused.graph
        assert compiler.compile_cnn(cfg, fuse=False).graph is unfused.graph

    def test_dwc_chain_fuses(self):
        """A hand-built dwc -> add -> global pool chain fuses into the DWC
        node (the engine the paper extends for depthwise models)."""
        g = Graph(nodes=(
            InputOp(0, ()),
            DwcOp(1, (0,), w=("wd",), b=("bd",), act="relu"),
            ConvOp(2, (0,), w=("wp",), b=("bp",)),
            AddOp(3, (1, 2), act="relu"),
            PoolOp(4, (3,), pool="global"),
            LinearOp(5, (4,), w=("head_w",), b=("head_b",)),
        ), output=5)
        fg, _ = compiler.fuse_epilogues(g)
        fused = [n for n in fg.nodes
                 if getattr(n, "epilogue", None) is not None]
        assert len(fused) == 1 and isinstance(fused[0], DwcOp)
        ep = fused[0].epilogue
        assert ep.add and ep.add_act == "relu" and ep.pool == "global"
        assert len(fg.nodes) == 4                # input, conv, fused, head
        compiler.validate_schedule(fg, compiler.level_schedule(fg))

    def test_per_channel_residual_edge_collapses(self):
        """An edge a fused DwcOp consumes as its RESIDUAL operand is not a
        channelwise-consumed edge: under per-channel calibration its scale
        must collapse to the per-tensor max (the epilogue's residual add
        carries a scalar scale), and the fused program must execute."""
        from repro.compiler.executor import _finish_program

        g = Graph(nodes=(
            InputOp(0, ()),
            ConvOp(1, (0,), w=("wp",), b=("bp",)),
            DwcOp(2, (1,), w=("wd",), b=("bd",), act="relu"),
            AddOp(3, (2, 1), act="relu"),         # conv1 consumed twice:
            LinearOp(4, (3,), w=("head_w",)),     # dwc input AND residual
        ), output=4)
        c = 8
        rng = np.random.default_rng(0)
        params = {
            "wp": jnp.asarray(rng.normal(size=(1, 1, c, c)),
                              jnp.float32) * 0.3,
            "bp": jnp.zeros((c,), jnp.float32),
            "wd": jnp.asarray(rng.normal(size=(3, 3, c)), jnp.float32) * 0.3,
            "bd": jnp.zeros((c,), jnp.float32),
            "head_w": jnp.asarray(rng.normal(size=(c, 4)), jnp.float32) * 0.3,
        }
        x = jnp.asarray(rng.normal(size=(2, 8, 8, c)), jnp.float32) * 0.5
        scales = compiler.calibrate(g, params, [x], None,
                                    granularity="per_channel")
        fg, fscales = compiler.fuse_epilogues(g, scales)
        fused = [n for n in fg.nodes
                 if getattr(n, "epilogue", None) is not None]
        assert len(fused) == 1 and fused[0].epilogue.add
        prog = _finish_program(fg, None, fscales, True,
                               granularity="per_channel")
        # conv1's edge feeds the fused DwcOp as data AND residual: scalar
        conv_id = next(n.id for n in fg.nodes
                       if isinstance(n, ConvOp))
        assert isinstance(prog.plan.out_scale[conv_id], float)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qp = eng_lib.quantize_params(params, eng)
        out = compiler.execute(prog, qp, x, eng)
        assert np.isfinite(np.array(out)).all()
        # and matches the unfused per-channel program bitwise
        pu = _finish_program(g, None, scales, True,
                             granularity="per_channel")
        np.testing.assert_array_equal(
            np.array(out), np.array(compiler.execute(pu, qp, x, eng)))

    def test_idempotent_on_fused_graphs(self):
        g = compiler.build_graph(CNN_ZOO["resnet50"])
        fg, _ = compiler.fuse_epilogues(g)
        fg2, _ = compiler.fuse_epilogues(fg)
        assert fg2 is fg or len(fg2.nodes) == len(fg.nodes)


# ---------------------------------------------------------------------------
# Execution parity: fused == unfused (bitwise int8 / golden-tolerance f32)
# ---------------------------------------------------------------------------

class TestFusedExecutionParity:
    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=3),
           out_ch=st.sampled_from([8, 16]),
           stride=st.sampled_from([1, 2]))
    def test_static_int8_bit_identical_property(self, kinds, out_ch, stride):
        """Random configs: the fused static program's logits match the
        unfused program's bit for bit on the ref backend."""
        cfg = _random_cfg(kinds, 4, out_ch, stride)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        fused = compiler.compile_calibrated(cfg, params, [x])
        unfused = compiler.compile_calibrated(cfg, params, [x], fuse=False)
        a = np.array(compiler.execute(fused, qparams, x, eng))
        b = np.array(compiler.execute(unfused, qparams, x, eng))
        np.testing.assert_array_equal(a, b)

    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=3),
           out_ch=st.sampled_from([8, 16]))
    def test_dynamic_f32_parity_property(self, kinds, out_ch):
        cfg = _random_cfg(kinds, 4, out_ch, 1)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="none", backend="ref")
        a = np.array(compiler.execute(compiler.compile_cnn(cfg),
                                      params, x, eng))
        b = np.array(compiler.execute(compiler.compile_cnn(cfg, fuse=False),
                                      params, x, eng))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("name", sorted(CNN_ZOO))
    def test_zoo_static_bit_identical(self, name, backend, fusion_golden):
        """Whole zoo x both backends: fused static int8 execution is
        bit-identical to the unfused program (the in-register qdq points
        reproduce the unfused dataflow exactly)."""
        cfg, params, x, fused, unfused = fusion_golden(name)
        eng = EngineConfig(quant="w8a8", backend=backend, interpret=True)
        qparams = eng_lib.quantize_params(params, eng)
        a = np.array(compiler.execute(fused, qparams, x, eng))
        b = np.array(compiler.execute(unfused, qparams, x, eng))
        assert np.isfinite(a).all()
        np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("name", ["resnet50", "mobilenetv2"])
    def test_zoo_dynamic_pallas_tolerance(self, name):
        """Dynamic (per-call quant) path on the fused pallas kernels stays
        within golden tolerance of the unfused dynamic program."""
        cfg = dataclasses.replace(CNN_ZOO[name], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="pallas", interpret=True)
        qparams = eng_lib.quantize_params(params, eng)
        a = np.array(compiler.execute(compiler.compile_cnn(cfg),
                                      qparams, x, eng))
        b = np.array(compiler.execute(compiler.compile_cnn(cfg, fuse=False),
                                      qparams, x, eng))
        gap = np.max(np.abs(a - b))
        assert gap <= 0.05 * np.max(np.abs(b)) + 1e-6, gap

    def test_fused_static_jits_and_schedules(self):
        """Fused programs jit and execute bit-identically scheduled vs
        sequential (the executor parity harness covers fused nodes too)."""
        cfg = dataclasses.replace(CNN_ZOO["resnet50"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        assert compiler.fusion_stats(prog.graph)["fused_ops"] > 0
        seq = dataclasses.replace(prog, schedule=None)
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(seq, qparams, x, eng))
        np.testing.assert_array_equal(a, b)
        # jit-vs-jit (XLA fusion can flip requant-boundary rounding against
        # the eager run, like the folding suite notes): scheduled and
        # sequential traces still agree bitwise
        ja = np.array(jax.jit(
            lambda p, im: compiler.execute(prog, p, im, eng))(qparams, x))
        jb = np.array(jax.jit(
            lambda p, im: compiler.execute(seq, p, im, eng))(qparams, x))
        np.testing.assert_array_equal(ja, jb)
        assert np.isfinite(ja).all()

    def test_dwc_fused_chain_executes(self):
        """The hand-built dwc->add->GAP chain runs fused on both backends
        and matches the unfused graph bitwise (static int8)."""
        from repro.compiler.executor import _finish_program

        unfused = Graph(nodes=(
            InputOp(0, ()),
            DwcOp(1, (0,), w=("wd",), b=("bd",), act="relu"),
            ConvOp(2, (0,), w=("wp",), b=("bp",)),
            AddOp(3, (1, 2), act="relu"),
            PoolOp(4, (3,), pool="global"),
            LinearOp(5, (4,), w=("head_w",), b=("head_b",)),
        ), output=5)
        c = 8
        rng = np.random.default_rng(0)
        params = {
            "wd": jnp.asarray(rng.normal(size=(3, 3, c)), jnp.float32) * 0.3,
            "bd": jnp.zeros((c,), jnp.float32),
            "wp": jnp.asarray(rng.normal(size=(1, 1, c, c)),
                              jnp.float32) * 0.3,
            "bp": jnp.zeros((c,), jnp.float32),
            "head_w": jnp.asarray(rng.normal(size=(c, 4)), jnp.float32) * 0.3,
            "head_b": jnp.zeros((4,), jnp.float32),
        }
        x = jnp.asarray(rng.normal(size=(2, 8, 8, c)), jnp.float32) * 0.5
        scales = compiler.calibrate(unfused, params, [x], None)
        fg, fscales = compiler.fuse_epilogues(unfused, scales)
        pu = _finish_program(unfused, None, scales, True)
        pf = _finish_program(fg, None, fscales, True)
        for backend in ("ref", "pallas"):
            eng = EngineConfig(quant="w8a8", backend=backend, interpret=True)
            qp = eng_lib.quantize_params(params, eng)
            a = np.array(compiler.execute(pf, qp, x, eng))
            b = np.array(compiler.execute(pu, qp, x, eng))
            np.testing.assert_array_equal(a, b)

    def test_serving_engine_serves_fused_programs(self):
        """CNNServeEngine binds fused programs from the ProgramCache by
        default, and its results match direct fused execution."""
        from repro.serve.cnn_engine import CNNServeEngine

        cfg = dataclasses.replace(CNN_ZOO["resnet50"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        engine = CNNServeEngine(eng, wave_size=2)
        engine.register(cfg, params, calib_batches=[x])
        got = engine.infer(cfg.name, np.asarray(x))
        prog = engine.program_for(cfg.name)
        assert compiler.fusion_stats(prog.graph)["fused_ops"] > 0
        qparams = eng_lib.quantize_params(params, eng)
        want = np.array(jax.jit(
            lambda p, im: compiler.execute(prog, p, im, eng))(
                compiler.fold_weight_layouts(prog.graph, qparams), x))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Slack (contention-aware) leveling
# ---------------------------------------------------------------------------

def _max_unit_width(g, sched):
    return sched.stats["max_unit_width"]


class TestSlackLeveling:
    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=4),
           stem_ch=st.sampled_from([4, 8]),
           out_ch=st.sampled_from([8, 16]),
           stride=st.sampled_from([1, 2]))
    def test_valid_and_never_worse_than_asap(self, kinds, stem_ch, out_ch,
                                             stride):
        """Property: on fused and unfused random graphs, slack levelings
        validate, keep the critical-path length, never raise the worst
        same-unit width above ASAP, and never lower engine occupancy."""
        g = compiler.build_graph(_random_cfg(kinds, stem_ch, out_ch, stride))
        for gg in (g, compiler.fuse_epilogues(g)[0]):
            a = compiler.level_schedule(gg, "asap")
            s = compiler.level_schedule(gg, "slack")
            compiler.validate_schedule(gg, s)
            assert s.n_levels == a.n_levels
            assert _max_unit_width(gg, s) <= _max_unit_width(gg, a)
            assert (compiler.engine_occupancy(gg, s)["occupancy"]
                    >= compiler.engine_occupancy(gg, a)["occupancy"] - 1e-12)

    def test_zoo_slack_occupancy_at_least_asap(self):
        for name, cfg in CNN_ZOO.items():
            g, _ = compiler.fuse_epilogues(compiler.build_graph(cfg))
            a = compiler.level_schedule(g, "asap")
            s = compiler.level_schedule(g, "slack")
            compiler.validate_schedule(g, s)
            assert _max_unit_width(g, s) <= _max_unit_width(g, a), name
            assert (compiler.engine_occupancy(g, s)["occupancy"]
                    >= compiler.engine_occupancy(g, a)["occupancy"]
                    - 1e-12), name

    def test_slack_levels_down_contention(self):
        """The case the policy exists for: two independent convs next to a
        three-op MISC chain, all joining at the end.  ASAP stacks both
        convs in the first level (Conv PE contention 2) and leaves the
        later levels MISC-only; slack spreads one conv into the idle
        window, halving the worst same-unit width and raising occupancy."""
        g = Graph(nodes=(
            InputOp(0, ()),
            AddOp(1, (0, 0)),                        # 3-op MISC chain
            AddOp(2, (1, 1)),
            AddOp(3, (2, 2)),
            ConvOp(4, (0,), w=("a",)),               # independent convs:
            ConvOp(5, (0,), w=("b",)),               # slack window [1, 3]
            ConcatOp(6, (3, 4, 5)),
        ), output=6)
        a = compiler.level_schedule(g, "asap")
        s = compiler.level_schedule(g, "slack")
        compiler.validate_schedule(g, s)
        assert _max_unit_width(g, a) == 2            # conv4+conv5 co-leveled
        assert _max_unit_width(g, s) == 1            # spread across slack
        assert (compiler.engine_occupancy(g, s)["occupancy"]
                > compiler.engine_occupancy(g, a)["occupancy"])

    def test_slack_execution_bit_identical(self):
        """Slack-scheduled static execution matches sequential bitwise."""
        cfg = dataclasses.replace(CNN_ZOO["resnet50"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x], policy="slack")
        assert prog.schedule is not None
        seq = dataclasses.replace(prog, schedule=None)
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(seq, qparams, x, eng))
        np.testing.assert_array_equal(a, b)

    def test_serving_engine_accepts_slack_policy(self):
        from repro.serve.cnn_engine import CNNServeEngine

        cfg = dataclasses.replace(CNN_ZOO["squeezenet"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        engine = CNNServeEngine(eng, wave_size=2, schedule_policy="slack")
        engine.register(cfg, params, calib_batches=[x])
        out = engine.infer(cfg.name, np.asarray(x))
        assert np.isfinite(out).all()
        prog = engine.program_for(cfg.name)
        compiler.validate_schedule(prog.graph, prog.schedule)


# ---------------------------------------------------------------------------
# Satellites: rope-table cache, precomputed scale arrays, perf model
# ---------------------------------------------------------------------------

class TestSatellites:
    def test_rope_tables_cached_across_executes(self):
        from repro import configs
        from repro.compiler import executor as ex
        from repro.models import transformer as T

        arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, arch.vocab_size, (2, 10)).astype(np.int32))
        eng = EngineConfig(quant="none", backend="ref")
        prog = compiler.compile_lm(arch)
        ex._rope_tables.clear()
        compiler.execute(prog, params, toks, eng)
        entries = compiler.rope_table_stats()["entries"]
        assert entries >= 1
        t0 = ex._rope_tables[next(iter(ex._rope_tables))]
        compiler.execute(prog, params, toks, eng)
        # second execute reuses the SAME table objects (no rebuild)
        assert ex._rope_tables[next(iter(ex._rope_tables))][0] is t0[0]
        assert compiler.rope_table_stats()["entries"] == entries
        # bounded: sweeping many shapes cannot grow it past capacity
        for l in range(4, 4 + ex._ROPE_TABLE_CAPACITY + 8):
            ex._rope_table(1, l, arch.head_dim, arch.rope_theta)
        assert (compiler.rope_table_stats()["entries"]
                <= ex._ROPE_TABLE_CAPACITY)

    def test_rope_tables_never_cache_tracers(self):
        from repro import configs
        from repro.compiler import executor as ex
        from repro.models import transformer as T

        arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, arch.vocab_size, (2, 7)).astype(np.int32))
        eng = EngineConfig(quant="none", backend="ref")
        prog = compiler.compile_lm(arch)
        ex._rope_tables.clear()
        jax.jit(lambda p, t: compiler.execute(prog, p, t, eng))(params, toks)
        for cos, sin in ex._rope_tables.values():
            assert not isinstance(cos, jax.core.Tracer)

    def test_plan_precomputes_scale_arrays(self):
        cfg = dataclasses.replace(CNN_ZOO["mobilenetv2"], input_hw=32)
        params, x = _setup(cfg)
        prog = compiler.compile_calibrated(cfg, params, [x])
        plan = prog.plan
        for n in prog.graph.nodes:
            if plan.emit_int8[n.id]:
                arr = plan.scale_arr[n.id]
                assert arr.dtype == jnp.float32
                np.testing.assert_allclose(
                    np.asarray(arr).ravel(),
                    np.asarray(plan.out_scale[n.id],
                               dtype=np.float32).ravel())

    def test_cnn_node_times_cover_fused_graph(self):
        from benchmarks import perf_model as pm

        for name in ("resnet50", "mobilenetv2"):
            cfg = CNN_ZOO[name]
            g, _ = compiler.fuse_epilogues(compiler.build_graph(cfg))
            times = pm.cnn_node_times(g, cfg)
            assert set(times) == {n.id for n in g.nodes}
            assert all(t >= 0.0 for t in times.values())
            tw = pm.cnn_busy_fractions(cfg, policy="slack")
            assert 0.0 < tw["occupancy"] <= 1.0
            # the fused graph's modeled span is never worse than unfused
            tw_unfused = pm.cnn_busy_fractions(cfg, policy="slack",
                                               fuse=False)
            assert tw["span_s"] <= tw_unfused["span_s"] + 1e-12

    def test_bench_payload_shape(self):
        from benchmarks import serve_cnn as sc

        zoo = sc.zoo_fusion_occupancy()
        assert set(zoo) == set(CNN_ZOO)
        for name, z in zoo.items():
            assert z["launches_fused"] <= z["launches_unfused"]
            assert (z["occupancy"]["slack"]
                    >= z["occupancy"]["asap"] - 1e-12), name
        assert zoo["resnet50"]["launch_reduction"] >= 0.25


@pytest.fixture(scope="module")
def fusion_golden():
    """One calibration + fused/unfused compile per model, shared across
    backend parametrizations."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = dataclasses.replace(CNN_ZOO[name], input_hw=32)
            params, x = _setup(cfg)
            fused = compiler.compile_calibrated(cfg, params, [x])
            unfused = compiler.compile_calibrated(cfg, params, [x],
                                                  fuse=False)
            assert compiler.fusion_stats(fused.graph)["fused_ops"] > 0, name
            cache[name] = (cfg, params, x, fused, unfused)
        return cache[name]

    return get
