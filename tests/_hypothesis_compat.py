"""Fallback shim for `hypothesis` in offline environments.

Property-test modules import `given / settings / strategies` from here when
the real package is absent.  The shim replays each property over a small
deterministic set of examples drawn from the declared strategies, so the
tests still exercise several points of the input space (just not hundreds,
and without shrinking).  When hypothesis IS installed the shim is unused.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                       # offline container
        from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

import inspect

N_EXAMPLES = 5          # examples replayed per property


class _Strategy:
    """A deterministic sample list standing in for a hypothesis strategy."""

    def __init__(self, samples):
        self.samples = list(samples)


def _spread(lo: int, hi: int, n: int):
    """n deterministic integers covering [lo, hi] (endpoints included)."""
    if hi <= lo:
        return [lo]
    vals = sorted({lo + round((hi - lo) * i / (n - 1)) for i in range(n)})
    return vals


class strategies:
    """Mirror of the tiny hypothesis.strategies surface the suite uses."""

    @staticmethod
    def integers(min_value=0, max_value=100):
        return _Strategy(_spread(min_value, max_value, N_EXAMPLES))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        lo, hi = float(min_value), float(max_value)
        if hi <= lo:
            return _Strategy([lo])
        # geometric spread when the range spans decades, else linear
        if lo > 0 and hi / lo > 100.0:
            r = (hi / lo) ** (1.0 / (N_EXAMPLES - 1))
            return _Strategy([lo * r ** i for i in range(N_EXAMPLES)])
        step = (hi - lo) / (N_EXAMPLES - 1)
        return _Strategy([lo + step * i for i in range(N_EXAMPLES)])

    @staticmethod
    def sampled_from(elements):
        return _Strategy(list(elements))

    @staticmethod
    def booleans():
        return _Strategy([False, True])

    @staticmethod
    def lists(elem, min_size=0, max_size=5, **_):
        sizes = _spread(min_size, max_size, 3)
        pool = elem.samples
        return _Strategy([(pool * (s // len(pool) + 1))[:s] for s in sizes])


st = strategies


def given(**strategy_kwargs):
    """Replay the property over a rotated cross-section of the strategies."""

    def deco(fn):
        names = list(strategy_kwargs)
        pools = [strategy_kwargs[n].samples for n in names]
        n_runs = max((len(p) for p in pools), default=1)
        n_runs = max(n_runs, N_EXAMPLES)

        def wrapper(*args, **kwargs):
            for i in range(n_runs):
                # stride-1 rotation with a per-kwarg offset: every pool
                # element is reached (n_runs >= len(pool)) while the
                # combinations still vary across kwargs
                ex = {n: pool[(i + j) % len(pool)]
                      for j, (n, pool) in enumerate(zip(names, pools))}
                fn(*args, **kwargs, **ex)

        # Hide the strategy parameters from pytest's fixture resolution
        # (real hypothesis does the same); remaining params stay fixtures.
        sig = inspect.signature(fn)
        wrapper.__signature__ = inspect.Signature(
            [p for name, p in sig.parameters.items()
             if name not in strategy_kwargs])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis_compat = True
        return wrapper

    return deco


def settings(*args, **kwargs):
    """No-op decorator (deadline / max_examples have no meaning here)."""
    if args and callable(args[0]):
        return args[0]

    def deco(fn):
        return fn

    return deco
