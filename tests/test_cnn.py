"""CNN zoo on the DPUV4E engines: shapes, engine-feature equivalence, and
the quantized end-to-end path."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import cnn
from repro.models.params import init_params

SMALL_HW = 32


def _small(cfg):
    return dataclasses.replace(cfg, input_hw=SMALL_HW)


def _fwd(cfg, eng, seed=0):
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(seed))
    if eng.quant != "none":
        params = eng_lib.quantize_params(params, eng)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, cfg.input_hw, cfg.input_hw, cfg.input_ch)
    ).astype(np.float32) * 0.5)
    return cnn.cnn_forward(params, x, cfg, eng)


@pytest.mark.parametrize("name", sorted(CNN_ZOO))
def test_smoke_forward_all_models(name):
    cfg = _small(CNN_ZOO[name])
    eng = EngineConfig(quant="none", backend="ref")
    logits = _fwd(cfg, eng)
    assert logits.shape == (2, cfg.num_classes)
    assert np.isfinite(np.array(logits)).all()


@pytest.mark.parametrize("name", ["resnet50", "mobilenetv2"])
def test_quantized_close_to_float(name):
    """Random-init deep CNNs amplify per-layer quant noise, so the serving
    criterion is rank agreement (top-1 class), not elementwise closeness."""
    cfg = _small(CNN_ZOO[name])
    f = np.array(_fwd(cfg, EngineConfig(quant="none", backend="ref")))
    q = np.array(_fwd(cfg, EngineConfig(quant="w8a8", backend="ref")))
    assert np.isfinite(q).all()
    corr = np.corrcoef(f.ravel(), q.ravel())[0, 1]
    assert corr > 0.7, corr


def test_engine_features_do_not_change_math():
    """DWC engine / low-channel unit / MISC fusion are perf features: the
    float-path outputs must match with them on or off."""
    cfg = _small(CNN_ZOO["mobilenetv2"])
    base = EngineConfig(quant="none", backend="ref")
    variants = [
        dataclasses.replace(base, use_dwc_engine=False),
        dataclasses.replace(base, use_low_channel_unit=False),
        dataclasses.replace(base, misc_on_engine=False),
    ]
    want = np.array(_fwd(cfg, base))
    for v in variants:
        got = np.array(_fwd(cfg, v))
        # identical math, different accumulation order through ~20 layers
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_dwc_fraction_ordering():
    """MobileNets are DWC-heavy; ResNets have none (drives Table III)."""
    f = {n: cnn.dwc_op_fraction(CNN_ZOO[n]) for n in CNN_ZOO}
    assert f["mobilenetv1"] > 0.02
    assert f["mobilenetv2"] > 0.02
    assert f["efficientnet"] > 0.02
    assert f["resnet50"] == 0.0
    assert f["squeezenet"] == 0.0


def test_cnn_flops_scale():
    """Analytic flops track the paper's GOPs within 2x for the exact archs
    (YOLOs are approximated backbones, so they are excluded)."""
    for name in ["resnet50", "resnet152", "mobilenetv1", "mobilenetv2"]:
        cfg = CNN_ZOO[name]
        params = None
        flops = cnn.cnn_flops(cfg, params)
        paper = cfg.gops * 1e9
        assert 0.5 < flops / paper < 2.2, (name, flops, paper)


def test_perf_model_sanity():
    from benchmarks import perf_model as pm
    for name, cfg in CNN_ZOO.items():
        t_ours = pm.model_inference_time(cfg, pm.OURS)
        t_base = pm.model_inference_time(cfg, pm.BASELINE)
        assert 0 < t_ours < 1.0
        assert t_base >= t_ours * 0.99, name
    # DWC-heavy models gain more from the DWC engine (paper's Table III)
    gain = lambda n: (pm.model_inference_time(CNN_ZOO[n], pm.NO_DWC)
                      / pm.model_inference_time(CNN_ZOO[n], pm.OURS))
    assert gain("mobilenetv1") > gain("resnet50")
