"""CNN serving invariants: wave batching matches per-image execution, the
program cache hits/misses/evicts correctly, and the executor's dynamic
program store is bounded (regression for the old unbounded lru_cache)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import compiler
from repro.compiler import executor as ex
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import cnn
from repro.models.params import init_params
from repro.serve.cnn_engine import CNNServeEngine, calibration_digest
from repro.serve.program_cache import ProgramCache, ProgramKey

HW = 32
W8 = EngineConfig(quant="w8a8", backend="ref")


def _model(name, seed=0):
    cfg = dataclasses.replace(CNN_ZOO[name], input_hw=HW)
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(seed))
    return cfg, params


def _images(n, ch=3, seed=0):
    return np.random.default_rng(seed).normal(
        size=(n, HW, HW, ch)).astype(np.float32) * 0.5


def _calib():
    return [jnp.asarray(_images(2, seed=7))]


# ---------------------------------------------------------------------------
# Wave batching
# ---------------------------------------------------------------------------

class TestWaveBatching:
    def test_waves_match_per_image_execution(self):
        """5 requests through wave_size=2 (3 waves, 1 padded slot) return
        the same logits as executing each image alone through the same
        compiled program."""
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib())
        images = _images(5)
        got = engine.infer(cfg.name, images)
        assert got.shape == (5, cfg.num_classes)
        assert engine.wave_stats.waves == 3
        assert engine.wave_stats.padded == 1
        prog = engine.program_for(cfg.name)
        qparams = eng_lib.quantize_params(params, W8)
        for i in range(5):
            solo = np.array(compiler.execute(
                prog, qparams, jnp.asarray(images[i:i + 1]), W8))
            np.testing.assert_allclose(got[i], solo[0], rtol=1e-4, atol=1e-4)

    def test_submission_order_preserved_across_models(self):
        """Interleaved requests for two models come back in ticket order,
        each equal to its own model's direct execution."""
        cfg_a, params_a = _model("squeezenet", seed=0)
        cfg_b, params_b = _model("mobilenetv2", seed=1)
        engine = CNNServeEngine(W8, wave_size=4)
        engine.register(cfg_a, params_a, calib_batches=_calib())
        engine.register(cfg_b, params_b, calib_batches=_calib())
        images = _images(6)
        order = [cfg_a.name, cfg_b.name, cfg_b.name,
                 cfg_a.name, cfg_b.name, cfg_a.name]
        for name, img in zip(order, images):
            engine.submit(name, img)
        out = engine.flush()
        assert len(out) == 6
        for i, name in enumerate(order):
            cfg = cfg_a if name == cfg_a.name else cfg_b
            params = params_a if name == cfg_a.name else params_b
            prog = engine.program_for(name)
            solo = np.array(compiler.execute(
                prog, eng_lib.quantize_params(params, W8),
                jnp.asarray(images[i:i + 1]), W8))
            np.testing.assert_allclose(out[i], solo[0], rtol=1e-4, atol=1e-4)

    def test_float_engine_matches_cnn_forward(self):
        """quant='none' serving (dynamic program) equals the eager path."""
        cfg, params = _model("squeezenet")
        eng = EngineConfig(quant="none", backend="ref")
        engine = CNNServeEngine(eng, wave_size=4)
        engine.register(cfg, params)
        images = _images(4)
        got = engine.infer(cfg.name, images)
        want = np.array(cnn.cnn_forward(params, jnp.asarray(images), cfg,
                                        eng))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_submit_validates(self):
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8)
        engine.register(cfg, params, calib_batches=_calib())
        with pytest.raises(KeyError):
            engine.submit("nope", _images(1)[0])
        with pytest.raises(ValueError):
            engine.submit(cfg.name, _images(2))      # batch, not one image


# ---------------------------------------------------------------------------
# Program cache behavior through the engine
# ---------------------------------------------------------------------------

class TestProgramCaching:
    def test_hit_on_second_request(self):
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib())
        engine.infer(cfg.name, _images(2))
        first = engine.cache.stats.misses
        p1 = engine.program_for(cfg.name)
        engine.infer(cfg.name, _images(2, seed=1))
        assert engine.cache.stats.misses == first    # no recompile
        assert engine.cache.stats.hits >= 2
        assert engine.program_for(cfg.name) is p1    # same compiled object

    def test_miss_and_recompile_on_engine_change(self):
        """Two engines sharing one cache: the key includes EngineConfig, so
        a different engine config recompiles instead of aliasing."""
        cfg, params = _model("squeezenet")
        shared = ProgramCache(capacity=4)
        e1 = CNNServeEngine(W8, wave_size=2, cache=shared)
        e2 = CNNServeEngine(
            EngineConfig(quant="w8a8", backend="ref", baseline=True),
            wave_size=2, cache=shared)
        calib = _calib()
        e1.register(cfg, params, calib_batches=calib)
        e2.register(cfg, params, calib_batches=calib)
        p1 = e1.program_for(cfg.name)
        assert shared.stats.misses == 1
        p2 = e2.program_for(cfg.name)
        assert shared.stats.misses == 2              # engine change -> miss
        assert p1 is not p2
        assert e1.program_for(cfg.name) is p1        # both entries live
        assert e2.program_for(cfg.name) is p2
        assert shared.stats.hits == 2

    def test_miss_on_calibration_change(self):
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib())
        p1 = engine.program_for(cfg.name)
        other = [jnp.asarray(_images(2, seed=99))]
        assert calibration_digest(other) != calibration_digest(_calib())
        engine.register(cfg, params, calib_batches=other)
        p2 = engine.program_for(cfg.name)
        assert p2 is not p1
        assert engine.cache.stats.misses == 2

    def test_miss_on_params_change(self):
        """Re-registering new weights under the same config + calibration
        batches must recompile: the calibrated scales depend on the params,
        so reusing the old program would execute against stale scales."""
        cfg, params = _model("squeezenet", seed=0)
        _, params2 = _model("squeezenet", seed=1)
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib())
        p1 = engine.program_for(cfg.name)
        engine.register(cfg, params2, calib_batches=_calib())
        p2 = engine.program_for(cfg.name)
        assert p2 is not p1
        assert engine.cache.stats.misses == 2
        # the plans genuinely differ: different weights -> different scales
        assert p1.plan.out_scale != p2.plan.out_scale

    def test_miss_on_calibrator_method_change(self):
        """absmax and percentile calibrations are distinct cache entries:
        the calibrator method is part of the calibration-id."""
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib())
        p1 = engine.program_for(cfg.name)
        engine.register(cfg, params, calib_batches=_calib(),
                        calibrator="p99.9")
        p2 = engine.program_for(cfg.name)
        assert p2 is not p1
        assert engine.cache.stats.misses == 2
        assert calibration_digest(_calib(), params) != \
            calibration_digest(_calib(), params, "p99.9")

    def test_lru_eviction_respects_capacity(self):
        """capacity=2 with 3 models: the least-recently-used program is
        evicted, and revisiting it recompiles."""
        engine = CNNServeEngine(W8, wave_size=2, cache_capacity=2)
        names = []
        for i, zoo in enumerate(("squeezenet", "mobilenetv2", "resnet50")):
            cfg, params = _model(zoo, seed=i)
            names.append(engine.register(cfg, params, calib_batches=_calib()))
        a, b, c = names
        pa = engine.program_for(a)
        engine.program_for(b)
        assert len(engine.cache) == 2 and engine.cache.stats.evictions == 0
        engine.program_for(a)                        # refresh a's recency
        engine.program_for(c)                        # evicts b (LRU)
        assert len(engine.cache) == 2
        assert engine.cache.stats.evictions == 1
        assert engine.program_for(a) is pa           # a survived (refreshed)
        misses = engine.cache.stats.misses
        engine.program_for(b)                        # b was evicted
        assert engine.cache.stats.misses == misses + 1


# ---------------------------------------------------------------------------
# ProgramCache unit behavior
# ---------------------------------------------------------------------------

class TestProgramCacheUnit:
    def test_lru_order_and_eviction_callback(self):
        evicted = []
        c = ProgramCache(capacity=2, on_evict=lambda k, v: evicted.append(k))
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1                       # refresh a
        c.put("c", 3)                                # evicts b
        assert evicted == ["b"]
        assert "a" in c and "c" in c and "b" not in c

    def test_get_or_compile_counts(self):
        c = ProgramCache(capacity=2)
        calls = []
        fn = lambda: calls.append(1) or len(calls)
        assert c.get_or_compile("k", fn) == 1
        assert c.get_or_compile("k", fn) == 1        # cached
        assert len(calls) == 1
        assert (c.stats.hits, c.stats.misses, c.stats.compiles) == (1, 1, 1)
        assert c.stats.hit_rate == 0.5
        assert "hit-rate 50.0%" in c.stats.summary()

    def test_zero_capacity_never_stores(self):
        c = ProgramCache(capacity=0)
        assert c.get_or_compile("k", lambda: 1) == 1
        assert c.get_or_compile("k", lambda: 2) == 2  # recompiled
        assert len(c) == 0 and c.stats.misses == 2

    def test_program_key_hashable_and_distinct(self):
        cfg, _ = _model("squeezenet")
        k1 = ProgramKey(cfg, W8, "abc", "scheduled")
        k2 = ProgramKey(cfg, W8, "abc", "scheduled")
        k3 = ProgramKey(cfg, W8, "abd", "scheduled")
        assert k1 == k2 and hash(k1) == hash(k2) and k1 != k3


# ---------------------------------------------------------------------------
# Executor dynamic-program store (regression: was an unbounded lru_cache)
# ---------------------------------------------------------------------------

class TestDynamicProgramStore:
    def test_repeat_compile_hits(self):
        cfg, _ = _model("squeezenet")
        p1 = compiler.compile_cnn(cfg)
        p2 = compiler.compile_cnn(cfg)
        assert p1 is p2
        # the sequential variant is a distinct cached program
        p3 = compiler.compile_cnn(cfg, scheduled=False)
        assert p3 is not p1 and p3.schedule is None
        assert compiler.compile_cnn(cfg, scheduled=False) is p3

    def test_store_is_bounded(self):
        """Sweeping more configs than the capacity must not grow the store
        without limit (the old functools.lru_cache(maxsize=None) did)."""
        cache = compiler.program_cache()
        cap = cache.capacity
        base, _ = _model("squeezenet")
        for i in range(cap + 8):
            compiler.compile_cnn(dataclasses.replace(
                base, name=f"sweep{i}", num_classes=8 + i))
        assert len(cache) <= cap

    def test_static_programs_not_in_dynamic_store(self):
        """Calibrated programs are keyed by the serving cache, not the
        executor's dynamic store (their scales are not part of its key)."""
        cfg, params = _model("squeezenet")
        before = len(compiler.program_cache())
        prog = compiler.compile_calibrated(cfg, params, _calib())
        assert prog.static
        assert len(compiler.program_cache()) == before


# ---------------------------------------------------------------------------
# Continuous batching: pump/refill, shape-shared waves, fill-rate
# ---------------------------------------------------------------------------

class TestContinuousWaves:
    def test_pump_dispatches_full_waves_only(self):
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib())
        images = _images(5)
        tickets = [engine.submit(cfg.name, img) for img in images]
        got = engine.pump()
        assert sorted(got) == tickets[:4]        # two full waves
        assert engine.pending() == 1             # partial wave stays queued
        assert engine.wave_stats.padded == 0
        rest = engine.flush()                    # drain pads the tail
        assert len(rest) == 1
        assert engine.wave_stats.padded == 1
        assert engine.wave_stats.waves == 3

    def test_partial_wave_refills_across_arrivals(self):
        """A partial wave left by pump() is topped up by later arrivals
        instead of being padded -- the continuous-batching win."""
        cfg, params = _model("squeezenet")
        engine = CNNServeEngine(W8, wave_size=4)
        engine.register(cfg, params, calib_batches=_calib())
        images = _images(8)
        for img in images[:2]:
            engine.submit(cfg.name, img)
        assert engine.pump() == {}               # partial: nothing dispatches
        for img in images[2:6]:
            engine.submit(cfg.name, img)
        got = engine.pump()                      # refilled to a full wave
        assert len(got) == 4
        assert engine.wave_stats.padded == 0
        assert engine.stats()["refilled_waves"] >= 1

    def test_same_shape_models_share_tail_wave(self):
        """Two same-shape models' leftovers pack into ONE physical wave
        (executed once per model), instead of two padded waves."""
        cfg_a, params_a = _model("squeezenet", seed=0)
        cfg_b, params_b = _model("mobilenetv2", seed=1)
        engine = CNNServeEngine(W8, wave_size=4)
        engine.register(cfg_a, params_a, calib_batches=_calib())
        engine.register(cfg_b, params_b, calib_batches=_calib())
        images = _images(4)
        ta = [engine.submit(cfg_a.name, images[i]) for i in range(2)]
        tb = [engine.submit(cfg_b.name, images[i]) for i in range(2, 4)]
        out = engine.flush()
        assert len(out) == 4
        assert engine.wave_stats.waves == 1          # one shared buffer
        assert engine.wave_stats.padded == 0
        assert engine.wave_stats.program_execs == 2  # once per model
        # each request still gets its own model's logits
        for t, cfg, params, idx in [(ta[0], cfg_a, params_a, 0),
                                    (tb[0], cfg_b, params_b, 2)]:
            prog = engine.program_for(cfg.name)
            solo = np.array(compiler.execute(
                prog, eng_lib.quantize_params(params, W8),
                jnp.asarray(images[idx:idx + 1]), W8))
            np.testing.assert_allclose(out[t], solo[0], rtol=1e-4,
                                       atol=1e-4)

    def test_arrival_order_invariance(self):
        """Shuffled mixed-model arrivals served with pump-per-arrival +
        final drain return the same per-ticket logits as serial one-image
        inference."""
        cfg_a, params_a = _model("squeezenet", seed=0)
        cfg_b, params_b = _model("mobilenetv2", seed=1)
        images = _images(6)
        names = [cfg_a.name, cfg_b.name] * 3
        serial = {}
        eng0 = CNNServeEngine(W8, wave_size=1)
        eng0.register(cfg_a, params_a, calib_batches=_calib())
        eng0.register(cfg_b, params_b, calib_batches=_calib())
        for i, (n, img) in enumerate(zip(names, images)):
            serial[i] = eng0.infer(n, img[None])[0]
        for seed in range(3):
            order = list(range(6))
            np.random.default_rng(seed).shuffle(order)
            engine = CNNServeEngine(W8, wave_size=4)
            engine.register(cfg_a, params_a, calib_batches=_calib())
            engine.register(cfg_b, params_b, calib_batches=_calib())
            results = {}
            tickets = {}
            for i in order:
                tickets[i] = engine.submit(names[i], images[i])
                results.update(engine.pump())
            # drain the tail
            rest = engine._dispatch(force=True)
            results.update(rest)
            for i in order:
                np.testing.assert_allclose(
                    results[tickets[i]], serial[i], rtol=1e-4, atol=1e-4,
                    err_msg=f"req {i} seed {seed}")

    def test_fill_rate_beats_pad_and_mask(self):
        """Acceptance: continuous wave fill-rate >= the flush-per-arrival
        pad-and-mask baseline on a mixed-arrival trace."""
        cfg_a, params_a = _model("squeezenet", seed=0)
        cfg_b, params_b = _model("mobilenetv2", seed=1)
        images = _images(10)
        names = [cfg_a.name, cfg_b.name] * 5

        def serve(continuous):
            engine = CNNServeEngine(W8, wave_size=4)
            engine.register(cfg_a, params_a, calib_batches=_calib())
            engine.register(cfg_b, params_b, calib_batches=_calib())
            for n, img in zip(names, images):
                engine.submit(n, img)
                if continuous:
                    engine.pump()
                else:
                    engine.flush()
            engine.flush()
            return engine.stats()["wave_fill_rate"]

        base, cont = serve(False), serve(True)
        assert cont >= base
        assert cont >= 0.8                        # 10 reqs, >=2 full waves


# ---------------------------------------------------------------------------
# Per-channel static activation scales
# ---------------------------------------------------------------------------

class TestPerChannelCalibration:
    def test_digest_distinct_and_registry(self):
        cfg, params = _model("mobilenetv2")
        d_pt = calibration_digest(_calib(), params, "absmax", "per_tensor")
        d_pc = calibration_digest(_calib(), params, "absmax", "per_channel")
        assert d_pt != d_pc
        engine = CNNServeEngine(W8, wave_size=2)
        engine.register(cfg, params, calib_batches=_calib(),
                        granularity="per_channel")
        assert engine._models[cfg.name].calib_id == d_pc

    def test_plan_keeps_only_dwc_consumed_edges(self):
        """Vectors survive exactly where the channelwise DWC engine
        consumes the edge; every other edge collapses to its channel max
        (= the per-tensor scale)."""
        cfg, params = _model("mobilenetv2")
        prog_pc = compiler.compile_calibrated(cfg, params, _calib(),
                                              granularity="per_channel")
        prog_pt = compiler.compile_calibrated(cfg, params, _calib())
        g, plan = prog_pc.graph, prog_pc.plan
        consumers = g.consumers()
        kept = 0
        for nid, s in plan.out_scale.items():
            if isinstance(s, tuple):
                kept += 1
                assert consumers[nid]
                assert all(isinstance(g.nodes[c], compiler.DwcOp)
                           for c in consumers[nid])
                # collapsing the vector reproduces the per-tensor scale
                assert max(s) == pytest.approx(
                    prog_pt.plan.out_scale[nid], rel=1e-6)
            else:
                assert s == pytest.approx(prog_pt.plan.out_scale[nid],
                                          rel=1e-6)
        assert kept == plan.stats["per_channel_edges"] > 0
        assert prog_pc.f32_roundtrips() == 0

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_per_channel_program_executes(self, backend):
        cfg, params = _model("mobilenetv2")
        eng = dataclasses.replace(eng_lib.paper_engine(), backend=backend)
        prog = compiler.compile_calibrated(cfg, params, _calib(),
                                           granularity="per_channel")
        qparams = eng_lib.quantize_params(params, eng)
        images = _images(2)
        out = np.array(compiler.execute(prog, qparams,
                                        jnp.asarray(images), eng))
        assert np.isfinite(out).all()
        # tracks the per-tensor static program (same calibration data)
        pt = compiler.compile_calibrated(cfg, params, _calib())
        ref_out = np.array(compiler.execute(pt, qparams,
                                            jnp.asarray(images), eng))
        scale = max(np.max(np.abs(ref_out)), 1e-3)
        assert np.max(np.abs(out - ref_out)) <= 0.5 * scale

    def test_per_channel_requires_absmax(self):
        with pytest.raises(ValueError):
            compiler.make_calibrator("p99.9", "per_channel")
        with pytest.raises(ValueError):
            compiler.make_calibrator("absmax", "per_row")
