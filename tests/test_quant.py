"""INT8 quantization invariants (hypothesis property tests)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.core.quant import (Calibrator, QTensor, fake_quant, quantize,
                              quantize_act_dynamic, requantize)


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 64), scale=st.floats(0.01, 100.0))
def test_roundtrip_error_bound(n, scale):
    """|x - dq(q(x))| <= scale/2 elementwise (symmetric quant property)."""
    rng = np.random.default_rng(n)
    x = (rng.normal(size=(n, n)) * scale).astype(np.float32)
    qt = quantize(jnp.array(x))
    err = np.abs(np.array(qt.dequant()) - x)
    bound = float(qt.scale) / 2 + 1e-6
    assert err.max() <= bound


@settings(deadline=None, max_examples=20)
@given(k=st.integers(2, 32), n=st.integers(2, 32))
def test_per_channel_tighter_than_per_tensor(k, n):
    rng = np.random.default_rng(k * 100 + n)
    x = rng.normal(size=(k, n)).astype(np.float32)
    x[:, 0] *= 100.0                      # one hot channel
    per_t = np.abs(np.array(fake_quant(jnp.array(x))) - x).mean()
    per_c = np.abs(np.array(fake_quant(jnp.array(x), axis=1)) - x).mean()
    assert per_c <= per_t + 1e-6


def test_quantize_range():
    x = jnp.array([[-10.0, 0.0, 10.0]])
    qt = quantize(x)
    assert int(qt.q.min()) >= -127 and int(qt.q.max()) <= 127
    assert int(qt.q[0, 2]) == 127


def test_dynamic_act_per_token():
    x = jnp.array([[1.0, 2.0], [100.0, 200.0]])
    qt = quantize_act_dynamic(x, per_token=True)
    assert qt.scale.shape == (2, 1)
    np.testing.assert_allclose(np.array(qt.dequant()), np.array(x),
                               rtol=0.02)


def test_requantize_matches_manual():
    acc = jnp.array([[1000, -2000]], jnp.int32)
    out = requantize(acc, jnp.float32(0.01), jnp.float32(0.02))
    np.testing.assert_allclose(np.array(out), [[0.2, -0.4]], rtol=1e-6)


def test_calibrator_running_max():
    c = Calibrator()
    c.observe("a", jnp.array([1.0, -3.0]))
    c.observe("a", jnp.array([2.0]))
    assert abs(c.scales()["a"] - 3.0 / 127) < 1e-9


class TestParamTreeQuant:
    def _params(self):
        rng = np.random.default_rng(0)
        return {
            "embed": jnp.array(rng.normal(size=(32, 8)).astype(np.float32)),
            "blocks": [{
                "norm": jnp.zeros((8,), jnp.float32),
                "attn": {"wq": jnp.array(
                    rng.normal(size=(8, 16)).astype(np.float32))},
                "mixer": {"conv_w": jnp.array(
                    rng.normal(size=(4, 8)).astype(np.float32))},
            }],
        }

    def test_quantizes_allowlisted_keys_only(self):
        eng = EngineConfig(quant="w8a8")
        q = eng_lib.quantize_params(self._params(), eng)
        assert isinstance(q["embed"], QTensor)
        assert isinstance(q["blocks"][0]["attn"]["wq"], QTensor)
        # conv_w (DWC taps) and norms stay float
        assert not isinstance(q["blocks"][0]["mixer"]["conv_w"], QTensor)
        assert not isinstance(q["blocks"][0]["norm"], QTensor)

    def test_embed_quantized_per_row(self):
        eng = EngineConfig(quant="w8a8")
        q = eng_lib.quantize_params(self._params(), eng)
        assert q["embed"].scale.shape == (32, 1)
        assert q["blocks"][0]["attn"]["wq"].scale.shape == (1, 16)

    def test_schema_matches_value_structure(self):
        """quantize_schema and quantize_params must produce the same tree
        structure (dry-run abstract args == real args)."""
        from repro.models.params import ParamSpec, abstract_params
        eng = EngineConfig(quant="w8a8")
        schema = {
            "embed": ParamSpec((32, 8), ("tp", None), "embed"),
            "blocks": [{
                "norm": ParamSpec((8,), (None,), "zeros"),
                "attn": {"wq": ParamSpec((8, 16), (None, "tp"))},
                "mixer": {"conv_w": ParamSpec((4, 8), (None, "tp"), "small")},
            }],
        }
        qschema = eng_lib.quantize_schema(schema, eng)
        abs_tree = abstract_params(qschema)
        qvals = eng_lib.quantize_params(self._params(), eng)
        t1 = jax.tree_util.tree_structure(abs_tree)
        t2 = jax.tree_util.tree_structure(qvals)
        assert t1 == t2
        # shapes/dtypes agree leaf-by-leaf
        for a, v in zip(jax.tree_util.tree_leaves(abs_tree),
                        jax.tree_util.tree_leaves(qvals)):
            assert a.shape == v.shape and a.dtype == v.dtype

    def test_w8a8_linear_accuracy(self):
        """End-to-end W8A8 relative error stays small on gaussian data."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(64, 128)).astype(np.float32)
        w = rng.normal(size=(128, 96)).astype(np.float32) / np.sqrt(128)
        from repro.kernels import ops
        wq = quantize(jnp.array(w), axis=1)
        got = np.array(ops.linear(jnp.array(x), wq, None, "none",
                                  EngineConfig(quant="w8a8", backend="ref")))
        want = x @ w
        rel = np.abs(got - want).mean() / np.abs(want).mean()
        assert rel < 0.02
