"""Decode-as-program invariants: the LM decode step lowers through the
engine IR (AttnOp `update` mode, DecodeStep program kind), compiles to a
static-int8 program from the same calibration run as prefill, executes from
the ProgramCache inside ServeEngine's decode burst, and the continuous-
batching slot scheduler serves any arrival order with per-request outputs
identical to serial serving."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro import compiler, configs
from repro.compiler import executor as ex
from repro.compiler import passes
from repro.compiler.graph import AttnOp, HeadOp, LinearOp
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models.params import init_params, is_spec
from repro.serve.engine import ServeEngine, SubmitRejection

ENG = EngineConfig(quant="none", backend="ref")
W8 = EngineConfig(quant="w8a8", backend="ref")

GOLDEN = ["qwen2-1.5b", "gemma2-2b"]

B, L, STEPS = 2, 8, 4


def _setup(name, seed=0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(seed))
    toks = jnp.array(np.random.default_rng(seed).integers(
        0, arch.vocab_size, (B, L)).astype(np.int32))
    return arch, params, toks


def _cache(arch, batch, seq, eng):
    return jtu.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                        T.cache_schema(arch, batch, seq, eng),
                        is_leaf=is_spec)


def _greedy_ids(arch, params, prompts, eng, steps, compute=jnp.float32,
                max_seq=None):
    """Reference greedy loop: eager prefill + eager decode, one prompt per
    batch row (batch-size len(prompts), equal-length prompts)."""
    max_seq = max_seq or (len(prompts[0]) + steps + 2)
    toks = jnp.asarray(np.stack(prompts).astype(np.int32))
    cache = _cache(arch, len(prompts), max_seq, eng)
    logits, cache = T.prefill(params, cache, {"tokens": toks}, arch, eng,
                              compute_dtype=compute)
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = []
    for _ in range(steps):
        out.append(np.asarray(cur[:, 0]))
        logits, cache = T.decode(params, cache, cur, arch, eng,
                                 compute_dtype=compute)
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return np.stack(out, axis=1)          # [B, steps]


# ---------------------------------------------------------------------------
# Lowering: the DecodeStep graph
# ---------------------------------------------------------------------------

class TestDecodeLowering:
    @pytest.mark.parametrize("name", GOLDEN)
    def test_decode_graph_mirrors_full_graph(self, name):
        """Same node sequence as the full graph (so calibration scales
        transfer by node id), with every AttnOp in update mode."""
        arch, _, _ = _setup(name)
        full = compiler.lower_transformer(arch)
        dec = compiler.lower_transformer(arch, mode="decode")
        assert len(full.nodes) == len(dec.nodes)
        for f, d in zip(full.nodes, dec.nodes):
            assert type(f) is type(d)
            assert f.inputs == d.inputs
            if isinstance(d, AttnOp):
                assert d.mode == "update" and f.mode == "full"
        assert dec.count(AttnOp) == arch.n_layers
        assert not dec.nodes[dec.output].last_only

    def test_unknown_mode_rejected(self):
        arch, _, _ = _setup("qwen2-1.5b")
        with pytest.raises(ValueError):
            compiler.lower_transformer(arch, mode="chunked")
        with pytest.raises(ValueError):
            compiler.compile_lm(arch, mode="chunked")

    def test_decode_program_kind_and_memoization(self):
        arch, _, _ = _setup("qwen2-1.5b")
        d = compiler.compile_lm(arch, mode="decode")
        assert d.kind == "decode"
        assert compiler.compile_lm(arch, mode="decode") is d
        # prefill / full / decode memoize as three distinct programs
        assert compiler.compile_lm(arch, prefill=True) is not d
        assert compiler.compile_lm(arch) is not d

    def test_execute_guards_program_kind(self):
        arch, params, toks = _setup("qwen2-1.5b")
        d = compiler.compile_lm(arch, mode="decode")
        p = compiler.compile_lm(arch, prefill=True)
        with pytest.raises(ValueError):
            compiler.execute(d, params, toks, ENG)
        cache = _cache(arch, B, L, ENG)
        with pytest.raises(ValueError):
            compiler.execute_decode(p, params, cache, toks[:, :1], ENG)


# ---------------------------------------------------------------------------
# Static plan: every decode GEMM input carries a compile-time scale
# ---------------------------------------------------------------------------

class TestStaticDecodePlan:
    def test_decode_gemms_all_static(self):
        arch, params, toks = _setup("qwen2-1.5b")
        prog = compiler.compile_lm_calibrated(arch, params, [toks],
                                              mode="decode")
        assert prog.static and prog.kind == "decode"
        g, plan = prog.graph, prog.plan
        assert passes.f32_roundtrip_edges(g, plan) == []
        assert prog.f32_roundtrips() == 0
        for n in g.nodes:
            if isinstance(n, LinearOp):
                # a fused residual tail rides the epilogue in f32 (the PE
                # adds it post-GEMM); only the GEMM inputs must be int8
                ins = (n.inputs[:-1] if n.epilogue is not None
                       and n.epilogue.add else n.inputs)
                assert all(plan.emit_int8[i] for i in ins), n

    def test_one_calibration_run_covers_both_programs(self):
        """calibrate_lm scales compile prefill AND decode; the two plans
        agree edge-for-edge on every shared (non-head) node."""
        arch, params, toks = _setup("gemma2-2b")
        scales = compiler.calibrate_lm(arch, params, [toks])
        pp = compiler.compile_lm(arch, scales=scales, mode="prefill")
        dp = compiler.compile_lm(arch, scales=scales, mode="decode")
        for n in dp.graph.nodes:
            if isinstance(n, HeadOp):
                continue
            assert dp.plan.out_scale[n.id] == pp.plan.out_scale[n.id]
            assert dp.plan.emit_int8[n.id] == pp.plan.emit_int8[n.id]

    def test_static_decode_tracks_static_full_program(self):
        """Teacher-forced static decode continues the static prefill within
        a small quantization drift of the static full-sequence program."""
        arch, params, _ = _setup("qwen2-1.5b")
        EXTRA = 3
        rng = np.random.default_rng(3)
        toks = jnp.array(rng.integers(0, arch.vocab_size,
                                      (B, L + EXTRA)).astype(np.int32))
        scales = compiler.calibrate_lm(arch, params, [toks])
        fprog = compiler.compile_lm(arch, scales=scales)
        pprog = compiler.compile_lm(arch, scales=scales, mode="prefill")
        dprog = compiler.compile_lm(arch, scales=scales, mode="decode")
        qparams = eng_lib.quantize_params(params, W8)
        full = np.asarray(compiler.execute(fprog, qparams, toks, W8))
        kvs = {}
        lp = compiler.execute(pprog, qparams, toks[:, :L], W8, collect=kvs)
        np.testing.assert_allclose(np.asarray(lp[:, 0]), full[:, L - 1],
                                   rtol=1e-5, atol=1e-5)
        cache = _cache(arch, B, L + EXTRA, W8)
        layers = [T._kv_store(cache["layers"][i], *kvs[i], 0, W8)
                  for i in range(arch.n_layers)]
        cache = {"layers": layers, "pos": jnp.asarray(L, jnp.int32)}
        bound = 0.15 * np.max(np.abs(full))
        for t in range(EXTRA):
            ld, cache = compiler.execute_decode(
                dprog, qparams, cache, toks[:, L + t:L + t + 1], W8)
            gap = float(np.max(np.abs(np.asarray(ld[:, 0]) - full[:, L + t])))
            assert np.isfinite(np.asarray(ld)).all()
            assert gap <= bound, (t, gap, bound)


# ---------------------------------------------------------------------------
# Golden decode parity x {ref, pallas}: bit-identical greedy token ids
# ---------------------------------------------------------------------------

class TestGoldenDecodeParity:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("name", GOLDEN)
    def test_compiled_decode_greedy_ids_match_eager(self, name, backend):
        """Full prefill + N-token greedy decode through the compiled
        (dynamic) prefill + DecodeStep programs produces bit-identical
        token ids to the eager T.prefill/T.decode loop, on both kernel
        backends."""
        arch, params, toks = _setup(name)
        eng = EngineConfig(quant="none", backend=backend, interpret=True)
        max_seq = L + STEPS + 2
        want = _greedy_ids(arch, params, np.asarray(toks), eng, STEPS,
                           max_seq=max_seq)

        pprog = compiler.compile_lm(arch, prefill=True)
        dprog = compiler.compile_lm(arch, mode="decode")
        kvs = {}
        logits = compiler.execute(pprog, params, toks, eng, collect=kvs)
        cache = _cache(arch, B, max_seq, eng)
        layers = []
        for i in range(arch.n_layers):
            k, v = kvs[i]
            entry = cache["layers"][i]
            if arch.layer_kind(i) == "local":
                w = entry["k"].shape[1]
                entry = T._kv_store(entry, k[:, -w:], v[:, -w:], 0, eng)
            else:
                entry = T._kv_store(entry, k, v, 0, eng)
            layers.append(entry)
        cache = {"layers": layers, "pos": jnp.asarray(L, jnp.int32)}
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        got = []
        for _ in range(STEPS):
            got.append(np.asarray(cur[:, 0]))
            ld, cache = compiler.execute_decode(dprog, params, cache, cur,
                                                eng)
            cur = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.stack(got, axis=1), want)

    def test_dynamic_decode_logits_bitwise_vs_eager(self):
        """Stronger than id parity on the float path: the compiled decode
        step's logits equal eager T.decode's bit for bit."""
        arch, params, toks = _setup("gemma2-2b")
        max_seq = L + 3
        dprog = compiler.compile_lm(arch, mode="decode")
        cache = _cache(arch, B, max_seq, ENG)
        _, cache = T.prefill(params, cache, {"tokens": toks}, arch, ENG,
                             compute_dtype=jnp.float32)
        cache2 = jtu.tree_map(lambda x: x, cache)
        tok = toks[:, -1:]
        for _ in range(3):
            le, cache = T.decode(params, cache, tok, arch, ENG,
                                 compute_dtype=jnp.float32)
            lp, cache2 = compiler.execute_decode(dprog, params, cache2, tok,
                                                 ENG)
            np.testing.assert_array_equal(np.asarray(le), np.asarray(lp))
            tok = jnp.argmax(le[:, -1], -1)[:, None].astype(jnp.int32)


# ---------------------------------------------------------------------------
# ServeEngine: compiled decode burst + continuous batching
# ---------------------------------------------------------------------------

class TestServeEngineDecode:
    def test_decode_burst_executes_cached_program(self):
        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(0)
        calib = [jnp.array(rng.integers(0, arch.vocab_size,
                                        (2, 8)).astype(np.int32))]
        se = ServeEngine(arch, params, W8, batch_size=2, max_seq=32,
                         calib_batches=calib)
        prompts = [rng.integers(0, arch.vocab_size, size=6)
                   for _ in range(2)]
        se.generate(prompts, max_new_tokens=2)
        # two compiles: prefill + decode, no more on re-serve
        assert se.cache.stats.misses == 2
        d = se.decode_program()
        assert d.static and d.kind == "decode" and d.f32_roundtrips() == 0
        se.generate(prompts, max_new_tokens=2)
        assert se.cache.stats.misses == 2
        st = se.stats()
        assert st["compiled_decode"] and st["decode_levels"] > 0
        assert st["decode_steps"] > 0

    def test_prefill_and_decode_cache_keys_distinct(self):
        arch, params, _ = _setup("qwen2-1.5b")
        se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32)
        assert se._prefill_key() != se._decode_key()
        se.prefill_program()
        se.decode_program()
        variants = {k.variant for k in se.cache.keys()}
        assert len(variants) == 2

    def test_compiled_matches_eager_engine_ids(self):
        """Engine-level golden: compiled prefill+decode serving produces
        the same greedy ids as a ServeEngine with both programs disabled
        (the all-eager path), float fabric."""
        arch, params, _ = _setup("gemma2-2b")
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, arch.vocab_size, size=6)
                   for _ in range(3)]
        a = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32,
                        prefill_len=6).generate(prompts, max_new_tokens=3)
        # eager engine decodes in bf16; compare against the f32 reference
        # loop instead, which the compiled path must match bitwise
        want = _greedy_ids(arch, params, prompts[:1] + prompts[1:],
                           ENG, 3, max_seq=32)
        for got, ref in zip(a, want):
            np.testing.assert_array_equal(got, ref)

    def test_continuous_refill_serves_deep_queue(self):
        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(2)
        se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32,
                         decode_burst=2)
        prompts = [rng.integers(0, arch.vocab_size, size=5)
                   for _ in range(7)]
        outs = se.generate(prompts, max_new_tokens=3)
        assert len(outs) == 7
        assert all(len(o) == 3 for o in outs)
        st = se.stats()
        assert st["slot_refills"] >= 5          # 7 requests, 2 slots
        assert st["slot_refill_rate"] > 0.5
        assert 0 < st["slot_occupancy"] <= 1

    def test_arrival_order_invariance(self):
        """The continuous-batching property: any submission order yields
        the same per-request token ids as serial serving (slot placement
        and batch composition cannot leak between rows)."""
        arch, params, _ = _setup("qwen2-1.5b")
        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, arch.vocab_size, size=6)
                   for _ in range(6)]
        serial = {}
        for i, p in enumerate(prompts):
            se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32,
                             prefill_len=6)
            serial[i] = se.generate([p], max_new_tokens=3)[0]
        for seed in range(3):
            order = list(range(len(prompts)))
            np.random.default_rng(seed).shuffle(order)
            se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32,
                             prefill_len=6, decode_burst=1 + seed)
            tickets = {i: se.submit(prompts[i], 3) for i in order}
            res = se.run()
            for i in order:
                np.testing.assert_array_equal(res[tickets[i]], serial[i],
                                              err_msg=f"req {i} seed {seed}")

    def test_eager_fallback_reports_blockers(self):
        """A non-lowerable arch serves through the same continuous
        scheduler on the eager path, and stats() says WHY it fell back."""
        arch = configs.reduced(configs.get_arch("falcon-mamba-7b"))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32)
        assert not se.compiled and not se.compiled_decode
        prompts = [rng.integers(0, arch.vocab_size, size=5)
                   for _ in range(3)]
        outs = se.generate(prompts, max_new_tokens=2)
        assert len(outs) == 3 and all(len(o) == 2 for o in outs)
        st = se.stats()
        assert st["lowering_blockers"], "fallback must not be silent"
        assert any("mamba" in b for b in st["lowering_blockers"])
        assert st["slot_refills"] >= 1

    def test_oversized_prompt_rejected(self):
        arch, params, _ = _setup("qwen2-1.5b")
        se = ServeEngine(arch, params, ENG, batch_size=2, max_seq=16)
        # queue-level backpressure: a falsy SubmitRejection, not a raise
        rej = se.submit(np.zeros(12, np.int32), max_new_tokens=8)
        assert isinstance(rej, SubmitRejection) and not rej
        assert rej.reason == "over_length"
        assert se.stats()["rejected_requests"] == 1
        assert se.pending() == 0
        # a 0-token request would never own its slot; reject at submit
        with pytest.raises(ValueError):
            se.submit(np.zeros(4, np.int32), max_new_tokens=0)
        se2 = ServeEngine(arch, params, ENG, batch_size=2, max_seq=32,
                          prefill_len=4)
        se2.submit(np.zeros(8, np.int32), max_new_tokens=2)
        with pytest.raises(ValueError):
            se2.run()
