"""Per-arch reduced smoke tests: one forward + one train step on CPU,
asserting output shapes and finiteness (the assignment's smoke contract)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.config import EngineConfig, ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.params import init_params
from repro.train.train_step import init_train_state, make_train_step

ENG = EngineConfig(quant="none", backend="ref")
ALL_ARCHS = configs.list_archs()


def _batch(arch, rng, b=2, l=16):
    tokens = rng.integers(0, arch.vocab_size, (b, l + 1)).astype(np.int32)
    batch = {"tokens": jnp.array(tokens[:, :l]),
             "labels": jnp.array(tokens[:, 1:])}
    if arch.family == "vlm":
        batch = {
            "embeds": jnp.array(
                rng.normal(size=(b, l, arch.d_model)).astype(np.float32)),
            "positions": jnp.broadcast_to(
                jnp.arange(l)[None, :, None], (b, l, 3)).astype(jnp.int32),
            "labels": jnp.array(tokens[:, 1:]),
        }
    elif arch.family == "audio":
        batch["enc_embeds"] = jnp.array(rng.normal(
            size=(b, arch.encoder_seq, arch.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward(name, rng):
    arch = configs.reduced(configs.get_arch(name))
    is_audio = arch.family == "audio"
    schema = (W.whisper_schema(arch, max_dec_pos=64) if is_audio
              else T.lm_schema(arch))
    params = init_params(schema, jax.random.PRNGKey(0))
    batch = _batch(arch, rng)
    mod = W if is_audio else T
    logits, aux = mod.forward(params, batch, arch, ENG)
    b = 2
    l = batch["labels"].shape[1]
    assert logits.shape == (b, l, arch.vocab_size)
    assert np.isfinite(np.array(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name, rng):
    arch = configs.reduced(configs.get_arch(name))
    is_audio = arch.family == "audio"
    schema = (W.whisper_schema(arch, max_dec_pos=64) if is_audio
              else T.lm_schema(arch))
    params = init_params(schema, jax.random.PRNGKey(0))
    state = init_train_state(params)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(arch, ENG, tcfg), donate_argnums=(0,))
    batch = _batch(arch, rng)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(state["opt"]["step"]) == 1
    # a second step with the same batch must reduce the loss
    state, metrics2 = step(state, batch)
    assert float(metrics2["loss"]) < loss


def test_remat_matches_no_remat(rng):
    arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.array(rng.integers(0, 64, (2, 8)).astype(np.int32))}
    l1, _ = T.forward(params, batch, arch, ENG, remat="none",
                      compute_dtype=jnp.float32)
    l2, _ = T.forward(params, batch, arch, ENG, remat="block",
                      compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.array(l1), np.array(l2), rtol=1e-5,
                               atol=1e-5)


def test_microbatched_grads_match(rng):
    """Gradient accumulation over 2 microbatches == single batch."""
    arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    tok = rng.integers(0, arch.vocab_size, (4, 9)).astype(np.int32)
    batch = {"tokens": jnp.array(tok[:, :8]), "labels": jnp.array(tok[:, 1:])}
    t1 = TrainConfig(microbatches=1, z_loss=0.0)
    t2 = TrainConfig(microbatches=2, z_loss=0.0)
    s1, m1 = make_train_step(arch, ENG, t1)(init_train_state(params), batch)
    s2, m2 = make_train_step(arch, ENG, t2)(init_train_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-4)
    p1 = jax.tree_util.tree_leaves(s1["params"])
    p2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-3,
                                   atol=2e-4)


def test_param_count_matches_schema():
    """ArchConfig.param_count() tracks the real schema within 2%
    (it is the roofline's N)."""
    from repro.models.params import param_count
    for name in ALL_ARCHS:
        arch = configs.get_arch(name)
        if arch.family == "audio":
            continue                        # whisper counted separately
        schema = T.lm_schema(arch)
        real = param_count(schema)
        approx = arch.param_count()
        assert abs(real - approx) / real < 0.02, (name, real, approx)


def test_grok_is_314b_scale():
    arch = configs.get_arch("grok-1-314b")
    n = arch.param_count()
    assert 2.8e11 < n < 3.6e11, n
    assert arch.active_param_count() < 0.35 * n
