"""Compiler layer: op-graph lowering, calibration, requant folding, and the
compiled static-int8 engine program vs the eager dynamic path."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import compiler
from repro.compiler import passes
from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, InputOp,
                                  LinearOp, PoolOp)
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.core.config import CNNConfig, ConvSpec as C, EngineConfig
from repro.core.quant import QTensor
from repro.models import cnn
from repro.models.params import init_params

SMALL_HW = 32


def _small(cfg):
    return dataclasses.replace(cfg, input_hw=SMALL_HW)


def _setup(name, seed=0, batch=2):
    cfg = _small(CNN_ZOO[name])
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(batch, cfg.input_hw, cfg.input_hw, cfg.input_ch)
    ).astype(np.float32) * 0.5)
    return cfg, params, x


# ---------------------------------------------------------------------------
# Graph construction
# ---------------------------------------------------------------------------

class TestGraph:
    def test_all_six_stage_kinds_lower(self):
        """One synthetic config exercising every stage kind."""
        cfg = CNNConfig(
            name="allkinds", input_hw=32, input_ch=3,
            stem_kernel=3, stem_stride=2, stem_ch=16,
            stages=(
                C("conv", out_ch=32, kernel=3, stride=1, repeat=1),
                C("pool", kernel=2, stride=2),
                C("bottleneck", out_ch=32, kernel=3, stride=1, repeat=1),
                C("inverted", out_ch=32, kernel=3, stride=1, repeat=1,
                  expand=2),
                C("dwsep", out_ch=64, kernel=3, stride=1, repeat=1),
                C("fire", out_ch=64, kernel=3, stride=1, repeat=1),
            ), num_classes=10)
        g = compiler.build_graph(cfg)
        assert g.count(InputOp) == 1
        assert g.count(ConvOp) >= 9          # stem + stage convs
        assert g.count(DwcOp) == 2           # inverted + dwsep
        assert g.count(AddOp) == 2           # bottleneck + inverted residual
        assert g.count(PoolOp) == 2          # max pool + global avgpool
        assert g.count(ConcatOp) == 1        # fire expand concat
        assert g.count(LinearOp) == 1        # head
        # topological: every input id precedes its consumer
        for n in g.nodes:
            assert all(i < n.id for i in n.inputs)
        assert isinstance(g.nodes[g.output], LinearOp)

    def test_graph_matches_schema_for_zoo(self):
        """Every zoo model builds, and every param path resolves against the
        schema-shaped param tree."""
        for name, cfg0 in CNN_ZOO.items():
            cfg = _small(cfg0)
            params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(0))
            g = compiler.build_graph(cfg)
            for n in g.nodes:
                for path in (getattr(n, "w", None), getattr(n, "b", None)):
                    if path:
                        leaf = compiler.get_param(params, path)
                        assert hasattr(leaf, "shape"), (name, path)

    def test_bottleneck_residual_shapes(self):
        g = compiler.build_graph(_small(CNN_ZOO["resnet50"]))
        chains = passes.residual_chains(g)
        assert len(chains) >= 16             # 3+4+6+3 blocks, some 2-input
        for conv_id, add_id in chains:
            assert isinstance(g.nodes[add_id], AddOp)


# ---------------------------------------------------------------------------
# Dynamic program == eager path (cnn_forward is the thin wrapper)
# ---------------------------------------------------------------------------

class TestDynamicProgram:
    @pytest.mark.parametrize("name", ["resnet50", "mobilenetv2",
                                      "squeezenet"])
    def test_float_forward_finite(self, name):
        cfg, params, x = _setup(name)
        eng = EngineConfig(quant="none", backend="ref")
        prog = compiler.compile_cnn(cfg)
        out = compiler.execute(prog, params, x, eng)
        assert out.shape == (2, cfg.num_classes)
        assert np.isfinite(np.array(out)).all()
        # and cnn_forward is exactly this program
        np.testing.assert_array_equal(
            np.array(out), np.array(cnn.cnn_forward(params, x, cfg, eng)))


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

class TestCalibration:
    def test_records_scale_for_every_edge(self):
        cfg, params, x = _setup("mobilenetv2")
        g = compiler.build_graph(cfg)
        scales = compiler.calibrate(g, params, [x], cfg)
        assert set(scales) == {n.id for n in g.nodes}
        assert all(s > 0 for s in scales.values())

    def test_running_absmax_over_batches(self):
        """The recorded scale is the max over all batches (running absmax)."""
        cfg, params, x = _setup("squeezenet")
        g = compiler.build_graph(cfg)
        s1 = compiler.calibrate(g, params, [x], cfg)
        s2 = compiler.calibrate(g, params, [x, 3.0 * x], cfg)
        assert s2[0] > s1[0]                 # input edge saw a larger range
        assert all(s2[i] >= s1[i] - 1e-12 for i in s1)

    def test_rejects_quantized_engine(self):
        cfg, params, x = _setup("squeezenet")
        g = compiler.build_graph(cfg)
        with pytest.raises(ValueError):
            compiler.calibrate(g, params, [x], cfg,
                               eng=EngineConfig(quant="w8a8"))


# ---------------------------------------------------------------------------
# Requant folding / fusion
# ---------------------------------------------------------------------------

class TestPasses:
    def test_no_f32_roundtrips_on_conv_add_relu_chains(self):
        """The fusion criterion: in the compiled static program every
        conv->add->relu chain stays int8 -- the conv epilogue requants into
        the MISC add's input scale and the add requants its output, with no
        f32 tensor materialized between engines."""
        cfg, params, x = _setup("resnet50")
        prog = compiler.compile_calibrated(cfg, params, [x])
        assert passes.f32_roundtrip_edges(prog.graph, prog.plan) == []
        assert prog.f32_roundtrips() == 0
        for conv_id, add_id in passes.residual_chains(prog.graph):
            assert prog.plan.emit_int8[conv_id]
            assert prog.plan.emit_int8[add_id]
        # while the dynamic program round-trips every internal edge
        assert compiler.compile_cnn(cfg).f32_roundtrips() > 50

    def test_maxpool_scale_preserving(self):
        cfg, params, x = _setup("resnet50")
        g = compiler.build_graph(cfg)
        scales = compiler.calibrate(g, params, [x], cfg)
        plan = compiler.fold_requant(g, scales)
        for n in g.nodes:
            if isinstance(n, PoolOp) and n.pool == "max":
                assert plan.out_scale[n.id] == plan.out_scale[n.inputs[0]]

    def test_concat_branches_folded_to_one_scale(self):
        cfg, params, x = _setup("squeezenet")
        prog = compiler.compile_calibrated(cfg, params, [x])
        g, plan = prog.graph, prog.plan
        folded = dict((p, c) for p, c in plan.folded)
        for n in g.nodes:
            if isinstance(n, ConcatOp):
                for p in n.inputs:
                    assert plan.out_scale[p] == plan.out_scale[n.id]
                    assert folded.get(p) == n.id
        assert plan.stats["folded_requants"] >= 16   # 8 fire modules x 2

    def test_missing_scales_rejected(self):
        g = compiler.build_graph(_small(CNN_ZOO["squeezenet"]))
        with pytest.raises(ValueError):
            compiler.fold_requant(g, {0: 1.0})


# ---------------------------------------------------------------------------
# End-to-end: compiled static int8 vs eager reference
# ---------------------------------------------------------------------------

class TestStaticProgram:
    @pytest.mark.parametrize("name", ["resnet50", "mobilenetv2"])
    def test_matches_eager_within_quant_tolerance(self, name):
        """ResNet-style and MobileNet-style: the compiled static-int8
        program agrees with both the float path and the eager dynamic w8a8
        path within quantization tolerance (rank correlation, as in
        test_cnn.test_quantized_close_to_float)."""
        cfg, params, x = _setup(name)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        f = np.array(cnn.cnn_forward(
            params, x, cfg, EngineConfig(quant="none", backend="ref")))
        dyn = np.array(cnn.cnn_forward(qparams, x, cfg, eng))
        prog = compiler.compile_calibrated(cfg, params, [x])
        stat = np.array(compiler.execute(prog, qparams, x, eng))
        assert np.isfinite(stat).all()
        assert np.corrcoef(f.ravel(), stat.ravel())[0, 1] > 0.7
        assert np.corrcoef(dyn.ravel(), stat.ravel())[0, 1] > 0.7

    def test_all_intermediates_int8(self):
        """Structural check on the executed values: every internal edge of
        the static program carries int8."""
        cfg, params, x = _setup("mobilenetv2")
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        out = compiler.execute(prog, qparams, x, eng)
        assert out.dtype == jnp.float32      # only the logits are f32
        assert all(prog.plan.emit_int8[n.id] for n in prog.graph.nodes
                   if n.id != prog.graph.output)

    def test_static_program_on_pallas_backend(self):
        """The same compiled program runs on the Pallas kernels and matches
        the ref backend (the engines' out_scale epilogues agree)."""
        cfg, params, x = _setup("mobilenetv2")
        engr = EngineConfig(quant="w8a8", backend="ref")
        engp = EngineConfig(quant="w8a8", backend="pallas", interpret=True)
        qparams = eng_lib.quantize_params(params, engr)
        prog = compiler.compile_calibrated(cfg, params, [x])
        r = np.array(compiler.execute(prog, qparams, x, engr))
        p = np.array(compiler.execute(prog, qparams, x, engp))
        assert np.corrcoef(r.ravel(), p.ravel())[0, 1] > 0.99

    def test_static_program_jits(self):
        cfg, params, x = _setup("squeezenet")
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        eager = np.array(compiler.execute(prog, qparams, x, eng))
        jitted = np.array(jax.jit(
            lambda p, im: compiler.execute(prog, p, im, eng))(qparams, x))
        np.testing.assert_allclose(jitted, eager, rtol=1e-5, atol=1e-5)

    def test_requires_quantized_params(self):
        cfg, params, x = _setup("squeezenet")
        prog = compiler.compile_calibrated(cfg, params, [x])
        with pytest.raises(ValueError, match="QTensor"):
            compiler.execute(prog, params, x,
                             EngineConfig(quant="w8a8", backend="ref"))


# ---------------------------------------------------------------------------
# Compile-time weight-layout folding (im2col reshape, DWC lane padding)
# ---------------------------------------------------------------------------

class TestWeightLayoutFolding:
    def test_conv_weights_prelaid_and_dwc_padded(self):
        cfg, params, x = _setup("mobilenetv2")
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        g = compiler.build_graph(cfg)
        folded = passes.fold_weight_layouts(g, qparams)
        for n in g.nodes:
            if isinstance(n, ConvOp) and not n.first_layer:
                w = compiler.get_param(folded, n.w)
                assert w.q.ndim == 2              # im2col GEMM layout
                assert w.scale.shape[0] == 1
            elif isinstance(n, ConvOp):
                assert compiler.get_param(folded, n.w).q.ndim == 4  # stem
            elif isinstance(n, DwcOp):
                w = compiler.get_param(folded, n.w)
                assert w.q.shape[2] % 128 == 0    # lane-aligned
                b = compiler.get_param(folded, n.b)
                assert b.shape[0] == w.q.shape[2]
        # untouched leaves are shared, not copied
        assert compiler.get_param(folded, ("stem_w",)) is \
            compiler.get_param(qparams, ("stem_w",))

    @pytest.mark.parametrize("name", ["mobilenetv2", "resnet50"])
    def test_folded_execution_bit_identical(self, name):
        """Reshape and zero-padding do not touch values: the folded tree
        executes bit-identically, static and dynamic, ref and pallas."""
        cfg, params, x = _setup(name)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        folded = passes.fold_weight_layouts(prog.graph, qparams)
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(prog, folded, x, eng))
        np.testing.assert_array_equal(a, b)
        engp = EngineConfig(quant="w8a8", backend="pallas", interpret=True)
        ap = np.array(compiler.execute(prog, qparams, x, engp))
        bp = np.array(compiler.execute(prog, folded, x, engp))
        np.testing.assert_array_equal(ap, bp)

    def test_folded_float_and_dynamic_paths(self):
        cfg, params, x = _setup("mobilenetv1")
        eng = EngineConfig(quant="none", backend="ref")
        prog = compiler.compile_cnn(cfg)
        folded = passes.fold_weight_layouts(prog.graph, params)
        a = np.array(compiler.execute(prog, params, x, eng))
        b = np.array(compiler.execute(prog, folded, x, eng))
        np.testing.assert_array_equal(a, b)

    def test_folding_idempotent(self):
        cfg, params, x = _setup("mobilenetv2")
        g = compiler.build_graph(cfg)
        once = passes.fold_weight_layouts(g, params)
        twice = passes.fold_weight_layouts(g, once)
        for a, b in zip(jax.tree_util.tree_leaves(once),
                        jax.tree_util.tree_leaves(twice)):
            assert a is b

    def test_baseline_engine_unfolds_dwc(self):
        """The dense-diagonal DWC baseline works on true channels: folded
        (pre-padded) weights still execute correctly there."""
        cfg, params, x = _setup("mobilenetv1")
        eng = eng_lib.baseline_engine()
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        folded = passes.fold_weight_layouts(prog.graph, qparams)
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(prog, folded, x, eng))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_serving_engine_folds_transparently(self):
        """CNNServeEngine binds folded params: results match the jitted
        unfolded program execution bitwise (jit-vs-jit, since XLA's fusion
        can flip requant-boundary rounding against the eager run)."""
        from repro.serve.cnn_engine import CNNServeEngine
        cfg, params, x = _setup("mobilenetv2")
        eng = EngineConfig(quant="w8a8", backend="ref")
        engine = CNNServeEngine(eng, wave_size=2)
        engine.register(cfg, params, calib_batches=[x])
        imgs = np.asarray(x)
        got = engine.infer(cfg.name, imgs)
        prog = engine.program_for(cfg.name)
        qparams = eng_lib.quantize_params(params, eng)
        want = np.array(jax.jit(
            lambda p, im: compiler.execute(prog, p, im, eng))(qparams, x))
        np.testing.assert_array_equal(got, want)
        m = engine._models[cfg.name]
        assert m.folded is not None and m.folded[0] is prog


# ---------------------------------------------------------------------------
# Golden dynamic-vs-static parity across the whole zoo, on both backends
# ---------------------------------------------------------------------------

# Max |static - dynamic| logit gap, as a fraction of max |dynamic logit|
# (absolute logit magnitudes at random init vary by orders of magnitude
# across the zoo, so the bound is relative).  Values are ~2.5x the measured
# gap at seed 0, hw=32: the requant-rounding drift of each model's depth /
# branch structure.  A regression that breaks the static plan (wrong scale,
# dropped fold, misrouted epilogue) blows far past these.
GOLDEN_GAP_FRAC = {
    "resnet50": 0.10,
    "resnet152": 0.13,
    "mobilenetv1": 0.20,
    "mobilenetv2": 0.35,
    "efficientnet": 0.18,
    "squeezenet": 0.12,
    "yolov3": 0.12,
    "yolov5n": 0.10,
}


@pytest.fixture(scope="module")
def zoo_golden():
    """Shared per-config setup: one calibration + compile per model, reused
    by both backend parametrizations."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg, params, x = _setup(name)
            prog = compiler.compile_calibrated(cfg, params, [x])
            f = np.array(cnn.cnn_forward(
                params, x, cfg, EngineConfig(quant="none", backend="ref")))
            cache[name] = (cfg, params, x, prog, f)
        return cache[name]

    return get


class TestGoldenZooParity:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("name", sorted(CNN_ZOO))
    def test_dynamic_vs_static_gap_bounded(self, name, backend, zoo_golden):
        """Every zoo config: the compiled static-int8 program tracks the
        eager dynamic w8a8 path within the golden max-logit-gap bound, and
        both correlate with the float reference."""
        cfg, params, x, prog, f = zoo_golden(name)
        eng = EngineConfig(quant="w8a8", backend=backend, interpret=True)
        qparams = eng_lib.quantize_params(params, eng)
        dyn = np.array(cnn.cnn_forward(qparams, x, cfg, eng))
        stat = np.array(compiler.execute(prog, qparams, x, eng))
        assert np.isfinite(stat).all() and np.isfinite(dyn).all()
        gap = np.max(np.abs(stat - dyn))
        bound = GOLDEN_GAP_FRAC[name] * np.max(np.abs(dyn))
        assert gap <= bound, (name, backend, gap, bound)
        assert np.corrcoef(f.ravel(), stat.ravel())[0, 1] > 0.9
