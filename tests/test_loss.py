"""Loss functions: fused chunked-vocab CE == standard CE."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.quant import quantize
from repro.train import loss as L


def test_ce_matches_manual(rng):
    logits = jnp.array(rng.normal(size=(2, 4, 16)).astype(np.float32))
    labels = jnp.array(rng.integers(0, 16, (2, 4)).astype(np.int32))
    loss, m = L.cross_entropy(logits, labels)
    p = jax.nn.log_softmax(np.array(logits), axis=-1)
    want = -np.take_along_axis(p, np.array(labels)[..., None], -1).mean()
    np.testing.assert_allclose(float(loss), want, rtol=1e-6)


@settings(deadline=None, max_examples=10)
@given(v=st.integers(10, 300), chunk=st.sampled_from([7, 32, 128]),
       tied=st.booleans())
def test_fused_ce_equals_standard(v, chunk, tied):
    rng = np.random.default_rng(v)
    b, l, d = 2, 3, 8
    x = jnp.array(rng.normal(size=(b, l, d)).astype(np.float32))
    emb = jnp.array(rng.normal(size=((v, d) if tied else (d, v))
                               ).astype(np.float32))
    labels = jnp.array(rng.integers(0, v, (b, l)).astype(np.int32))
    logits = (jnp.einsum("bld,vd->blv", x, emb) if tied
              else jnp.einsum("bld,dv->blv", x, emb))
    want, _ = L.cross_entropy(logits, labels, z_loss=1e-4)
    got, _ = L.fused_ce_loss(x, emb, labels, transpose_emb=tied,
                             z_loss=1e-4, chunk=chunk)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-6)


def test_fused_ce_with_softcap(rng):
    b, l, d, v = 2, 3, 8, 50
    x = jnp.array(rng.normal(size=(b, l, d)).astype(np.float32))
    emb = jnp.array(rng.normal(size=(v, d)).astype(np.float32))
    labels = jnp.array(rng.integers(0, v, (b, l)).astype(np.int32))
    logits = jnp.einsum("bld,vd->blv", x, emb)
    logits = jnp.tanh(logits / 30.0) * 30.0
    want, _ = L.cross_entropy(logits, labels)
    got, _ = L.fused_ce_loss(x, emb, labels, transpose_emb=True,
                             chunk=16, final_softcap=30.0)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_fused_ce_quantized_embed(rng):
    b, l, d, v = 2, 3, 16, 64
    x = jnp.array(rng.normal(size=(b, l, d)).astype(np.float32))
    emb = jnp.array(rng.normal(size=(v, d)).astype(np.float32))
    qt = quantize(emb, axis=0)
    labels = jnp.array(rng.integers(0, v, (b, l)).astype(np.int32))
    logits = jnp.einsum("bld,vd->blv", x, qt.dequant())
    want, _ = L.cross_entropy(logits, labels)
    got, _ = L.fused_ce_loss(x, qt, labels, transpose_emb=True, chunk=16)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_fused_ce_grads_match(rng):
    """d(loss)/dx must agree between fused and standard paths."""
    b, l, d, v = 1, 2, 8, 40
    x = rng.normal(size=(b, l, d)).astype(np.float32)
    emb = jnp.array(rng.normal(size=(v, d)).astype(np.float32))
    labels = jnp.array(rng.integers(0, v, (b, l)).astype(np.int32))

    def f_std(x):
        logits = jnp.einsum("bld,vd->blv", x, emb)
        return L.cross_entropy(logits, labels)[0]

    def f_fused(x):
        return L.fused_ce_loss(x, emb, labels, transpose_emb=True,
                               chunk=16)[0]

    g1 = jax.grad(f_std)(jnp.array(x))
    g2 = jax.grad(f_fused)(jnp.array(x))
    np.testing.assert_allclose(np.array(g1), np.array(g2), rtol=1e-4,
                               atol=1e-6)
