"""Sharding machinery: logical-axis resolution, ZeRO-1 specs, and a
subprocess mini dry-run on a fake multi-device mesh."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import params as prm
from repro.models import transformer as T


class FakeMesh:
    """Shape-only stand-in (tests run on 1 device)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestResolve:
    def test_divisible(self):
        assert prm.resolve_pspec(MESH, (4096, 14336), ("fsdp", "tp")) == \
            P("data", "model")

    def test_non_divisible_drops(self):
        # 8 kv heads * 128 = 1024 divisible; 49155 vocab is not.
        assert prm.resolve_pspec(MESH, (49155, 1024), ("tp", None)) == P()

    def test_dp_composes_pods(self):
        assert prm.resolve_pspec(POD, (256, 4096), ("dp", None)) == \
            P(("pod", "data"))

    def test_axis_used_once(self):
        spec = prm.resolve_pspec(MESH, (256, 256), ("tp", "tp"))
        assert spec == P("model")      # second use dropped

    def test_all_arch_param_specs_resolve(self):
        """Every param of every arch gets a valid spec on both meshes."""
        for name in configs.list_archs():
            arch = configs.get_arch(name)
            if arch.family == "audio":
                from repro.models import whisper as W
                schema = W.whisper_schema(arch)
            else:
                schema = T.lm_schema(arch)
            for mesh in (MESH, POD):
                tree = prm.pspec_tree(schema, mesh)
                leaves = jax.tree_util.tree_leaves(
                    tree, is_leaf=lambda x: isinstance(x, P))
                assert all(isinstance(l, P) for l in leaves), name

    def test_tp_coverage(self):
        """The big matrices must actually shard over the model axis."""
        arch = configs.get_arch("granite-8b")
        schema = T.lm_schema(arch)
        specs = prm.pspec_tree(schema, MESH)
        blk = specs["blocks"][0]
        assert "model" in tuple(blk["attn"]["wq"])
        assert "model" in tuple(blk["mlp"]["wu"])


class TestZero1:
    def test_adds_data_axis(self):
        from repro.train.optim import zero1_pspec

        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        sp = zero1_pspec(P(None, "model"), (4096, 14336), M())
        assert sp == P("data", "model")

    def test_respects_existing_fsdp(self):
        from repro.train.optim import zero1_pspec

        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        sp = zero1_pspec(P("data", "model"), (4096, 14336), M())
        assert sp == P("data", "model")

    def test_non_divisible_stays(self):
        from repro.train.optim import zero1_pspec

        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        assert zero1_pspec(P(), (3,), M()) == P()


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.core.config import ShapeConfig
from repro.core import engine as eng_lib
from repro.launch import build as B
from repro.launch import mesh as mesh_lib
mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
arch = configs.reduced(configs.get_arch("gemma2-2b"))
arch = dataclasses.replace(arch, vocab_size=256)
import repro.core.config as cc
cc.SHAPES["mini"] = ShapeConfig("mini", 64, 8, "train")
prog = B.build(arch.name, "mini", mesh, arch=arch)
lowered = prog.fn.lower(*prog.args)
compiled = lowered.compile()
txt = compiled.as_text()
assert any(k in txt for k in ("all-reduce", "all-gather", "reduce-scatter")), \
    "expected collectives in the partitioned module"
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):        # older jax returns [dict]
    cost = cost[0] if cost else {}
print("MINI_DRYRUN_OK", cost.get("flops", 0.0) > 0)
"""


def test_mini_dryrun_multidevice():
    """End-to-end: production builder lowers+compiles on a fake 8-device
    mesh and the partitioned module contains collectives."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MINI_DRYRUN_OK True" in out.stdout, out.stdout + out.stderr
