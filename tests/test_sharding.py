"""Sharding machinery: logical-axis resolution, ZeRO-1 specs, and a
subprocess mini dry-run on a fake multi-device mesh."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import params as prm
from repro.models import transformer as T


class FakeMesh:
    """Shape-only stand-in (tests run on 1 device)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestResolve:
    def test_divisible(self):
        assert prm.resolve_pspec(MESH, (4096, 14336), ("fsdp", "tp")) == \
            P("data", "model")

    def test_non_divisible_drops(self):
        # 8 kv heads * 128 = 1024 divisible; 49155 vocab is not.
        assert prm.resolve_pspec(MESH, (49155, 1024), ("tp", None)) == P()

    def test_dp_composes_pods(self):
        assert prm.resolve_pspec(POD, (256, 4096), ("dp", None)) == \
            P(("pod", "data"))

    def test_axis_used_once(self):
        spec = prm.resolve_pspec(MESH, (256, 256), ("tp", "tp"))
        assert spec == P("model")      # second use dropped

    def test_all_arch_param_specs_resolve(self):
        """Every param of every arch gets a valid spec on both meshes."""
        for name in configs.list_archs():
            arch = configs.get_arch(name)
            if arch.family == "audio":
                from repro.models import whisper as W
                schema = W.whisper_schema(arch)
            else:
                schema = T.lm_schema(arch)
            for mesh in (MESH, POD):
                tree = prm.pspec_tree(schema, mesh)
                leaves = jax.tree_util.tree_leaves(
                    tree, is_leaf=lambda x: isinstance(x, P))
                assert all(isinstance(l, P) for l in leaves), name

    def test_tp_coverage(self):
        """The big matrices must actually shard over the model axis."""
        arch = configs.get_arch("granite-8b")
        schema = T.lm_schema(arch)
        specs = prm.pspec_tree(schema, MESH)
        blk = specs["blocks"][0]
        assert "model" in tuple(blk["attn"]["wq"])
        assert "model" in tuple(blk["mlp"]["wu"])


class TestZero1:
    def test_adds_data_axis(self):
        from repro.train.optim import zero1_pspec

        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        sp = zero1_pspec(P(None, "model"), (4096, 14336), M())
        assert sp == P("data", "model")

    def test_respects_existing_fsdp(self):
        from repro.train.optim import zero1_pspec

        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        sp = zero1_pspec(P("data", "model"), (4096, 14336), M())
        assert sp == P("data", "model")

    def test_non_divisible_stays(self):
        from repro.train.optim import zero1_pspec

        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        assert zero1_pspec(P(), (3,), M()) == P()


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.core.config import ShapeConfig
from repro.core import engine as eng_lib
from repro.launch import build as B
from repro.launch import mesh as mesh_lib
mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
arch = configs.reduced(configs.get_arch("gemma2-2b"))
arch = dataclasses.replace(arch, vocab_size=256)
import repro.core.config as cc
cc.SHAPES["mini"] = ShapeConfig("mini", 64, 8, "train")
prog = B.build(arch.name, "mini", mesh, arch=arch)
lowered = prog.fn.lower(*prog.args)
compiled = lowered.compile()
txt = compiled.as_text()
assert any(k in txt for k in ("all-reduce", "all-gather", "reduce-scatter")), \
    "expected collectives in the partitioned module"
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):        # older jax returns [dict]
    cost = cost[0] if cost else {}
print("MINI_DRYRUN_OK", cost.get("flops", 0.0) > 0)
"""


def test_mini_dryrun_multidevice():
    """End-to-end: production builder lowers+compiles on a fake 8-device
    mesh and the partitioned module contains collectives."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MINI_DRYRUN_OK True" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# Serving-mesh placement (serve/mesh_exec.py): topology keys, slot pools,
# the whole-head TP rule, and sharded-vs-single-device parity
# ---------------------------------------------------------------------------


class TestMeshTopologyKeys:
    def test_program_key_mesh_disambiguates(self):
        from repro.core.program_cache import ProgramKey
        from repro.serve.mesh_exec import MeshTopology

        t2 = MeshTopology((("data", 2), ("model", 1)))
        t8 = MeshTopology((("data", 8), ("model", 1)))
        k2 = ProgramKey("m", "e", None, "scheduled", mesh=t2)
        k8 = ProgramKey("m", "e", None, "scheduled", mesh=t8)
        assert k2 != k8 and hash(k2) != hash(k8)
        assert {k2: 1, k8: 2}[k2] == 1

    def test_program_key_default_mesh_back_compat(self):
        """Old positional constructions (no mesh) still work and equal the
        explicit-None form -- single-device cache keys are unchanged."""
        from repro.core.program_cache import ProgramKey

        assert ProgramKey("m", "e", None, "v") == \
            ProgramKey("m", "e", None, "v", mesh=None)

    def test_topology_descriptor(self):
        from repro.serve.mesh_exec import MeshTopology

        t = MeshTopology((("data", 8), ("model", 1)))
        assert t.devices == 8 and t.size("data") == 8
        assert t.size("missing") == 1
        assert str(t) == "mesh[8x1;data,model]"


class TestSlotPools:
    def _sched(self, slots=2, pools=2):
        from repro.serve.base import SlotScheduler
        return SlotScheduler(slots, pools=pools)

    def test_wave_slots_scales_with_pools(self):
        assert self._sched(slots=2, pools=4).wave_slots == 8
        assert self._sched(slots=3, pools=1).wave_slots == 3

    def test_locality_packing_sticky_home_pools(self):
        """Two affinity keys on a 2-pool scheduler: each gets a sticky
        home pool and a full wave places every request at home."""
        s = self._sched(slots=2, pools=2)
        for i in range(2):
            s.submit("g", ("a", i), affinity="a")
        for i in range(2):
            s.submit("g", ("b", i), affinity="b")
        wave = s.take_wave("g")
        assert len(wave) == 4
        payloads = [p for _, p in wave]
        # pool 0 = rows [0,2) -> model "a" (first seen), pool 1 -> "b"
        assert {p[0] for p in payloads[:2]} == {"a"}
        assert {p[0] for p in payloads[2:]} == {"b"}
        assert s.stats.locality_hits == 4 and s.stats.locality_misses == 0
        assert s.stats.locality_rate == 1.0

    def test_locality_spill_counts_misses(self):
        """One affinity overflowing its home pool spills to the other pool
        and the spilled rows count as misses."""
        s = self._sched(slots=2, pools=2)
        for i in range(4):
            s.submit("g", ("a", i), affinity="a")
        wave = s.take_wave("g")
        assert len(wave) == 4
        assert s.stats.locality_hits == 2 and s.stats.locality_misses == 2

    def test_partial_wave_queues_until_forced(self):
        s = self._sched(slots=2, pools=2)
        s.submit("g", "x", affinity="a")
        assert s.take_wave("g") is None
        assert len(s.take_wave("g", force=True)) == 1


class TestWholeHeadTPRule:
    """tp_shardable / lm_tp_pspec on shape-only meshes: attention
    projections shard only at whole-head granularity; MLP + embeddings
    shard whenever divisible; norms/biases replicate."""

    def _arch(self, name):
        return configs.reduced(configs.get_arch(name))

    def test_kv_replicates_when_heads_dont_divide(self):
        # qwen2 reduced: 4 q heads, 1 kv head.  tp=4 divides q, not kv.
        from repro.serve import mesh_exec as mx

        arch = self._arch("qwen2-1.5b")
        mesh = FakeMesh({"data": 2, "model": 4})
        d, hd = arch.d_model, arch.head_dim
        assert mx.lm_tp_pspec("wq", (d, arch.n_heads * hd), arch, mesh) == \
            P(None, "model")
        assert mx.lm_tp_pspec("wk", (d, arch.n_kv_heads * hd), arch, mesh) \
            == P()
        assert mx.lm_tp_pspec("wo", (arch.n_heads * hd, d), arch, mesh) == \
            P("model")                 # trailing None trimmed

    def test_kv_shards_at_whole_head_granularity(self):
        # gemma2 reduced: 2 kv heads.  tp=2 splits BETWEEN them -- exact.
        from repro.serve import mesh_exec as mx

        arch = self._arch("gemma2-2b")
        mesh = FakeMesh({"data": 4, "model": 2})
        d, hd = arch.d_model, arch.head_dim
        assert mx.tp_shardable("wk", arch, 2)
        assert mx.lm_tp_pspec("wk", (d, arch.n_kv_heads * hd), arch, mesh) \
            == P(None, "model")
        assert not mx.tp_shardable("wk", arch, 4)   # intra-head: refuse

    def test_mlp_and_vocab_shard_norms_replicate(self):
        from repro.serve import mesh_exec as mx

        arch = self._arch("qwen2-1.5b")
        mesh = FakeMesh({"data": 2, "model": 4})
        assert mx.lm_tp_pspec("wu", (arch.d_model, arch.d_ff), arch, mesh) \
            == P(None, "model")
        assert mx.lm_tp_pspec("wd", (arch.d_ff, arch.d_model), arch, mesh) \
            == P("model")
        assert mx.lm_tp_pspec("embed", (arch.vocab_size, arch.d_model),
                              arch, mesh) == P("model")
        assert mx.lm_tp_pspec("norm", (arch.d_model,), arch, mesh) == P()

    def test_tp1_replicates_everything(self):
        from repro.serve import mesh_exec as mx

        arch = self._arch("qwen2-1.5b")
        for name in ("wq", "wk", "wu", "embed"):
            assert not mx.tp_shardable(name, arch, 1)


class TestLatencyTracker:
    def test_percentiles_over_completions(self):
        from repro.serve.base import LatencyTracker

        lt = LatencyTracker()
        for t in range(4):
            lt.submitted(t)
        for t in range(3):
            lt.completed(t)
        p = lt.percentiles()
        assert p["n"] == 3 and p["p99_ms"] >= p["p50_ms"] >= 0.0

    def test_unknown_ticket_is_noop(self):
        from repro.serve.base import LatencyTracker

        lt = LatencyTracker()
        lt.completed(99)
        assert lt.percentiles()["n"] == 0


CNN_WAVE_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.models import cnn
from repro.models.params import init_params
from repro.serve.cnn_engine import CNNServeEngine
from repro.serve.mesh_exec import make_serve_mesh

mesh = make_serve_mesh(n_data=8)
rng = np.random.default_rng(0)
# zoo-wide: a mesh-attached engine (8 pools x 2 slots = 16-row sharded
# waves) must reproduce a plain 2-slot engine's bits on the same requests
for i, (name, base) in enumerate(sorted(CNN_ZOO.items())):
    cfg = dataclasses.replace(base, input_hw=32)
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(i))
    calib = jnp.asarray(rng.normal(
        size=(2, 32, 32, cfg.input_ch)).astype(np.float32))
    imgs = rng.normal(size=(16, 32, 32, cfg.input_ch)).astype(np.float32)
    plain = CNNServeEngine(eng_lib.paper_engine(), wave_size=2)
    plain.register(cfg, params, calib_batches=[calib])
    meshed = CNNServeEngine(eng_lib.paper_engine(), wave_size=2, mesh=mesh)
    meshed.register(cfg, params, calib_batches=[calib])
    assert meshed.wave_rows == 16 and plain.wave_rows == 2
    a = plain.infer(cfg.name, imgs)
    b = meshed.infer(cfg.name, imgs)
    assert np.array_equal(a, b), name
    st = meshed.stats()
    assert st["mesh"]["devices"] == 8, name
    print("WAVE_PARITY_OK", name)
"""


def test_cnn_sharded_wave_parity_zoo():
    """Property, zoo-wide on 8 forced host devices: a mesh-attached engine
    serving 16-row waves sharded over the data axis (2 rows per device)
    emits BIT-IDENTICAL logits to a single-device 2-slot engine on the
    same requests.  Matching per-device row counts is what makes the
    executables bit-compatible: the int8 GEMMs accumulate in int32
    (order-free) and XLA tiles the float epilogues identically when the
    local batch matches."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", CNN_WAVE_PARITY], env=env,
                         capture_output=True, text=True, timeout=600)
    from repro.configs.cnn_zoo import CNN_ZOO
    for name in CNN_ZOO:
        assert f"WAVE_PARITY_OK {name}" in out.stdout, \
            name + "\n" + out.stdout + out.stderr


LM_TP_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro import configs
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.mesh_exec import make_serve_mesh

w8 = EngineConfig(quant="w8a8", backend="ref")
rng = np.random.default_rng(0)
# (arch, data x model): qwen2 tp=4 keeps its 1 kv head replicated;
# gemma2 tp=2 shards its 2 kv heads whole -- both must stay exact
for name, (nd, nm) in (("qwen2-1.5b", (2, 4)), ("gemma2-2b", (4, 2))):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    calib = [jnp.array(rng.integers(0, arch.vocab_size,
                                    (2, 8)).astype(np.int32))]
    prompts = [rng.integers(0, arch.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]
    def serve(mesh):
        se = ServeEngine(arch, params, w8, batch_size=2, max_seq=32,
                         calib_batches=calib, prefill_len=8, mesh=mesh)
        return se.generate(prompts, max_new_tokens=6), se.stats()
    base, _ = serve(None)
    tp, st = serve(make_serve_mesh(n_data=nd, n_model=nm))
    for a, b in zip(base, tp):
        assert np.array_equal(a, b), (name, a, b)
    assert st["tp_placement"]["tp_sharded"] > 0, name
    print("LM_TP_OK", name, st["tp_placement"]["tp_sharded"])
"""


def test_lm_tp_decode_parity():
    """Property, on 8 forced host devices: tensor-parallel decode bursts
    (whole-head sharding over the model axis) emit bit-identical token
    ids to single-device serving, for a kv-replicated arch (qwen2, tp=4)
    and a kv-sharded arch (gemma2, tp=2)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", LM_TP_PARITY], env=env,
                         capture_output=True, text=True, timeout=600)
    for name in ("qwen2-1.5b", "gemma2-2b"):
        assert f"LM_TP_OK {name}" in out.stdout, out.stdout + out.stderr
