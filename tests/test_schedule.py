"""Scheduler pass properties: every schedule is a valid topological leveling
covering all ops exactly once, and scheduled execution is bit-identical to
sequential raw-order execution for both dynamic and static programs.

Property tests draw random CNNConfigs (stage kinds, widths, strides) through
the hypothesis shim, so the invariants hold structurally -- not just on the
zoo models."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro import compiler
from repro.compiler import schedule as sched_lib
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.core.config import CNNConfig, ConvSpec as C, EngineConfig
from repro.models import cnn
from repro.models.params import init_params

# fire first: it is the branchy kind (concat of two expand convs), so the
# shim's prefix-sampling lists() always exercises co-leveled ops
KINDS = ("fire", "conv", "pool", "bottleneck", "inverted", "dwsep")


def _stage(kind: str, out_ch: int, stride: int) -> C:
    if kind == "pool":
        return C("pool", kernel=2, stride=2)
    if kind == "inverted":
        return C(kind, out_ch=out_ch, kernel=3, stride=stride, repeat=1,
                 expand=2)
    return C(kind, out_ch=out_ch, kernel=3, stride=stride, repeat=1)


def _random_cfg(kinds, stem_ch: int, out_ch: int, stride: int) -> CNNConfig:
    stages = tuple(_stage(k, out_ch, stride) for k in kinds)
    name = f"prop_{'-'.join(kinds)}_{stem_ch}_{out_ch}_{stride}"
    # hw=32 keeps every feature map non-empty even for pool-heavy draws
    return CNNConfig(name=name, input_hw=32, input_ch=3, stem_kernel=3,
                     stem_stride=2, stem_ch=stem_ch, stages=stages,
                     num_classes=8)


def _setup(cfg: CNNConfig, batch: int = 1):
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(batch, cfg.input_hw, cfg.input_hw, cfg.input_ch)
    ).astype(np.float32) * 0.5)
    return params, x


# ---------------------------------------------------------------------------
# Structural properties of the leveling
# ---------------------------------------------------------------------------

def _assert_valid_leveling(g, s):
    # coverage: each node exactly once
    flat = list(s.order())
    assert sorted(flat) == [n.id for n in g.nodes]
    assert len(flat) == len(set(flat))
    # leveling: strict precedence of inputs
    level_of = {i: k for k, lv in enumerate(s.levels) for i in lv}
    for n in g.nodes:
        for i in n.inputs:
            assert level_of[i] < level_of[n.id], (n.id, i)
    # no empty levels, and the validator agrees
    assert all(len(lv) > 0 for lv in s.levels)
    compiler.validate_schedule(g, s)


def _random_arch(n_layers, post_norms, gated, tied):
    """A tiny attention ArchConfig for the mixed CNN/LM property draws."""
    from repro.core.config import ArchConfig
    return ArchConfig(
        name=f"prop_lm_{n_layers}_{int(post_norms)}_{int(gated)}_{int(tied)}",
        family="dense", n_layers=n_layers, d_model=32, n_heads=2,
        n_kv_heads=1, d_ff=64, vocab_size=64, head_dim=16,
        block_pattern=("global", "local"), local_window=8,
        post_norms=post_norms, mlp_gated=gated, tie_embeddings=tied)


class TestLevelingProperties:
    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=4),
           stem_ch=st.sampled_from([4, 8]),
           out_ch=st.sampled_from([8, 16]),
           stride=st.sampled_from([1, 2]))
    def test_schedule_is_valid_topological_leveling(self, kinds, stem_ch,
                                                    out_ch, stride):
        """Every op's inputs land in strictly earlier levels, and the levels
        cover every node exactly once -- for both leveling policies."""
        g = compiler.build_graph(_random_cfg(kinds, stem_ch, out_ch, stride))
        for policy in ("asap", "alap"):
            _assert_valid_leveling(g, compiler.level_schedule(g, policy))

    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=3),
           n_layers=st.sampled_from([1, 2]),
           post_norms=st.sampled_from([False, True]),
           gated=st.sampled_from([False, True]),
           tied=st.sampled_from([False, True]))
    def test_mixed_cnn_lm_graphs_level(self, kinds, n_layers, post_norms,
                                       gated, tied):
        """Mixed fleets: CNN and LM graphs drawn together both produce valid
        topological levelings under both policies, and every node maps to an
        engine unit."""
        graphs = [
            compiler.build_graph(_random_cfg(kinds, 4, 8, 1)),
            compiler.lower_transformer(
                _random_arch(n_layers, post_norms, gated, tied)),
        ]
        for g in graphs:
            for n in g.nodes:
                assert compiler.engine_unit(n) in (
                    sched_lib.CONV_PE, sched_lib.DWC_PE, sched_lib.MISC,
                    sched_lib.LOW_CHANNEL, sched_lib.MEM)
            for policy in ("asap", "alap"):
                s = compiler.level_schedule(g, policy)
                _assert_valid_leveling(g, s)
                occ = compiler.engine_occupancy(g, s)
                assert 0 < occ["occupancy"] <= 1

    def test_alap_within_critical_path(self):
        """ALAP keeps the critical-path level count and only slides slack
        ops later (every node's ALAP level >= its ASAP level)."""
        for name in ("squeezenet", "resnet50"):
            g = compiler.build_graph(CNN_ZOO[name])
            a = compiler.level_schedule(g, "asap")
            z = compiler.level_schedule(g, "alap")
            assert z.n_levels == a.n_levels
            asap_of = {i: k for k, lv in enumerate(a.levels) for i in lv}
            alap_of = {i: k for k, lv in enumerate(z.levels) for i in lv}
            assert all(alap_of[i] >= asap_of[i] for i in asap_of)
            if name == "resnet50":            # bottleneck skip convs slide
                assert any(alap_of[i] > asap_of[i] for i in asap_of)

    def test_unknown_policy_rejected(self):
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        with pytest.raises(ValueError, match="policy"):
            compiler.level_schedule(g, "greedy")

    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=4),
           stem_ch=st.sampled_from([4, 8]),
           out_ch=st.sampled_from([8, 16]),
           stride=st.sampled_from([1, 2]))
    def test_stats_consistent(self, kinds, stem_ch, out_ch, stride):
        g = compiler.build_graph(_random_cfg(kinds, stem_ch, out_ch, stride))
        s = compiler.level_schedule(g)
        assert s.stats["ops"] == len(g.nodes)
        assert s.stats["levels"] == s.n_levels
        assert s.stats["max_width"] == max(len(lv) for lv in s.levels)
        assert s.stats["wide_levels"] == sum(len(lv) > 1 for lv in s.levels)

    def test_every_zoo_graph_schedules(self):
        for name, cfg in CNN_ZOO.items():
            g = compiler.build_graph(cfg)
            s = compiler.level_schedule(g)
            compiler.validate_schedule(g, s)
            # a chain can never be shorter than its longest path; equality
            # holds exactly when the graph is a pure chain
            assert s.n_levels <= len(g.nodes)

    def test_branches_co_leveled(self):
        """The concurrency the pass exists to expose: a fire module's two
        expand convs land in the same dispatch level."""
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        s = compiler.level_schedule(g)
        assert s.stats["max_width"] >= 2
        assert s.stats["wide_levels"] >= 8           # 8 fire modules
        for n in g.nodes:
            if isinstance(n, compiler.ConcatOp):
                lv = {k for k, level in enumerate(s.levels)
                      for i in level if i in n.inputs}
                assert len(lv) == 1                  # e1 and e3 together

    def test_validator_rejects_bad_schedules(self):
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        s = compiler.level_schedule(g)
        # drop a node
        broken = sched_lib.Schedule(tuple(s.levels[:-1]))
        with pytest.raises(ValueError, match="coverage"):
            compiler.validate_schedule(g, broken)
        # duplicate a node
        dup = sched_lib.Schedule(s.levels + (s.levels[0],))
        with pytest.raises(ValueError, match="twice"):
            compiler.validate_schedule(g, dup)
        # co-level a dependent pair
        merged = sched_lib.Schedule(
            (s.levels[0] + s.levels[1],) + s.levels[2:])
        with pytest.raises(ValueError, match="leveling"):
            compiler.validate_schedule(g, merged)

    def test_engine_unit_mapping(self):
        g = compiler.build_graph(CNN_ZOO["mobilenetv2"])
        units = {compiler.engine_unit(n) for n in g.nodes}
        assert sched_lib.LOW_CHANNEL in units        # stem
        assert sched_lib.DWC_PE in units             # depthwise stages
        assert sched_lib.CONV_PE in units
        assert sched_lib.MISC in units               # residual adds / pools


# ---------------------------------------------------------------------------
# Execution parity: scheduled dispatch == sequential raw order, bitwise
# ---------------------------------------------------------------------------

def _strip_schedule(program):
    return dataclasses.replace(program, schedule=None)


class TestScheduledExecutionParity:
    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=3),
           out_ch=st.sampled_from([8, 16]))
    def test_dynamic_bit_identical(self, kinds, out_ch):
        cfg = _random_cfg(kinds, 4, out_ch, 1)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="none", backend="ref")
        scheduled = compiler.compile_cnn(cfg, scheduled=True)
        sequential = compiler.compile_cnn(cfg, scheduled=False)
        assert scheduled.schedule is not None and sequential.schedule is None
        a = np.array(compiler.execute(scheduled, params, x, eng))
        b = np.array(compiler.execute(sequential, params, x, eng))
        np.testing.assert_array_equal(a, b)

    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=3),
           out_ch=st.sampled_from([8, 16]))
    def test_static_bit_identical(self, kinds, out_ch):
        cfg = _random_cfg(kinds, 4, out_ch, 1)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        assert prog.static and prog.schedule is not None
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(_strip_schedule(prog), qparams, x, eng))
        np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("name", ["squeezenet", "resnet50"])
    def test_zoo_static_bit_identical(self, name):
        """Branchy zoo models (real co-leveled ops): scheduled static-int8
        execution is bit-identical to sequential, jitted and eager."""
        cfg = dataclasses.replace(CNN_ZOO[name], input_hw=32)
        params, x = _setup(cfg, batch=2)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x])
        assert prog.schedule.stats["wide_levels"] > 0
        seq = _strip_schedule(prog)
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(seq, qparams, x, eng))
        np.testing.assert_array_equal(a, b)
        ja = np.array(jax.jit(
            lambda p, im: compiler.execute(prog, p, im, eng))(qparams, x))
        jb = np.array(jax.jit(
            lambda p, im: compiler.execute(seq, p, im, eng))(qparams, x))
        np.testing.assert_array_equal(ja, jb)

    def test_alap_bit_identical_to_sequential(self):
        """The ALAP leveling dispatches the same ops with the same inputs:
        static w8a8 execution matches sequential bitwise (resnet50 has real
        slack, so ALAP genuinely reorders waves)."""
        cfg = dataclasses.replace(CNN_ZOO["resnet50"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x], policy="alap")
        assert prog.schedule is not None
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(_strip_schedule(prog), qparams, x, eng))
        np.testing.assert_array_equal(a, b)

    def test_lm_scheduled_bit_identical(self):
        """LM programs through the same parity harness: scheduled dispatch
        (both policies) equals sequential execution bitwise."""
        from repro import configs
        from repro.models import transformer as T
        from repro.models.params import init_params

        arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, arch.vocab_size, (2, 10)).astype(np.int32))
        eng = EngineConfig(quant="none", backend="ref")
        for policy in ("asap", "alap"):
            prog = compiler.compile_lm(arch, policy=policy)
            seq = compiler.compile_lm(arch, scheduled=False)
            a = np.array(compiler.execute(prog, params, toks, eng))
            b = np.array(compiler.execute(seq, params, toks, eng))
            np.testing.assert_array_equal(a, b)

    def test_cost_scheduled_bit_identical(self):
        """The cost leveling (node_times from compiler.cost) dispatches the
        same ops with the same inputs: static w8a8 execution matches
        sequential bitwise."""
        from repro.compiler import cost as cost_lib

        cfg = dataclasses.replace(CNN_ZOO["resnet50"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x], policy="cost")
        assert prog.schedule is not None
        assert "modeled_makespan" in prog.schedule.stats
        times = cost_lib.cnn_node_times(prog.graph, cfg)
        assert prog.schedule.stats["modeled_makespan"] == pytest.approx(
            compiler.modeled_makespan(prog.graph, prog.schedule, times))
        a = np.array(compiler.execute(prog, qparams, x, eng))
        b = np.array(compiler.execute(_strip_schedule(prog), qparams, x, eng))
        np.testing.assert_array_equal(a, b)

    def test_calibration_identical_under_scheduling(self):
        """The observer hook sees the same tensors whichever dispatch order
        runs: scales recorded through a scheduled program match the
        calibrate() pass (which walks sequentially) exactly."""
        from repro.core.quant import Calibrator

        cfg = dataclasses.replace(CNN_ZOO["squeezenet"], input_hw=32)
        params, x = _setup(cfg)
        eng = EngineConfig(quant="none", backend="ref")
        g = compiler.build_graph(cfg)
        sequential = compiler.calibrate(g, params, [x], cfg)
        cal = Calibrator()
        # fuse=False: calibration observes the UNFUSED graph's edges (that
        # is also what compile_calibrated calibrates before fusing)
        compiler.execute(compiler.compile_cnn(cfg, scheduled=True,
                                              fuse=False), params,
                         x, eng, observer=lambda n, v: cal.observe(str(n.id), v))
        scheduled = {int(k): float(v) for k, v in cal.scales().items()}
        assert scheduled == sequential


# ---------------------------------------------------------------------------
# Cost-driven leveling: modeled makespan objective + time-weighted occupancy
# ---------------------------------------------------------------------------

def _rand_times(g, seed=0):
    rng = np.random.default_rng(seed)
    return {n.id: float(rng.uniform(1e-7, 1e-4)) for n in g.nodes}


class TestCostPolicy:
    @settings(deadline=None)
    @given(kinds=st.lists(st.sampled_from(KINDS), min_size=1, max_size=4),
           out_ch=st.sampled_from([8, 16]),
           seed=st.sampled_from([0, 1, 2]))
    def test_cost_never_worse_than_asap(self, kinds, out_ch, seed):
        """The guarantee the policy advertises: on random graphs with random
        per-node times, the cost leveling stays a valid topological leveling
        and its modeled makespan never exceeds ASAP's; the carried
        `modeled_makespan` stat equals the public objective function."""
        g = compiler.build_graph(_random_cfg(kinds, 4, out_ch, 1))
        times = _rand_times(g, seed)
        a = compiler.level_schedule(g, "asap", node_times=times)
        c = compiler.level_schedule(g, "cost", node_times=times)
        _assert_valid_leveling(g, c)
        assert (c.stats["modeled_makespan"]
                <= a.stats["modeled_makespan"] + 1e-12)
        for s in (a, c):
            assert s.stats["modeled_makespan"] == pytest.approx(
                compiler.modeled_makespan(g, s, times))

    def test_cost_strictly_beats_asap_on_contended_graph(self):
        """The case the objective exists for: ASAP co-levels two convs on
        the one Conv PE (they time-share it) while the DWC unit idles; cost
        slides the slack conv into the DWC level so the units overlap."""
        from repro.compiler.graph import (AddOp, ConvOp, DwcOp, Graph,
                                          InputOp)

        g = Graph(nodes=(
            InputOp(0, ()),
            ConvOp(1, (0,), w=("w1",)),
            DwcOp(2, (1,), w=("wd",)),
            ConvOp(3, (0,), w=("w2",)),       # slack: needed only by the add
            AddOp(4, (2, 3)),
        ), output=4, name="contended")
        times = {0: 0.0, 1: 3e-6, 2: 2e-6, 3: 1e-6, 4: 1e-7}
        a = compiler.level_schedule(g, "asap", node_times=times)
        c = compiler.level_schedule(g, "cost", node_times=times)
        _assert_valid_leveling(g, c)
        # asap: {1,3} share CONV_PE (4us level), then {2} (2us)
        # cost: {1} (3us), then {2,3} overlap DWC/CONV (2us)
        assert a.stats["modeled_makespan"] == pytest.approx(6.1e-6)
        assert c.stats["modeled_makespan"] == pytest.approx(5.1e-6)
        assert c.stats["modeled_makespan"] < a.stats["modeled_makespan"]
        # and the time-weighted occupancy rises accordingly
        occ_a = compiler.time_weighted_occupancy(g, a, times)["occupancy"]
        occ_c = compiler.time_weighted_occupancy(g, c, times)["occupancy"]
        assert occ_c > occ_a

    def test_zero_time_nodes(self):
        """All-zero node times: every policy's makespan is 0, the cost
        leveling is still valid, and time-weighted occupancy degrades to
        0.0 instead of dividing by zero."""
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        times = {n.id: 0.0 for n in g.nodes}
        for policy in ("asap", "slack", "cost"):
            s = compiler.level_schedule(g, policy, node_times=times)
            _assert_valid_leveling(g, s)
            assert s.stats["modeled_makespan"] == 0.0
            tw = compiler.time_weighted_occupancy(g, s, times)
            assert tw["occupancy"] == 0.0
            assert tw["span_s"] == 0.0

    def test_missing_times_treated_as_zero(self):
        """node_times is a partial map: absent ids cost 0 seconds (the MEM
        input op never appears in the cost tables)."""
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        full = _rand_times(g)
        partial = {i: t for i, t in full.items() if i % 2 == 0}
        s = compiler.level_schedule(g, "cost", node_times=partial)
        _assert_valid_leveling(g, s)
        want = compiler.modeled_makespan(
            g, s, {i: partial.get(i, 0.0) for i in full})
        assert s.stats["modeled_makespan"] == pytest.approx(want)

    def test_single_node_graph(self):
        """Degenerate single-level graph: one input op, no compute units --
        makespan 0, occupancy 0, still a valid (single-level) schedule."""
        from repro.compiler.graph import Graph, InputOp

        g = Graph(nodes=(InputOp(0, ()),), output=0, name="lone")
        s = compiler.level_schedule(g, "cost", node_times={0: 0.0})
        assert s.levels == ((0,),)
        assert s.stats["modeled_makespan"] == 0.0
        assert compiler.time_weighted_occupancy(
            g, s, {0: 0.0})["occupancy"] == 0.0

    def test_empty_levels_makespan(self):
        """modeled_makespan on an empty leveling is 0.0 (the merged-stream
        accounting hits this for a program that has run dry)."""
        g = compiler.build_graph(CNN_ZOO["squeezenet"])
        assert compiler.modeled_makespan(g, (), {}) == 0.0

    def test_slack_without_times_stays_count_based(self):
        """Backwards compatibility: policy="slack" WITHOUT node_times keeps
        the count-based contention cap (no makespan stat requirement) and
        identical levels to a fresh count-based run."""
        g = compiler.build_graph(CNN_ZOO["resnet50"])
        s1 = compiler.level_schedule(g, "slack")
        s2 = compiler.level_schedule(g, "slack")
        assert s1.levels == s2.levels
        _assert_valid_leveling(g, s1)

    def test_cost_makespan_beats_or_ties_asap_zoo_wide(self):
        """Across the whole zoo with the real cost model: cost's modeled
        makespan <= ASAP's on every model (the never-worse guarantee on
        the graphs that matter, not just random draws)."""
        from repro.compiler import cost as cost_lib

        for name, cfg in CNN_ZOO.items():
            g = compiler.compile_cnn(cfg).graph
            times = cost_lib.cnn_node_times(g, cfg)
            a = compiler.level_schedule(g, "asap", node_times=times)
            c = compiler.level_schedule(g, "cost", node_times=times)
            _assert_valid_leveling(g, c)
            assert (c.stats["modeled_makespan"]
                    <= a.stats["modeled_makespan"] + 1e-12), name
