"""Paper Table I / Eq. 3-4 reproduction + TPU tile-solver invariants."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import dse


class TestTable1:
    def test_paper_rows(self):
        """Table I: reuse requirements under different bandwidths."""
        rows = {(r.bw_f, r.bw_w): r for r in dse.table1()}
        # FM (1x16), WT (16x8) rows of Table I.
        assert rows[(16, 16)].fm_reuse == 8
        assert rows[(16, 16)].wt_reuse == 64
        assert rows[(16, 16)].oc == 64
        assert rows[(16, 16)].ihw == 64
        assert rows[(16, 32)].fm_reuse == 8
        assert rows[(16, 32)].wt_reuse == 32
        assert rows[(32, 16)].fm_reuse == 4
        assert rows[(32, 16)].wt_reuse == 64
        assert rows[(32, 16)].oc == 32
        assert rows[(32, 16)].ihw == 64
        assert rows[(32, 32)].fm_reuse == 4
        assert rows[(32, 32)].wt_reuse == 32

    def test_dpuv4e_choice_is_ctc1(self):
        """The selected design point reaches CTC >= 1 (compute-bound)."""
        r = dse.dpuv4e_choice()
        assert r.bw_f == 32 and r.bw_w == 16
        assert r.ctc >= 1.0
        assert r.oc == 32 and r.ihw == 64      # Section IV-A conclusion

    @given(bw_f=st.sampled_from([8, 16, 32, 64, 128]),
           bw_w=st.sampled_from([8, 16, 32, 64, 128]))
    def test_reuse_always_reaches_ctc1(self, bw_f, bw_w):
        """Property: the solver's minimum reuse always achieves CTC >= 1."""
        r = dse.solve_reuse(bw_f, bw_w)
        assert r.ctc >= 1.0 - 1e-9
        # And it is minimal: one less FMReuse violates the FM constraint.
        if r.fm_reuse > 1:
            fm_load = r.wt_reuse * dse.FM_BITS / bw_f
            t_smaller = (r.fm_reuse - 1) * r.wt_reuse
            wt_load = (r.fm_reuse - 1) * dse.WT_BITS / bw_w
            assert fm_load > t_smaller or wt_load > t_smaller or \
                math.ceil(dse.FM_BITS / bw_f) == r.fm_reuse


class TestAccBuffers:
    def test_eq3_buffer_plan(self):
        """Paper Eq. 3: IH=4, IW=16, OC=32 fits the 64 KB ACC/NL pair."""
        plan = dse.acc_buffer_plan(ih=4, iw=16, oc=32)
        assert plan.psum_bytes == 4 * 16 * 32 * 4
        assert plan.fits

    def test_eq4_iw_max(self):
        """Paper Eq. 4: IW_max <= 32 at IH=4."""
        assert dse.max_iw(ih=4, oc=32) == 32

    def test_paper_selection_satisfies_reuse(self):
        """IH=4 (x2 multicast -> 8) x IW=16 >= the required IH*IW=64."""
        assert 8 * 16 >= dse.dpuv4e_choice().ihw


class TestTpuTiles:
    def test_blocks_are_mxu_aligned(self):
        t = dse.solve_conv_blocks(4096, 4096, 4096)
        assert t.bm % 128 == 0 and t.bn % 128 == 0 and t.bk % 128 == 0

    def test_vmem_constraint(self):
        t = dse.solve_conv_blocks(8192, 8192, 8192)
        assert t.vmem_bytes <= dse.VMEM_TARGET

    @settings(deadline=None, max_examples=25)
    @given(m=st.integers(128, 8192), n=st.integers(128, 8192),
           k=st.integers(128, 8192),
           ib=st.sampled_from([1, 2]))
    def test_solver_invariants(self, m, n, k, ib):
        """Property: any solver output fits VMEM and is MXU-aligned."""
        t = dse.solve_conv_blocks(m, n, k, in_dtype_bytes=ib)
        assert t.vmem_bytes <= dse.VMEM_TARGET
        assert t.bm % 128 == 0 and t.bn % 128 == 0 and t.bk % 128 == 0
        assert t.fm_reuse == t.bn and t.wt_reuse == t.bm

    def test_int8_fits_larger_blocks(self):
        """int8 operands are half the bytes -> larger blocks fit VMEM ->
        CTC at least as good as bf16 (the paper's INT8 datapath argument
        mapped to TPU constants)."""
        t8 = dse.solve_conv_blocks(4096, 4096, 4096, in_dtype_bytes=1)
        t16 = dse.solve_conv_blocks(4096, 4096, 4096, in_dtype_bytes=2)
        assert t8.ctc >= t16.ctc * 0.99
        assert t8.vmem_bytes <= dse.VMEM_TARGET


class TestDwcModel:
    def test_fig8_k3s1_atomic_cycles(self):
        """Paper Fig. 7: one atomic DWC (k=3, s=1) takes 12 MAC cycles."""
        p = dse.dwc_ctc(3, 1)
        assert p.mac_cycles == 12 * 8          # 8 atomics per iteration

    def test_fig8_trends(self):
        """Paper Fig. 8: larger kernel -> higher CTC; larger stride -> lower;
        7x7 stride-1 is the most efficient configuration."""
        pts = {(p.kernel, p.stride): p.ctc for p in dse.fig8_sweep()}
        assert pts[(5, 1)] > pts[(3, 1)]
        assert pts[(7, 1)] > pts[(5, 1)]
        for k in (3, 5, 7):
            assert pts[(k, 2)] < pts[(k, 1)]
        assert max(pts, key=pts.get) == (7, 1)

    def test_stride1_fm_bound(self):
        """Paper: at stride 1 the FM input is the bottleneck (CTC < 1)."""
        assert dse.dwc_ctc(3, 1).ctc < 1.0


class TestLowChannel:
    def test_resnet_stage0_utilization_low(self):
        """Paper Section V-B reports 13.1% Conv PE utilization on ResNet50
        stage 0.  Our model (without their exact pixel schedule) bounds it:
        well under half the array is useful, and far under hidden-layer
        utilization."""
        u = dse.conv_pe_utilization(ic=3, oc=64)
        hidden = dse.conv_pe_utilization(ic=256, oc=256) * (49 / 1)
        assert u < 0.4
        u_naive = (3 / 64) * (64 / 128)        # no window folding: 2.3%
        assert u_naive < 0.131 < u             # paper's 13.1% sits between

    def test_mxu_analogue_low(self):
        """TPU analogue: IC=3 conv wastes the MXU without window folding."""
        plain = dse.mxu_utilization(ic=3, oc=64, kk=1)
        folded = dse.mxu_utilization(ic=3, oc=64, kk=49)
        assert plain < 0.02
        assert folded > plain * 20
