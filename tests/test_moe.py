"""MoE: sort-based capacity dispatch vs a dense per-token loop."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.core.config import EngineConfig
from repro.models import layers as L
from repro.models.params import init_params

ENG = EngineConfig(quant="none", backend="ref")


def dense_moe_oracle(p, x, arch):
    """Per-token loop: route to top-k, run experts densely, combine."""
    b, l, d = x.shape
    xt = np.array(x.reshape(b * l, d), np.float64)
    router = np.array(p["router"], np.float64)
    wg = np.array(p["wg"], np.float64)
    wu = np.array(p["wu"], np.float64)
    wd = np.array(p["wd"], np.float64)
    logits = xt @ router
    gates = np.exp(logits - logits.max(-1, keepdims=True))
    gates = gates / gates.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        idx = np.argsort(-gates[t])[:arch.topk]
        w = gates[t, idx]
        w = w / w.sum()
        for e, wt in zip(idx, w):
            g = xt[t] @ wg[e]
            g = g / (1 + np.exp(-g)) if arch.mlp_act == "silu" else \
                0.5 * g * (1 + np.tanh(np.sqrt(2 / np.pi) * (g + 0.044715 * g ** 3)))
            h = (g * (xt[t] @ wu[e])) @ wd[e]
            out[t] += wt * h
    return out.reshape(b, l, d)


class TestMoE:
    @pytest.mark.parametrize("name", ["grok-1-314b", "granite-moe-1b-a400m"])
    def test_matches_dense_oracle(self, rng, name):
        arch = reduced(ARCHS[name])
        p = init_params(L.moe_schema(arch), jax.random.PRNGKey(0))
        x = jnp.array(rng.normal(size=(2, 8, arch.d_model)).astype(np.float32))
        got, aux = L.moe_apply(p, x, arch, ENG)
        want = dense_moe_oracle(p, x, arch)
        np.testing.assert_allclose(np.array(got), want, rtol=2e-3, atol=2e-3)
        assert float(aux) > 0

    def test_capacity_drops_tokens(self, rng):
        """At capacity_factor << 1 some tokens must be dropped (output
        contribution zero), never corrupted."""
        import dataclasses
        arch = dataclasses.replace(reduced(ARCHS["grok-1-314b"]),
                                   capacity_factor=0.25)
        p = init_params(L.moe_schema(arch), jax.random.PRNGKey(0))
        x = jnp.array(rng.normal(size=(2, 16, arch.d_model)).astype(np.float32))
        got, _ = L.moe_apply(p, x, arch, ENG)
        assert np.isfinite(np.array(got)).all()
        full = dataclasses.replace(arch, capacity_factor=8.0)
        got_full, _ = L.moe_apply(p, x, full, ENG)
        # dropping changes results; both remain finite and bounded
        assert np.abs(np.array(got)).max() <= \
            np.abs(np.array(got_full)).max() * 4 + 1.0

    def test_aux_loss_uniform_routing_is_one(self, rng):
        """Switch aux loss == 1 under perfectly uniform routing."""
        import dataclasses
        arch = reduced(ARCHS["grok-1-314b"])
        p = init_params(L.moe_schema(arch), jax.random.PRNGKey(0))
        # zero router -> uniform gates
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"])
        x = jnp.array(rng.normal(size=(2, 32, arch.d_model)).astype(np.float32))
        _, aux = L.moe_apply(p, x, arch, ENG)
        assert abs(float(aux) - 1.0) < 0.2

    def test_permutation_equivariance(self, rng):
        """Shuffling tokens shuffles outputs identically (dispatch has no
        cross-token leakage) -- requires lossless capacity."""
        arch = reduced(ARCHS["granite-moe-1b-a400m"])
        p = init_params(L.moe_schema(arch), jax.random.PRNGKey(1))
        x = rng.normal(size=(1, 8, arch.d_model)).astype(np.float32)
        perm = rng.permutation(8)
        y1, _ = L.moe_apply(p, jnp.array(x), arch, ENG)
        y2, _ = L.moe_apply(p, jnp.array(x[:, perm]), arch, ENG)
        np.testing.assert_allclose(np.array(y1)[:, perm], np.array(y2),
                                   rtol=2e-4, atol=2e-4)
