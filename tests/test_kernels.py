"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle sweeps."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.config import EngineConfig
from repro.core.quant import QTensor, quantize
from repro.kernels import conv_pe, dwc_pe, low_channel, misc_pe, ops, ref

PALLAS = EngineConfig(quant="w8a8", backend="pallas", interpret=True)
REF = EngineConfig(quant="w8a8", backend="ref")
FLOAT_PALLAS = EngineConfig(quant="none", backend="pallas", interpret=True)


def _rand_q(rng, shape):
    return rng.integers(-127, 128, shape).astype(np.int8)


# ---------------------------------------------------------------------------
# Conv PE
# ---------------------------------------------------------------------------

class TestConvPE:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (128, 256, 128),
                                       (256, 512, 128), (128, 384, 256)])
    @pytest.mark.parametrize("act", ["none", "relu", "silu"])
    def test_int8_fused_matches_ref(self, rng, m, k, n, act):
        aq, bq = _rand_q(rng, (m, k)), _rand_q(rng, (k, n))
        asc = rng.uniform(0.01, 0.1, (m, 1)).astype(np.float32)
        wsc = rng.uniform(0.01, 0.1, (1, n)).astype(np.float32)
        bias = rng.normal(size=n).astype(np.float32)
        got = conv_pe.matmul_int8_fused(
            jnp.array(aq), jnp.array(bq), jnp.array(asc), jnp.array(wsc),
            jnp.array(bias), act, bm=128, bn=128, bk=128, interpret=True)
        want = ref.matmul_int8_fused(
            jnp.array(aq), jnp.array(bq), jnp.array(asc), jnp.array(wsc),
            jnp.array(bias), act)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-5)

    def test_int8_accumulation_exact(self, rng):
        """The cascade accumulator must be exact int32 (no fp drift)."""
        m = k = n = 256
        aq, bq = _rand_q(rng, (m, k)), _rand_q(rng, (k, n))
        one_m = jnp.ones((m, 1), jnp.float32)
        one_n = jnp.ones((1, n), jnp.float32)
        got = conv_pe.matmul_int8_fused(
            jnp.array(aq), jnp.array(bq), one_m, one_n, None, "none",
            bm=128, bn=128, bk=128, interpret=True)
        want = aq.astype(np.int64) @ bq.astype(np.int64)
        np.testing.assert_array_equal(np.array(got).astype(np.int64), want)

    def test_int8_requantized_output(self, rng):
        m = k = n = 128
        aq, bq = _rand_q(rng, (m, k)), _rand_q(rng, (k, n))
        asc = np.full((m, 1), 0.02, np.float32)
        wsc = np.full((1, n), 0.03, np.float32)
        got = conv_pe.matmul_int8_fused(
            jnp.array(aq), jnp.array(bq), jnp.array(asc), jnp.array(wsc),
            None, "none", out_scale=0.5, bm=128, bn=128, bk=128,
            interpret=True)
        assert got.dtype == jnp.int8
        want = ref.matmul_int8_fused(
            jnp.array(aq), jnp.array(bq), jnp.array(asc), jnp.array(wsc),
            None, "none", out_scale=jnp.float32(0.5))
        np.testing.assert_array_equal(np.array(got), np.array(want))

    def test_bf16_variant(self, rng):
        m = k = n = 128
        a = rng.normal(size=(m, k)).astype(np.float32)
        b = rng.normal(size=(k, n)).astype(np.float32)
        got = conv_pe.matmul_f_fused(jnp.array(a), jnp.array(b), None, "gelu",
                                     bm=128, bn=128, bk=128, interpret=True)
        want = ref.act_fn("gelu")(a @ b)
        np.testing.assert_allclose(np.array(got), want, rtol=2e-3, atol=2e-3)

    def test_unfused_baseline_same_math(self, rng):
        """XVDPU-analog baseline differs in fusion, not in numerics."""
        x = rng.normal(size=(3, 40)).astype(np.float32)
        w = rng.normal(size=(40, 24)).astype(np.float32)
        wq = quantize(jnp.array(w), axis=1)
        ours = ops.linear(jnp.array(x), wq, None, "relu", REF)
        base = ops.linear(jnp.array(x), wq, None, "relu",
                          EngineConfig(quant="w8a8", baseline=True).resolved())
        np.testing.assert_allclose(np.array(ours), np.array(base),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# DWC PE
# ---------------------------------------------------------------------------

class TestDwcPE:
    @pytest.mark.parametrize("k", [3, 5, 7])
    @pytest.mark.parametrize("stride", [1, 2])
    def test_quantized_sweep(self, rng, k, stride):
        x = rng.normal(size=(2, 17, 17, 64)).astype(np.float32)
        w = (rng.normal(size=(k, k, 64)) * 0.2).astype(np.float32)
        b = rng.normal(size=64).astype(np.float32)
        q = quantize(jnp.array(w.reshape(-1, 64)), axis=1)
        wq = QTensor(q.q.reshape(k, k, 64), q.scale)
        got = ops.dwc2d(jnp.array(x), wq, jnp.array(b), stride, "SAME",
                        "relu6", PALLAS)
        want = ops.dwc2d(jnp.array(x), wq, jnp.array(b), stride, "SAME",
                         "relu6", REF)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("c", [32, 128, 192])
    def test_channel_padding(self, rng, c):
        """Lane alignment (the paper's zero-padded weights) is lossless."""
        x = rng.normal(size=(1, 9, 9, c)).astype(np.float32)
        w = (rng.normal(size=(3, 3, c)) * 0.2).astype(np.float32)
        got = ops.dwc2d(jnp.array(x), jnp.array(w), None, 1, "SAME",
                        "none", FLOAT_PALLAS)
        want = ref.dwc2d(jnp.pad(jnp.array(x), ((0, 0), (1, 1), (1, 1), (0, 0))),
                         jnp.array(w), None, 1)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)

    def test_dwc_against_lax_conv(self, rng):
        import jax.lax as lax
        x = rng.normal(size=(2, 12, 12, 128)).astype(np.float32)
        w = (rng.normal(size=(3, 3, 128)) * 0.2).astype(np.float32)
        got = ops.dwc2d(jnp.array(x), jnp.array(w), None, 1, "SAME", "none",
                        FLOAT_PALLAS)
        want = lax.conv_general_dilated(
            jnp.array(x), jnp.array(w).reshape(3, 3, 1, 128), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=128)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("k", [2, 4])
    def test_dwc1d_causal(self, rng, k):
        x = rng.normal(size=(2, 24, 96)).astype(np.float32)
        w = rng.normal(size=(k, 96)).astype(np.float32)
        b = rng.normal(size=96).astype(np.float32)
        got = ops.dwc1d_causal(jnp.array(x), jnp.array(w), jnp.array(b),
                               "silu", FLOAT_PALLAS)
        want = ref.dwc1d_causal(jnp.array(x), jnp.array(w), jnp.array(b),
                                "silu")
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)

    def test_causality(self, rng):
        """Future timesteps must not affect past outputs."""
        x1 = rng.normal(size=(1, 16, 128)).astype(np.float32)
        x2 = x1.copy()
        x2[:, 10:] += 5.0
        w = rng.normal(size=(4, 128)).astype(np.float32)
        y1 = np.array(ops.dwc1d_causal(jnp.array(x1), jnp.array(w), None,
                                       "none", FLOAT_PALLAS))
        y2 = np.array(ops.dwc1d_causal(jnp.array(x2), jnp.array(w), None,
                                       "none", FLOAT_PALLAS))
        np.testing.assert_allclose(y1[:, :10], y2[:, :10], rtol=1e-6)

    def test_baseline_diagonal_lowering(self, rng):
        """Without the DWC engine, depthwise runs as diagonalized dense conv
        (the paper's low-utilization path) -- same result."""
        x = rng.normal(size=(1, 8, 8, 16)).astype(np.float32)
        w = (rng.normal(size=(3, 3, 16)) * 0.2).astype(np.float32)
        nodwc = EngineConfig(quant="none", backend="ref", use_dwc_engine=False)
        got = ops.dwc2d(jnp.array(x), jnp.array(w), None, 1, "SAME", "none",
                        nodwc, out_dtype=jnp.float32)
        want = ops.dwc2d(jnp.array(x), jnp.array(w), None, 1, "SAME", "none",
                         EngineConfig(quant="none", backend="ref"))
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Low-Channel Conv Unit
# ---------------------------------------------------------------------------

class TestLowChannel:
    @pytest.mark.parametrize("k,stride,ic,oc", [
        (7, 2, 3, 64), (3, 2, 3, 32), (6, 2, 3, 16), (5, 1, 4, 32)])
    def test_sweep(self, rng, k, stride, ic, oc):
        x = rng.normal(size=(2, 20, 20, ic)).astype(np.float32)
        w = (rng.normal(size=(k, k, ic, oc)) * 0.1).astype(np.float32)
        b = rng.normal(size=oc).astype(np.float32)
        got = low_channel.low_channel_conv(
            jnp.array(x), jnp.array(w), jnp.array(b), stride, "relu",
            interpret=True)
        want = ref.low_channel_conv(jnp.array(x), jnp.array(w), jnp.array(b),
                                    stride, "relu")
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)

    def test_matches_general_conv_pe(self, rng):
        """The specialized unit computes the same conv as the general path."""
        x = rng.normal(size=(1, 16, 16, 3)).astype(np.float32)
        w = (rng.normal(size=(7, 7, 3, 32)) * 0.1).astype(np.float32)
        b = rng.normal(size=32).astype(np.float32)
        eng_on = EngineConfig(quant="none", backend="ref")
        eng_off = EngineConfig(quant="none", backend="ref",
                               use_low_channel_unit=False)
        got = ops.first_layer_conv(jnp.array(x), jnp.array(w), jnp.array(b),
                                   2, "SAME", "relu", eng_on)
        want = ops.first_layer_conv(jnp.array(x), jnp.array(w), jnp.array(b),
                                    2, "SAME", "relu", eng_off)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# MISC core
# ---------------------------------------------------------------------------

class TestMisc:
    @pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 9, 9, 32)])
    def test_add_shapes(self, rng, shape):
        a = rng.normal(size=shape).astype(np.float32)
        b = rng.normal(size=shape).astype(np.float32)
        got = misc_pe.misc_add(jnp.array(a), jnp.array(b), 1.5, -0.5, "relu",
                               interpret=True)
        want = ref.misc_add(jnp.array(a), jnp.array(b), 1.5, -0.5, "relu")
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-5)

    def test_avgpool(self, rng):
        x = rng.normal(size=(2, 8, 8, 128)).astype(np.float32)
        got = misc_pe.avgpool2d(jnp.array(x), 2, 2, interpret=True)
        want = ref.avgpool2d(jnp.array(x), 2, 2)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-5)

    def test_requantized_add(self, rng):
        a = rng.normal(size=(4, 128)).astype(np.float32)
        b = rng.normal(size=(4, 128)).astype(np.float32)
        got = misc_pe.misc_add(jnp.array(a), jnp.array(b), 1.0, 1.0, "none",
                               out_scale=0.05, interpret=True)
        assert got.dtype == jnp.int8
        want = ref.misc_add(jnp.array(a), jnp.array(b), 1.0, 1.0, "none",
                            out_scale=jnp.float32(0.05))
        np.testing.assert_array_equal(np.array(got), np.array(want))


# ---------------------------------------------------------------------------
# ops.linear property test
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(m=st.integers(1, 40), k=st.integers(8, 96), n=st.integers(8, 80))
def test_linear_pallas_equals_ref_property(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    wq = quantize(jnp.array(w), axis=1)
    got = ops.linear(jnp.array(x), wq, None, "none", PALLAS)
    want = ops.linear(jnp.array(x), wq, None, "none", REF)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Flash attention kernel (beyond-paper)
# ---------------------------------------------------------------------------

class TestFlashKernel:
    @pytest.mark.parametrize("l,s", [(128, 128), (256, 256), (128, 256)])
    def test_causal_matches_oracle(self, rng, l, s):
        q = jnp.array(rng.normal(size=(2, 3, l, 32)).astype(np.float32))
        k = jnp.array(rng.normal(size=(2, 3, s, 32)).astype(np.float32))
        v = jnp.array(rng.normal(size=(2, 3, s, 32)).astype(np.float32))
        got = ops.flash_mha(q, k, v, causal=True)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    def test_ragged_and_softcap(self, rng):
        q = jnp.array(rng.normal(size=(1, 2, 200, 32)).astype(np.float32))
        k = jnp.array(rng.normal(size=(1, 2, 200, 32)).astype(np.float32))
        v = jnp.array(rng.normal(size=(1, 2, 200, 32)).astype(np.float32))
        got = ops.flash_mha(q, k, v, causal=True, softcap=20.0)
        want = ref.attention(q, k, v, causal=True, logit_softcap=20.0)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal(self, rng):
        q = jnp.array(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
        k = jnp.array(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
        v = jnp.array(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
        got = ops.flash_mha(q, k, v, causal=False)
        want = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)
