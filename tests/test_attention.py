"""Chunked flash attention vs the dense oracle: causal / local / softcap /
GQA / offsets / triangle-skip / decode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models import layers as L


def _qkv(rng, b=2, lq=48, lk=48, hkv=2, g=2, d=16):
    q = rng.normal(size=(b, lq, hkv, g, d)).astype(np.float32)
    k = rng.normal(size=(b, lk, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, lk, hkv, d)).astype(np.float32)
    return jnp.array(q), jnp.array(k), jnp.array(v)


def _oracle(q, k, v, **kw):
    """ref.attention expects [B, H, L, D] with flat heads."""
    b, l, hkv, g, d = q.shape
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b, hkv * g, l, d)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    # repeat kv heads to match flat q heads
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    out = ref.attention(qf, kf, vf, **kw)
    return out.reshape(b, hkv, g, l, d).transpose(0, 3, 1, 2, 4)


class TestFlash:
    @pytest.mark.parametrize("blocks", [(16, 16), (32, 64), (128, 128)])
    def test_causal(self, rng, blocks):
        q, k, v = _qkv(rng)
        got = L.flash_attention(q, k, v, causal=True,
                                block_q=blocks[0], block_kv=blocks[1])
        want = _oracle(q, k, v, causal=True)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal(self, rng):
        q, k, v = _qkv(rng)
        got = L.flash_attention(q, k, v, causal=False, block_q=16,
                                block_kv=16)
        want = _oracle(q, k, v, causal=False)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [8, 24])
    def test_local_window(self, rng, window):
        q, k, v = _qkv(rng)
        got = L.flash_attention(q, k, v, causal=True, window=window,
                                block_q=16, block_kv=16)
        want = _oracle(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    def test_softcap(self, rng):
        q, k, v = _qkv(rng)
        got = L.flash_attention(q, k, v, causal=True, logit_softcap=10.0,
                                block_q=16, block_kv=16)
        want = _oracle(q, k, v, causal=True, logit_softcap=10.0)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    def test_triangle_skip_identical(self, rng):
        """The exact-triangle dynamic loop must match the masked scan."""
        q, k, v = _qkv(rng, lq=64, lk=64)
        base = L.flash_attention(q, k, v, causal=True, block_q=16,
                                 block_kv=16, triangle_skip=False)
        skip = L.flash_attention(q, k, v, causal=True, block_q=16,
                                 block_kv=16, triangle_skip=True)
        np.testing.assert_allclose(np.array(base), np.array(skip),
                                   rtol=1e-5, atol=1e-5)

    def test_q_offset_continuation(self, rng):
        """Prefill continuation: q at offset attends to earlier kv."""
        q, k, v = _qkv(rng, lq=16, lk=48)
        got = L.flash_attention(q, k, v, causal=True, q_offset=32,
                                block_q=16, block_kv=16)
        # oracle: positions line up so q[i] sees kv[: 32+i+1]
        b, l, hkv, g, d = q.shape
        qf = q.transpose(0, 2, 3, 1, 4).reshape(b, hkv * g, l, d)
        kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
        vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
        want = ref.attention(qf, kf, vf, causal=True)   # lk-lq offset rule
        want = want.reshape(b, hkv, g, l, d).transpose(0, 3, 1, 2, 4)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)

    def test_ragged_lengths_padding(self, rng):
        """Non-multiple-of-block lengths are padded losslessly."""
        q, k, v = _qkv(rng, lq=21, lk=37)
        got = L.flash_attention(q, k, v, causal=False, block_q=16,
                                block_kv=16)
        want = _oracle(q, k, v, causal=False)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=2e-4, atol=2e-4)


class TestDecodeAttention:
    def test_matches_full_attention_last_token(self, rng):
        b, s, hkv, g, d = 2, 32, 2, 3, 16
        kc = jnp.array(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        vc = jnp.array(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
        q = jnp.array(rng.normal(size=(b, 1, hkv, g, d)).astype(np.float32))
        length = 20
        got = L.decode_attention(q, kc, vc, jnp.asarray(length))
        want = L.flash_attention(q, kc[:, :length], vc[:, :length],
                                 causal=True, q_offset=length - 1,
                                 block_q=16, block_kv=16)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-4)

    def test_window_restricts_reads(self, rng):
        b, s, hkv, g, d = 1, 32, 1, 1, 8
        kc = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
        vc = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
        q = jnp.array(rng.normal(size=(b, 1, hkv, g, d)).astype(np.float32))
        # poison everything outside the window; result must not change
        kc2, vc2 = kc.copy(), vc.copy()
        kc2[:, :10] = 1e3
        vc2[:, :10] = 1e3
        a1 = L.decode_attention(q, jnp.array(kc), jnp.array(vc),
                                jnp.asarray(25), window=8)
        a2 = L.decode_attention(q, jnp.array(kc2), jnp.array(vc2),
                                jnp.asarray(25), window=8)
        np.testing.assert_allclose(np.array(a1), np.array(a2), rtol=1e-6)


class TestRope:
    def test_rope_preserves_norm(self, rng):
        x = jnp.array(rng.normal(size=(2, 8, 4, 32)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        cos, sin = L.rope_angles(pos, 32, 10000.0)
        y = L.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.array(x), axis=-1),
            np.linalg.norm(np.array(y), axis=-1), rtol=1e-5)

    def test_rope_relative_property(self, rng):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = rng.normal(size=(32,)).astype(np.float32)
        k = rng.normal(size=(32,)).astype(np.float32)
        def dot_at(i, j):
            pos = jnp.array([[i, j]])
            cos, sin = L.rope_angles(pos, 32, 100.0)
            qr = L.apply_rope(jnp.array(q)[None, None], cos[:, :1], sin[:, :1])
            kr = L.apply_rope(jnp.array(k)[None, None], cos[:, 1:], sin[:, 1:])
            return float(jnp.sum(qr * kr))
        assert abs(dot_at(3, 7) - dot_at(13, 17)) < 1e-3

    def test_mrope_sections(self):
        """M-RoPE with equal t/h/w positions == plain RoPE at that position."""
        pos3 = jnp.full((1, 4, 3), 5, jnp.int32)
        pos1 = jnp.full((1, 4), 5, jnp.int32)
        c3, s3 = L.rope_angles(pos3, 128, 10000.0, (16, 24, 24))
        c1, s1 = L.rope_angles(pos1, 128, 10000.0)
        np.testing.assert_allclose(np.array(c3), np.array(c1), rtol=1e-6)
        np.testing.assert_allclose(np.array(s3), np.array(s1), rtol=1e-6)
