"""Multi-tenant fabric interleaving: merge_schedules structure + cost
properties, execute_interleaved bit-identity to isolated execution across
backends, and the FabricPump serving contract (interleaved == serialized ==
isolated, on CNN logits AND LM token ids).

The invariant under test is the one MergedSchedule documents: interleaving
changes WHEN levels fire, never what they compute -- each lane keeps its own
value environment, so co-tenancy is free of cross-tenant numerics."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro import compiler, configs
from repro.compiler import cost as cost_lib
from repro.compiler.schedule import MergedSchedule
from repro.configs.cnn_zoo import CNN_ZOO
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import cnn as cnn_lib
from repro.models import transformer as T
from repro.models.params import init_params, is_spec

B, PLEN, MAX_SEQ, STEPS = 2, 8, 32, 3


def _cnn_setup(name="squeezenet", hw=32, batch=2, seed=0):
    cfg = dataclasses.replace(CNN_ZOO[name], input_hw=hw)
    params = init_params(cnn_lib.cnn_schema(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(seed).normal(
        size=(batch, hw, hw, cfg.input_ch)).astype(np.float32) * 0.5)
    return cfg, params, x


def _lm_setup(name="qwen2-1.5b", seed=0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(seed).integers(
        0, arch.vocab_size, (B, PLEN)).astype(np.int32))
    return arch, params, toks


def _cache(arch, batch, seq, eng):
    return jtu.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                        T.cache_schema(arch, batch, seq, eng),
                        is_leaf=is_spec)


def _decode_pair(arch):
    """(decode program, its cost node_times) -- lane B of every merge."""
    prog = compiler.compile_lm(arch, mode="decode")
    times = cost_lib.lm_node_times(prog.graph, arch, B, 1, cache_len=PLEN)
    return prog, times


# ---------------------------------------------------------------------------
# merge_schedules: structure + the cost DP's never-worse guarantees
# ---------------------------------------------------------------------------

class TestMergeSchedules:
    @pytest.mark.parametrize("name", ["squeezenet", "resnet50",
                                      "mobilenetv2"])
    def test_merged_preserves_both_orders(self, name):
        """Both policies dispatch each program's levels exactly once, in
        order (validate_merged's invariant -- what makes interleaved
        execution bit-identical), and every tick fires at least one lane."""
        arch, _, _ = _lm_setup()
        dec, times_b = _decode_pair(arch)
        cfg = CNN_ZOO[name]
        prog = compiler.compile_cnn(cfg, policy="cost")
        times_a = cost_lib.cnn_node_times(prog.graph, cfg)
        for policy in ("asap", "cost"):
            m = compiler.merge_schedules(prog.graph, prog.schedule,
                                         dec.graph, dec.schedule,
                                         times_a, times_b, policy=policy)
            compiler.validate_merged(prog.schedule, dec.schedule, m)
            assert all(ia is not None or ib is not None
                       for ia, ib in m.ticks)
            assert m.n_ticks == len(m.ticks)
            assert m.n_ticks <= (prog.schedule.n_levels
                                 + dec.schedule.n_levels)

    def test_cost_merge_never_worse_zoo_wide(self):
        """Modeled makespans order as the DP promises on every zoo model:
        cost DP <= naive in-order zip <= fully serialized."""
        arch, _, _ = _lm_setup()
        dec, times_b = _decode_pair(arch)
        for name, cfg in CNN_ZOO.items():
            prog = compiler.compile_cnn(cfg, policy="cost")
            times_a = cost_lib.cnn_node_times(prog.graph, cfg)
            ms = {}
            for policy in ("asap", "cost"):
                m = compiler.merge_schedules(prog.graph, prog.schedule,
                                             dec.graph, dec.schedule,
                                             times_a, times_b,
                                             policy=policy)
                ms[policy] = m.stats["makespan"]
                assert m.stats["makespan"] <= (m.stats["serialized_makespan"]
                                               + 1e-12), name
                assert 0.0 < m.stats["occupancy"] <= 1.0, name
            assert ms["cost"] <= ms["asap"] + 1e-12, name

    def test_unknown_merge_policy_rejected(self):
        arch, _, _ = _lm_setup()
        dec, _ = _decode_pair(arch)
        prog = compiler.compile_cnn(CNN_ZOO["squeezenet"])
        with pytest.raises(ValueError, match="policy"):
            compiler.merge_schedules(prog.graph, prog.schedule,
                                     dec.graph, dec.schedule,
                                     policy="greedy")

    def test_validate_merged_rejects_broken_streams(self):
        arch, _, _ = _lm_setup()
        dec, _ = _decode_pair(arch)
        prog = compiler.compile_cnn(CNN_ZOO["squeezenet"])
        m = compiler.merge_schedules(prog.graph, prog.schedule,
                                     dec.graph, dec.schedule)
        # drop the last tick: lane coverage breaks
        broken = MergedSchedule(ticks=m.ticks[:-1], stats=m.stats)
        with pytest.raises(ValueError):
            compiler.validate_merged(prog.schedule, dec.schedule, broken)
        # swap two of lane A's levels: order breaks
        ia = [t for t, (a, _) in enumerate(m.ticks) if a is not None]
        ticks = list(m.ticks)
        t0, t1 = ia[0], ia[1]
        ticks[t0] = (m.ticks[t1][0], ticks[t0][1])
        ticks[t1] = (m.ticks[t0][0], ticks[t1][1])
        swapped = MergedSchedule(ticks=tuple(ticks), stats=m.stats)
        with pytest.raises(ValueError):
            compiler.validate_merged(prog.schedule, dec.schedule, swapped)


# ---------------------------------------------------------------------------
# execute_interleaved: bit-identity to isolated execution
# ---------------------------------------------------------------------------

class TestInterleavedExecution:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_bit_identical_to_isolated(self, backend):
        """A static-int8 CNN wave interleaved with greedy LM decode steps:
        CNN logits, LM logits, token ids AND the KV cache match isolated
        execution bitwise on both backends, under both merge policies."""
        cfg, params, x = _cnn_setup()
        arch, lm_params, toks = _lm_setup()
        eng_a = EngineConfig(quant="w8a8", backend=backend)
        eng_b = EngineConfig(quant="none", backend=backend)
        qparams = eng_lib.quantize_params(params, eng_a)
        prog = compiler.compile_calibrated(cfg, params, [x], policy="cost")
        dec, times_b = _decode_pair(arch)
        times_a = cost_lib.cnn_node_times(prog.graph, cfg)

        def prefilled():
            cache = _cache(arch, B, MAX_SEQ, eng_b)
            logits, cache = T.prefill(lm_params, cache, {"tokens": toks},
                                      arch, eng_b)
            cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            return cache, cur

        # isolated: CNN alone, then the greedy decode loop alone
        iso_cnn = np.asarray(compiler.execute(prog, qparams, x, eng_a))
        cache, cur = prefilled()
        iso_ids, iso_logits = [], []
        for _ in range(STEPS):
            ld, cache = compiler.execute_decode(dec, lm_params, cache, cur,
                                                eng_b)
            iso_logits.append(np.asarray(ld))
            cur = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
            iso_ids.append(np.asarray(cur))
        iso_cache = cache

        for policy in ("asap", "cost"):
            merged = compiler.merge_schedules(
                prog.graph, prog.schedule, dec.graph, dec.schedule,
                times_a, times_b, policy=policy)
            cache, cur = prefilled()
            for step in range(STEPS):
                la, ld, cache = compiler.execute_interleaved(
                    prog, qparams, x, dec, lm_params, cache, cur,
                    eng_a, eng_b=eng_b, merged=merged)
                np.testing.assert_array_equal(np.asarray(la), iso_cnn)
                np.testing.assert_array_equal(np.asarray(ld),
                                              iso_logits[step])
                cur = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
                np.testing.assert_array_equal(np.asarray(cur),
                                              iso_ids[step])
            for got, want in zip(jtu.tree_leaves(cache),
                                 jtu.tree_leaves(iso_cache)):
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want))

    def test_bit_identical_under_jit(self):
        """The fused-tick path FabricPump jits: one jitted call running both
        lanes returns the same CNN logits / LM logits / cache as the
        isolated JITTED calls the serving engines dispatch (static int8
        programs on both lanes -- the pump's serving configuration)."""
        cfg, params, x = _cnn_setup()
        arch, lm_params, toks = _lm_setup()
        eng = EngineConfig(quant="w8a8", backend="ref")
        qparams = eng_lib.quantize_params(params, eng)
        qlm = eng_lib.quantize_params(lm_params, eng)
        prog = compiler.compile_calibrated(cfg, params, [x], policy="cost")
        dec = compiler.compile_lm_calibrated(arch, lm_params, [toks],
                                             mode="decode", policy="cost")
        times_a = cost_lib.cnn_node_times(prog.graph, cfg)
        times_b = cost_lib.lm_node_times(dec.graph, arch, B, 1,
                                         cache_len=PLEN)
        merged = compiler.merge_schedules(prog.graph, prog.schedule,
                                          dec.graph, dec.schedule,
                                          times_a, times_b, policy="cost")
        # a fresh cache at pos 0 keeps the setup prefill-free: bit-identity
        # of the decode step does not care how the history got there
        cache = _cache(arch, B, MAX_SEQ, eng)
        cur = toks[:, :1]

        iso_cnn = np.asarray(jax.jit(
            lambda qp, im: compiler.execute(prog, qp, im, eng))(qparams, x))
        iso_ld, iso_cache = jax.jit(
            lambda lp, c, t: compiler.execute_decode(dec, lp, c, t, eng)
        )(qlm, dict(cache), cur)

        step = jax.jit(lambda qp, im, lp, c, t: compiler.execute_interleaved(
            prog, qp, im, dec, lp, c, t, eng, merged=merged))
        la, ld, new_cache = step(qparams, x, qlm, dict(cache), cur)
        np.testing.assert_array_equal(np.asarray(la), iso_cnn)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(iso_ld))
        for got, want in zip(jtu.tree_leaves(new_cache),
                             jtu.tree_leaves(iso_cache)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_lane_kind_validation(self):
        cfg, params, x = _cnn_setup()
        arch, lm_params, _ = _lm_setup()
        eng = EngineConfig(quant="none", backend="ref")
        fwd = compiler.compile_cnn(cfg)
        dec, _ = _decode_pair(arch)
        cache = _cache(arch, B, MAX_SEQ, eng)
        cur = jnp.zeros((B, 1), jnp.int32)
        with pytest.raises(ValueError, match="forward"):
            compiler.execute_interleaved(dec, lm_params, cur, dec, lm_params,
                                         cache, cur, eng)
        with pytest.raises(ValueError, match="decode"):
            compiler.execute_interleaved(fwd, params, x, fwd, params,
                                         cache, cur, eng)


# ---------------------------------------------------------------------------
# FabricPump: the serving-layer contract
# ---------------------------------------------------------------------------

N_IMAGES, N_PROMPTS, NEW_TOKENS, WAVE = 6, 2, 4, 4


def _pump(interleave: bool):
    from repro.serve.base import FabricPump
    from repro.serve.cnn_engine import CNNServeEngine
    from repro.serve.engine import ServeEngine

    cfg, params, x = _cnn_setup(batch=2)
    arch, lm_params, toks = _lm_setup()
    cnn = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE)
    cnn.register(cfg, params, calib_batches=[x])
    lm = ServeEngine(arch, lm_params, EngineConfig(quant="w8a8",
                                                   backend="ref"),
                     batch_size=B, max_seq=MAX_SEQ, calib_batches=[toks],
                     prefill_len=PLEN)
    return FabricPump(cnn, lm, interleave=interleave), cfg, arch


def _workload(cfg, arch, seed=0):
    rng = np.random.default_rng(seed)
    images = [rng.normal(size=(cfg.input_hw, cfg.input_hw, cfg.input_ch)
                         ).astype(np.float32) for _ in range(N_IMAGES)]
    prompts = [rng.integers(0, arch.vocab_size, size=PLEN).astype(np.int32)
               for _ in range(N_PROMPTS)]
    return images, prompts


class TestFabricPump:
    def test_interleaved_matches_serialized_and_isolated(self):
        """The acceptance contract: the pump's interleaved run, its
        serialized run, and isolated per-engine execution all return
        bit-identical CNN logits and LM token ids."""
        pump, cfg, arch = _pump(interleave=True)
        images, prompts = _workload(cfg, arch)
        il_logits, il_tokens = pump.run(cfg.name, images, prompts,
                                        max_new_tokens=NEW_TOKENS)
        st = pump.stats()
        assert st["ticks"] > 0 and st["fused_ticks"] > 0
        assert "merged" in st and st["merged"]["ticks"] > 0

        sp, _, _ = _pump(interleave=False)
        sr_logits, sr_tokens = sp.run(cfg.name, images, prompts,
                                      max_new_tokens=NEW_TOKENS)
        assert sp.stats()["fused_ticks"] == 0

        iso, _, _ = _pump(interleave=True)
        iso_logits = [np.asarray(r) for r in
                      iso.cnn.infer(cfg.name, np.stack(images))]
        iso_tokens = list(iso.lm.generate(list(prompts),
                                          max_new_tokens=NEW_TOKENS))

        assert len(il_logits) == len(sr_logits) == N_IMAGES
        assert len(il_tokens) == len(sr_tokens) == N_PROMPTS
        for a, b, c in zip(iso_logits, il_logits, sr_logits):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        for a, b, c in zip(iso_tokens, list(il_tokens.values()),
                           list(sr_tokens.values())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_multi_model_dict_round_robin(self):
        """The per-model submission dict: two registered CNNs with DIFFERENT
        input shapes serve through one pump.run({name: imgs}, prompts) call.
        Waves drain round-robin across the shape groups, each model fuses
        its own program pair with the LM decode lane, and every output is
        bit-identical to serialized and isolated execution."""
        def build(interleave):
            from repro.serve.base import FabricPump
            from repro.serve.cnn_engine import CNNServeEngine
            from repro.serve.engine import ServeEngine
            cfg_a, params_a, xa = _cnn_setup("squeezenet", hw=32)
            cfg_b = dataclasses.replace(CNN_ZOO["squeezenet"], input_hw=64,
                                        name="squeezenet64")
            params_b = init_params(cnn_lib.cnn_schema(cfg_b),
                                   jax.random.PRNGKey(2))
            xb = jnp.asarray(np.random.default_rng(3).normal(
                size=(2, 64, 64, cfg_b.input_ch)).astype(np.float32) * 0.5)
            arch, lm_params, toks = _lm_setup()
            cnn = CNNServeEngine(eng_lib.paper_engine(), wave_size=WAVE)
            cnn.register(cfg_a, params_a, calib_batches=[xa])
            cnn.register(cfg_b, params_b, calib_batches=[xb])
            lm = ServeEngine(arch, lm_params,
                             EngineConfig(quant="w8a8", backend="ref"),
                             batch_size=B, max_seq=MAX_SEQ,
                             calib_batches=[toks], prefill_len=PLEN)
            return FabricPump(cnn, lm, interleave=interleave), cfg_a, cfg_b, arch

        rng = np.random.default_rng(7)
        imgs_a = [rng.normal(size=(32, 32, 3)).astype(np.float32)
                  for _ in range(5)]
        imgs_b = [rng.normal(size=(64, 64, 3)).astype(np.float32)
                  for _ in range(3)]
        pump, cfg_a, cfg_b, arch = build(interleave=True)
        prompts = [rng.integers(0, arch.vocab_size, size=PLEN
                                ).astype(np.int32) for _ in range(N_PROMPTS)]
        subs = {cfg_a.name: imgs_a, cfg_b.name: imgs_b}
        il_logits, il_tokens = pump.run(subs, prompts,
                                        max_new_tokens=NEW_TOKENS)
        st = pump.stats()
        assert st["fused_ticks"] > 0
        assert set(st["merged_by_model"]) == {cfg_a.name, cfg_b.name}
        assert pump.cnn.execs_by_model[cfg_a.name] == 2   # 5 imgs / wave 4
        assert pump.cnn.execs_by_model[cfg_b.name] == 1   # 3 imgs / wave 4
        assert pump.cnn.wave_stats.waves == 3

        sp, _, _, _ = build(interleave=False)
        sr_logits, sr_tokens = sp.run(subs, prompts,
                                      max_new_tokens=NEW_TOKENS)
        assert sp.stats()["fused_ticks"] == 0

        iso, _, _, _ = build(interleave=True)
        iso_logits = ([np.asarray(r) for r in
                       iso.cnn.infer(cfg_a.name, np.stack(imgs_a))]
                      + [np.asarray(r) for r in
                         iso.cnn.infer(cfg_b.name, np.stack(imgs_b))])
        iso_tokens = list(iso.lm.generate(list(prompts),
                                          max_new_tokens=NEW_TOKENS))

        assert len(il_logits) == len(sr_logits) == len(imgs_a) + len(imgs_b)
        for a, b, c in zip(iso_logits, il_logits, sr_logits):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        for a, b, c in zip(iso_tokens, list(il_tokens.values()),
                           list(sr_tokens.values())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_dict_form_matches_legacy_single_model(self):
        """run({name: imgs}, prompts) and the legacy run(name, imgs,
        prompts) positional form return identical results (same pump, so
        the second run rides the cached programs and fused trace)."""
        pump, cfg, arch = _pump(interleave=True)
        images, prompts = _workload(cfg, arch)
        leg_logits, leg_tokens = pump.run(cfg.name, images, prompts,
                                          max_new_tokens=NEW_TOKENS)
        new_logits, new_tokens = pump.run({cfg.name: images}, prompts,
                                          max_new_tokens=NEW_TOKENS)
        assert len(leg_logits) == len(new_logits) == N_IMAGES
        for a, b in zip(leg_logits, new_logits):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(list(leg_tokens.values()),
                        list(new_tokens.values())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latency_tracking(self):
        """Every request leaves a submit->response latency sample in the
        pump tracker (the serve_mixed p50/p99 evidence path)."""
        pump, cfg, arch = _pump(interleave=True)
        images, prompts = _workload(cfg, arch)
        pump.run(cfg.name, images, prompts, max_new_tokens=NEW_TOKENS)
        pct = pump.latency.percentiles()
        assert pct["n"] == N_IMAGES + N_PROMPTS
        assert pct["p99_ms"] >= pct["p50_ms"] >= 0.0
