"""Paged KV cache + speculative decode invariants.

The paged serving path replaces the dense per-slot `[B, max_seq]` KV
envelope with block tables over a shared pool (`T.paged_cache_schema`),
admission by free blocks (`serve.kv_alloc.BlockAllocator`), and an
optional self-speculative `[B, 1+k]` verify burst.  These tests pin:

  * the allocator's free-list semantics (interchangeable blocks, LIFO
    reuse, zero-free backpressure, double-free detection);
  * bit-identical greedy token ids between paged and dense serving on
    the golden archs x {ref, pallas} over variable-length prompts;
  * speculative decode (k >= 2) emitting token-for-token the same ids
    as plain greedy decode, with acceptance stats populated;
  * structured submit() rejections (over_length / over_capacity),
    block-constrained backpressure, and the zero-progress deadlock
    guard;
  * ProgramKey separation: dense / paged / draft-width decode programs
    are distinct cache lines.
"""
import numpy as np
import pytest
import jax

from repro import configs
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, SubmitRejection
from repro.serve.kv_alloc import BlockAllocator

ENG = EngineConfig(quant="none", backend="ref")
W8 = EngineConfig(quant="w8a8", backend="ref")

GOLDEN = ["qwen2-1.5b", "gemma2-2b"]


def _setup(name, seed=0):
    arch = configs.reduced(configs.get_arch(name))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(seed))
    return arch, params


def _prompts(arch, n, seed=0, lens=(4, 5, 6, 7)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab_size, size=int(lens[i % len(lens)]))
            for i in range(n)]


def _engine(arch, params, eng=ENG, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_seq", 32)
    return ServeEngine(arch, params, eng, **kw)


# ---------------------------------------------------------------------------
# BlockAllocator: free-list semantics
# ---------------------------------------------------------------------------

class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = BlockAllocator(8)
        got = a.alloc(3)
        assert len(got) == 3 and len(set(got)) == 3
        assert all(0 <= b < 8 for b in got)
        assert a.in_use == 3 and a.free_blocks == 5
        a.free(got)
        assert a.in_use == 0 and a.free_blocks == 8
        assert a.stats.allocs == 1 and a.stats.frees == 1
        assert a.stats.blocks_served == 3

    def test_interleaved_frees_leave_no_fragmentation(self):
        """Blocks are interchangeable: after any alloc/free interleaving,
        every request up to the free count is satisfiable (no external
        fragmentation by construction)."""
        a = BlockAllocator(6)
        r1, r2, r3 = a.alloc(2), a.alloc(2), a.alloc(2)
        a.free(r2)                       # hole in the middle of the pool
        assert a.free_blocks == 2
        assert a.can_allocate(2)
        r4 = a.alloc(2)
        assert sorted(r4) == sorted(r2)  # LIFO reuse of the freed hole
        a.free(r1 + r3 + r4)
        assert a.can_allocate(6) and sorted(a.alloc(6)) == list(range(6))

    def test_zero_free_backpressure(self):
        a = BlockAllocator(4)
        a.alloc(4)
        assert not a.can_allocate(1)
        assert a.stats.denied == 1
        with pytest.raises(RuntimeError):
            a.alloc(1)
        # a zero-block probe still succeeds (empty request)
        assert a.can_allocate(0) and a.alloc(0) == []

    def test_double_free_and_range_checks(self):
        a = BlockAllocator(4)
        got = a.alloc(2)
        a.free(got)
        with pytest.raises(ValueError):
            a.free([got[0]])             # double free
        with pytest.raises(ValueError):
            a.free([4])                  # out of range
        with pytest.raises(ValueError):
            a.alloc(-1)
        with pytest.raises(ValueError):
            BlockAllocator(0)

    def test_peak_and_describe(self):
        a = BlockAllocator(8)
        r = a.alloc(5)
        a.free(r)
        a.alloc(2)
        d = a.describe()
        assert d["peak_in_use"] == 5 and d["in_use"] == 2
        assert d["utilization"] == pytest.approx(0.25)
        assert d["num_blocks"] == 8 and d["free_blocks"] == 6


# ---------------------------------------------------------------------------
# Paged vs dense: bit-identical greedy ids, golden archs x backends
# ---------------------------------------------------------------------------

class TestPagedParity:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("name", GOLDEN)
    def test_paged_ids_match_dense(self, name, backend):
        """Variable-length prompts through a paged engine produce token
        ids bit-identical to the dense engine, on both kernel backends
        (gemma2 exercises the local ring layers that stay dense)."""
        arch, params = _setup(name)
        eng = EngineConfig(quant="none", backend=backend, interpret=True)
        prompts = _prompts(arch, 5, seed=1)
        dense = _engine(arch, params, eng).generate(prompts,
                                                    max_new_tokens=3)
        paged = _engine(arch, params, eng, kv_layout="paged",
                        page_size=8).generate(prompts, max_new_tokens=3)
        for d, p in zip(dense, paged):
            np.testing.assert_array_equal(p, d)

    def test_paged_int8_kv_matches_dense(self):
        """The int8 KV pools (per-page scales) keep bit-identical ids."""
        arch, params = _setup("qwen2-1.5b")
        eng = EngineConfig(quant="none", backend="ref",
                           kv_cache_dtype="int8")
        prompts = _prompts(arch, 3, seed=2)
        dense = _engine(arch, params, eng).generate(prompts,
                                                    max_new_tokens=3)
        paged = _engine(arch, params, eng, kv_layout="paged",
                        page_size=8).generate(prompts, max_new_tokens=3)
        for d, p in zip(dense, paged):
            np.testing.assert_array_equal(p, d)

    def test_paged_schema_requires_page_multiple(self):
        arch, _ = _setup("qwen2-1.5b")
        with pytest.raises(ValueError):
            T.paged_cache_schema(arch, 2, 30, ENG, 8)

    def test_paged_slot_footprint_beats_dense_envelope(self):
        """The headline claim: at fixed memory, measured KV bytes/slot of
        the paged engine is strictly below the dense max_seq envelope for
        short requests, so sustainable concurrency is strictly higher."""
        arch, params = _setup("qwen2-1.5b")
        de = _engine(arch, params)
        pe = _engine(arch, params, kv_layout="paged", page_size=8)
        prompts = _prompts(arch, 4, seed=3)
        de.generate(prompts, max_new_tokens=3)
        pe.generate(prompts, max_new_tokens=3)
        ds, ps = de.stats(), pe.stats()
        assert ps["kv_bytes_per_slot"] < ds["kv_bytes_per_slot"]
        assert ps["kv_blocks"]["peak_in_use"] <= ps["kv_blocks"]["num_blocks"]
        assert ps["page_size"] == 8 and ps["kv_layout"] == "paged"


# ---------------------------------------------------------------------------
# Speculative decode: greedy-exact acceptance
# ---------------------------------------------------------------------------

class TestSpeculativeDecode:
    @pytest.mark.parametrize("k", [2, 3])
    def test_spec_matches_greedy_token_for_token(self, k):
        """Self-speculative verify bursts (draft width k) emit exactly the
        plain greedy ids: acceptance only ever commits tokens the verify
        logits agree with, and rejected tails are never observable."""
        arch, params = _setup("gemma2-2b")
        prompts = _prompts(arch, 5, seed=4)
        want = _engine(arch, params).generate(prompts, max_new_tokens=4)
        se = _engine(arch, params, kv_layout="paged", page_size=8,
                     draft_len=k)
        got = se.generate(prompts, max_new_tokens=4)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        st = se.stats()
        assert st["spec_steps"] > 0
        assert 0.0 <= st["accepted_draft_rate"] <= 1.0
        assert 1.0 <= st["tokens_per_burst"] <= 1 + k

    def test_spec_on_dense_layout(self):
        """draft_len composes with the dense cache too (layout and
        speculation are independent axes)."""
        arch, params = _setup("qwen2-1.5b")
        prompts = _prompts(arch, 3, seed=5)
        want = _engine(arch, params).generate(prompts, max_new_tokens=3)
        got = _engine(arch, params, draft_len=2).generate(prompts,
                                                          max_new_tokens=3)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    def test_spec_requires_compiled_decode(self):
        """Speculation and paging ride the compiled DecodeStep; a
        non-lowerable arch must fail loudly at construction, not fall
        back to an eager path that silently ignores them."""
        arch = configs.reduced(configs.get_arch("falcon-mamba-7b"))
        params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            _engine(arch, params, draft_len=2)
        with pytest.raises(ValueError):
            _engine(arch, params, kv_layout="paged", page_size=8)


# ---------------------------------------------------------------------------
# Admission, backpressure, rejection
# ---------------------------------------------------------------------------

class TestPagedAdmission:
    def test_structured_rejections(self):
        arch, params = _setup("qwen2-1.5b")
        se = _engine(arch, params, kv_layout="paged", page_size=8,
                     kv_blocks=2)
        long = np.zeros(30, np.int32)
        r = se.submit(long, max_new_tokens=8)
        assert isinstance(r, SubmitRejection) and not r
        assert r.reason == "over_length"
        # fits max_seq but needs 4 blocks of a 2-block pool
        r2 = se.submit(np.zeros(20, np.int32), max_new_tokens=8)
        assert isinstance(r2, SubmitRejection) and not r2
        assert r2.reason == "over_capacity"
        assert se.stats()["rejected_requests"] == 2
        assert se.pending() == 0

    def test_generate_surfaces_rejections(self):
        arch, params = _setup("qwen2-1.5b")
        se = _engine(arch, params, kv_layout="paged", page_size=8,
                     kv_blocks=2)
        with pytest.raises(ValueError, match="rejected"):
            se.generate([np.zeros(20, np.int32)], max_new_tokens=8)

    def test_block_constrained_pool_still_exact(self):
        """With only enough blocks for one request at a time the engine
        serializes admissions (denied probes counted) but the ids are
        unchanged from an unconstrained pool."""
        arch, params = _setup("qwen2-1.5b")
        prompts = _prompts(arch, 4, seed=6)
        free = _engine(arch, params, kv_layout="paged", page_size=8,
                       prefill_len=8)
        want = free.generate(prompts, max_new_tokens=3)
        tight = _engine(arch, params, kv_layout="paged", page_size=8,
                        prefill_len=8, kv_blocks=2)
        got = tight.generate(prompts, max_new_tokens=3)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        d = tight.stats()["kv_blocks"]
        assert d["denied"] > 0                  # backpressure happened
        assert d["peak_in_use"] <= 2
        assert d["in_use"] == 0                 # all released at the end
        assert d["allocs"] == d["frees"] == len(prompts)

    def test_padded_prompt_overflow_deadlock_guard(self):
        """A request that fits the pool by raw length but not once padded
        to the prefill bucket can never be admitted; run() must raise
        instead of spinning."""
        arch, params = _setup("qwen2-1.5b")
        se = _engine(arch, params, kv_layout="paged", page_size=8,
                     kv_blocks=1, prefill_len=16)
        t = se.submit(np.zeros(4, np.int32), max_new_tokens=2)
        assert not isinstance(t, SubmitRejection)
        with pytest.raises(RuntimeError, match="KV blocks"):
            se.run()


# ---------------------------------------------------------------------------
# ProgramKey separation across layout / draft width
# ---------------------------------------------------------------------------

class TestDecodeKeyVariants:
    def test_layout_and_draft_produce_distinct_keys(self):
        arch, params = _setup("qwen2-1.5b")
        dense = _engine(arch, params)
        paged = _engine(arch, params, kv_layout="paged", page_size=8)
        spec = _engine(arch, params, kv_layout="paged", page_size=8,
                       draft_len=3)
        keys = {dense._decode_key(), paged._decode_key(),
                spec._decode_key()}
        assert len(keys) == 3
        assert ":p8" in paged._decode_key().variant
        assert ":k3" in spec._decode_key().variant
