"""ProgramCache variant-key + LRU semantics.

The serving tier now keys programs by (model, engine, calibration-id,
variant) where the calibration id carries the weight mode (w4g64 vs int8)
and the variant carries the fusion mode (":nofuse" opt-out).  These tests
pin the container semantics those keys rely on:

  * w4/w8 and fused/":nofuse" variants of one model coexist -- distinct
    keys, no aliasing, no eviction collisions while capacity holds;
  * `__contains__` does NOT refresh recency (pruning a jit store against
    the cache must not perturb eviction order);
  * `get` DOES refresh recency; `peek` touches neither recency nor
    counters;
  * eviction pops the least-recently-used entry (popitem(last=False))."""
import numpy as np
import jax.numpy as jnp

from repro.core.program_cache import CacheStats, ProgramCache, ProgramKey
from repro.serve.base import calibration_digest


def _key(tag="", calib="c0", model="m"):
    return ProgramKey(model, "eng", calib, tag)


class TestVariantKeys:
    def test_w4_and_w8_calibration_ids_distinct(self):
        batches = [np.arange(6, dtype=np.int32).reshape(2, 3)]
        params = {"w": np.ones((2, 2), np.float32)}
        d8 = calibration_digest(batches, params)
        d4 = calibration_digest(batches, params, weight_mode="w4g64")
        d4b = calibration_digest(batches, params, weight_mode="w4g32")
        assert d8 != d4 and d4 != d4b
        assert d4.endswith(":w4g64") and d4b.endswith(":w4g32")
        # weight mode composes with (does not replace) the method /
        # granularity suffixes
        dp = calibration_digest(batches, params, method="p99.9",
                                granularity="per_channel",
                                weight_mode="w4g64")
        assert dp.endswith(":p99.9:pc:w4g64")

    def test_w4_w8_and_nofuse_variants_coexist(self):
        """All four programs of one model -- {w8, w4} x {fused, nofuse} --
        hold distinct cache lines with zero evictions."""
        cache = ProgramCache(capacity=4)
        keys = [_key("scheduled:decode", "c0"),
                _key("scheduled:decode", "c0:w4g64"),
                _key("scheduled:decode:nofuse", "c0"),
                _key("scheduled:decode:nofuse", "c0:w4g64")]
        assert len(set(keys)) == 4
        for i, k in enumerate(keys):
            cache.put(k, f"prog{i}")
        assert len(cache) == 4 and cache.stats.evictions == 0
        for i, k in enumerate(keys):
            assert cache.peek(k) == f"prog{i}"

    def test_paged_and_draft_decode_variants_coexist(self):
        """Decode programs for dense vs paged KV (":p{page}") and
        speculative draft widths (":k{draft}") are distinct cache lines:
        switching page size or draft length can never alias a stale
        trace whose cache layout or burst width no longer matches."""
        cache = ProgramCache(capacity=8)
        keys = [_key("scheduled:decode"),
                _key("scheduled:decode:p8"),
                _key("scheduled:decode:p16"),
                _key("scheduled:decode:p8:k3"),
                _key("scheduled:decode:p8:k4"),
                _key("scheduled:decode:k3")]
        assert len(set(keys)) == 6
        for i, k in enumerate(keys):
            cache.put(k, f"prog{i}")
        assert len(cache) == 6 and cache.stats.evictions == 0
        for i, k in enumerate(keys):
            assert cache.peek(k) == f"prog{i}"

    def test_get_or_compile_counts_per_variant(self):
        cache = ProgramCache(capacity=4)
        k8, k4 = _key("d", "c0"), _key("d", "c0:w4g64")
        assert cache.get_or_compile(k8, lambda: "p8") == "p8"
        assert cache.get_or_compile(k4, lambda: "p4") == "p4"
        assert cache.get_or_compile(k8, lambda: "never") == "p8"
        assert cache.stats.misses == 2 and cache.stats.hits == 1


class TestLRUSemantics:
    def _filled(self, n=3):
        cache = ProgramCache(capacity=n)
        keys = [_key(f"v{i}") for i in range(n)]
        for i, k in enumerate(keys):
            cache.put(k, i)
        return cache, keys

    def test_contains_does_not_refresh_recency(self):
        cache, keys = self._filled()
        assert keys[0] in cache                 # membership only
        cache.put(_key("new"), "x")
        assert keys[0] not in cache             # still evicted first
        assert keys[1] in cache and keys[2] in cache

    def test_get_refreshes_recency(self):
        cache, keys = self._filled()
        assert cache.get(keys[0]) == 0          # moves k0 to MRU
        cache.put(_key("new"), "x")
        assert keys[0] in cache
        assert keys[1] not in cache             # k1 became LRU

    def test_peek_touches_neither_recency_nor_counters(self):
        cache, keys = self._filled()
        before = CacheStats(**vars(cache.stats))
        assert cache.peek(keys[0]) == 0
        assert vars(cache.stats) == vars(before)
        cache.put(_key("new"), "x")
        assert keys[0] not in cache             # peek did not refresh

    def test_eviction_order_is_lru(self):
        cache, keys = self._filled()
        evicted = []
        cache2 = ProgramCache(capacity=3,
                              on_evict=lambda k, v: evicted.append(k))
        for i, k in enumerate(keys):
            cache2.put(k, i)
        for i in range(3):
            cache2.put(_key(f"n{i}"), "x")
        assert evicted == keys                  # oldest first, in order

    def test_put_refreshes_existing_key(self):
        cache, keys = self._filled()
        cache.put(keys[0], "updated")           # re-put refreshes recency
        cache.put(_key("new"), "x")
        assert cache.peek(keys[0]) == "updated"
        assert keys[1] not in cache
