"""Checkpointing: atomic publish, async, GC, elastic restore, pipeline
restart determinism."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.checkpoint import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro import configs
from repro.core.config import ShapeConfig


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "blocks": [{"a": jnp.arange(4.0)},
                              {"a": jnp.arange(4.0) * 2}]},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        st = _state()
        mgr.save(7, st)
        back = mgr.restore(jax.tree_util.tree_map(jnp.zeros_like, st))
        for a, b in zip(jax.tree_util.tree_leaves(st),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.array(a), np.array(b))

    def test_async_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        st = _state()
        mgr.save(3, st)
        mgr.wait()
        assert mgr.latest_step() == 3

    def test_atomic_no_tmp_after_publish(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, _state())
        entries = os.listdir(tmp_path)
        assert not any(e.endswith(".tmp") for e in entries)
        assert "step_00000001" in entries

    def test_gc_keeps_last_n(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state())
        assert mgr.all_steps() == [3, 4]

    def test_latest_wins(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        st = _state()
        mgr.save(1, st)
        st2 = jax.tree_util.tree_map(lambda x: x + 1, st)
        mgr.save(2, st2)
        back = mgr.restore(jax.tree_util.tree_map(jnp.zeros_like, st))
        np.testing.assert_array_equal(np.array(back["params"]["w"]),
                                      np.array(st2["params"]["w"]))

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, _state())
        bad = _state()
        bad["params"]["w"] = jnp.zeros((4, 4))
        with pytest.raises(ValueError):
            mgr.restore(bad)

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto explicit (single-device) shardings -- the elastic
        reshard path."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh()
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        st = _state()
        mgr.save(5, st)
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), st)
        back = mgr.restore(jax.tree_util.tree_map(jnp.zeros_like, st),
                           shardings=sh)
        np.testing.assert_array_equal(np.array(back["params"]["w"]),
                                      np.array(st["params"]["w"]))
        assert back["params"]["w"].sharding.mesh.shape == mesh.shape


class TestPipeline:
    def _pipe(self):
        arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
        shape = ShapeConfig("t", 32, 4, "train")
        return SyntheticTokens(arch, shape, PipelineConfig(seed=3))

    def test_deterministic_restart(self):
        """batch_at(k) is a pure function of (seed, k): restartable."""
        p1, p2 = self._pipe(), self._pipe()
        for k in (0, 5, 17):
            b1, b2 = p1.batch_at(k), p2.batch_at(k)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        p = self._pipe()
        assert not np.array_equal(p.batch_at(0)["tokens"],
                                  p.batch_at(1)["tokens"])

    def test_host_sharding_disjoint(self):
        arch = configs.reduced(configs.get_arch("qwen2-1.5b"))
        shape = ShapeConfig("t", 32, 4, "train")
        h0 = SyntheticTokens(arch, shape,
                             PipelineConfig(seed=3, host_index=0,
                                            host_count=2))
        h1 = SyntheticTokens(arch, shape,
                             PipelineConfig(seed=3, host_index=1,
                                            host_count=2))
        assert h0.local_batch == 2
        assert not np.array_equal(h0.batch_at(0)["tokens"],
                                  h1.batch_at(0)["tokens"])

    def test_labels_shifted(self):
        b = self._pipe().batch_at(0)
        assert b["tokens"].shape == b["labels"].shape

    def test_prefetch_iterator(self):
        from repro.data.pipeline import PrefetchIterator
        it = PrefetchIterator(self._pipe(), start_step=0, prefetch=2)
        b0 = next(it)
        b1 = next(it)
        it.close()
        assert b0["tokens"].shape == (4, 32)
        assert not np.array_equal(b0["tokens"], b1["tokens"])
