#!/usr/bin/env bash
# Tier-1 gate: collection must be clean (catches import-time regressions
# like a hard dependency on an uninstalled package), then the full suite.
#
#   scripts/check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest collection =="
python -m pytest -q --collect-only >/dev/null

echo "== tier-1 suite =="
python -m pytest -x -q "$@"
