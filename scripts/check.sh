#!/usr/bin/env bash
# Tier-1 gate: collection must be clean (catches import-time regressions
# like a hard dependency on an uninstalled package), then the full suite,
# then the serving benchmark's one-line program-cache summary.
#
#   scripts/check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest collection =="
# covers every suite, including the serving/schedule parity harness
# (tests/test_cnn_serving.py, tests/test_schedule.py, tests/test_compiler.py)
python -m pytest -q --collect-only >/dev/null

echo "== tier-1 suite =="
python -m pytest -x -q "$@"

echo "== serving cache + fusion =="
python -m benchmarks.serve_cnn --summary
echo "serving perf snapshot: $(pwd)/BENCH_serve.json"
python -m benchmarks.serve_lm --summary

echo "== decode throughput (compiled vs eager, w4 vs w8) =="
# also merges tokens/s + weight-bytes/token into BENCH_serve.json's
# "lm_decode" block (merge-preserving; serve_cnn/serve_fleet keys survive)
python -m benchmarks.serve_lm --decode-summary

echo "== paged KV + speculative decode + prefix sharing smoke =="
# dense vs paged vs paged+speculative on one arch (random AND repetitive-
# token acceptance legs): asserts bit-identical token ids, merges accepted-
# draft rate / tokens-per-burst / KV bytes-per-slot / p50-p99 latency into
# BENCH_serve.json's "lm_decode" block; then the prefix-sharing shared-
# prompt trace: 8 concurrent requests over one page-aligned system prompt,
# asserting <=0.6x fresh blocks/request and <=0.5x prefill tokens/request
# vs a no-sharing baseline, merged under "lm_decode"."prefix_sharing"
# (blocks/request + tokens/s guarded by bench_guard below)
python -m benchmarks.serve_lm --fast

echo "== fleet scaling smoke (forced 8 host devices) =="
# subprocess sweep over {1, 8} forced devices: asserts derived ops/s
# scales monotonically with the mesh (the full {1,2,4,8} sweep that
# records BENCH_serve.json's "fleet" block runs without --smoke)
python -m benchmarks.serve_fleet --smoke

echo "== mixed co-tenancy smoke (CNN waves + LM decode on one fabric) =="
# interleaved vs serialized at equal work through the FabricPump; asserts
# bit-identical outputs vs isolated engines and merge-writes the "mixed"
# block (ops/s, tokens/s, p50/p99, merged-schedule occupancy per policy)
python -m benchmarks.serve_mixed --summary --fast

echo "== bench guard (fresh smoke vs committed BENCH_serve.json) =="
# the steps above just regenerated the working-tree snapshot, so judge it
# as-is against HEAD's copy: >20% ops/s or p99 regression on a smoke leg
# fails the gate
python scripts/bench_guard.py --no-run
