#!/usr/bin/env python
"""Perf-regression gate over BENCH_serve.json.

Compares a FRESH smoke run (fast legs of the serving benchmarks) against
the committed snapshot (`git show HEAD:BENCH_serve.json`) and fails when
a smoke leg regresses past the tolerance:

  * any throughput figure (ops/s, tokens/s) drops by more than
    --tolerance (default 20%), or
  * any p99 latency rises by more than --tolerance.

The guard reads the committed snapshot from git (NOT the working tree --
the fresh legs merge-write into the working-tree file while running, so
the tree copy is already contaminated by the run being judged).  Legs
are the SMOKE subset only: throughput is noisy on shared CI hosts, and
the slow full legs already re-record the snapshot on release runs.

    PYTHONPATH=src:. python scripts/bench_guard.py [--tolerance 0.2]
                                                   [--no-run]

--no-run skips the fresh smoke run and re-checks whatever the working
tree currently holds against HEAD -- the mode check.sh uses, since its
earlier steps have just regenerated the tree snapshot.
"""
import argparse
import json
import subprocess
import sys

BENCH = "BENCH_serve.json"

# (human label, path into the snapshot dict, "higher"|"lower" is better)
# Only legs the smoke runs refresh: serve_lm --fast rewrites lm_decode's
# paged/spec fields; serve_mixed --summary --fast rewrites "mixed_fast"
# (the full "mixed" block is release-run only and keeps its committed
# numbers).  Full-run-only fields (serve_cnn ops_per_s, fleet sweep) are
# checked when present but skipped when either side lacks them.
GUARDED = [
    ("lm spec tokens/s", ("lm_decode", "tokens_per_s_spec"), "higher"),
    ("lm dense tokens/s", ("lm_decode", "tokens_per_s_dense"), "higher"),
    ("lm spec p99 ms", ("lm_decode", "latency_ms", "p99_ms"), "lower"),
    ("lm prefix-share tokens/s",
     ("lm_decode", "prefix_sharing", "tokens_per_s"), "higher"),
    ("lm prefix-share blocks/request",
     ("lm_decode", "prefix_sharing", "blocks_per_request"), "lower"),
    ("mixed interleaved ops/s", ("mixed_fast", "interleaved", "ops_per_s"),
     "higher"),
    ("mixed interleaved tok/s",
     ("mixed_fast", "interleaved", "tokens_per_s"), "higher"),
    ("mixed interleaved p99 ms",
     ("mixed_fast", "interleaved", "latency_ms", "p99_ms"), "lower"),
    ("cnn serve ops/s", ("ops_per_s",), "higher"),
]


def _dig(d, path):
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d if isinstance(d, (int, float)) else None


def committed_snapshot():
    """BENCH_serve.json as of HEAD, or None when it has no committed copy
    (first PR that records it: nothing to regress against)."""
    try:
        out = subprocess.run(["git", "show", f"HEAD:{BENCH}"],
                             capture_output=True, text=True, check=True)
    except (subprocess.CalledProcessError, OSError):
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def fresh_snapshot(run: bool):
    """Refresh the smoke legs (merge-writing the working-tree snapshot),
    then load it."""
    if run:
        for leg in (["-m", "benchmarks.serve_lm", "--fast"],
                    ["-m", "benchmarks.serve_mixed", "--summary", "--fast"]):
            subprocess.run([sys.executable] + leg, check=True)
    try:
        with open(BENCH) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def compare(old, new, tolerance):
    """[(label, old, new, ratio, ok)] for every guarded leg present in
    BOTH snapshots; absent legs are skipped, not failed."""
    rows = []
    for label, path, better in GUARDED:
        a, b = _dig(old, path), _dig(new, path)
        if a is None or b is None or a <= 0:
            continue
        ratio = b / a
        ok = ratio >= 1.0 - tolerance if better == "higher" \
            else ratio <= 1.0 + tolerance
        rows.append((label, a, b, ratio, ok))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="fractional regression allowed (default 0.2)")
    ap.add_argument("--no-run", action="store_true",
                    help="judge the working-tree snapshot as-is instead "
                         "of re-running the smoke legs first")
    args = ap.parse_args(argv)

    old = committed_snapshot()
    if old is None:
        print("bench_guard: no committed BENCH_serve.json at HEAD; "
              "nothing to regress against -- pass")
        return 0
    new = fresh_snapshot(run=not args.no_run)
    if new is None:
        print("bench_guard: FAIL -- fresh snapshot missing/unreadable")
        return 1

    rows = compare(old, new, args.tolerance)
    if not rows:
        print("bench_guard: no guarded legs present in both snapshots "
              "-- pass (vacuous)")
        return 0
    failed = [r for r in rows if not r[4]]
    for label, a, b, ratio, ok in rows:
        mark = "ok  " if ok else "FAIL"
        print(f"bench_guard: {mark} {label}: {a:.2f} -> {b:.2f} "
              f"({ratio:.2f}x, tol {args.tolerance:.0%})")
    if failed:
        print(f"bench_guard: FAIL -- {len(failed)}/{len(rows)} guarded "
              f"legs regressed past {args.tolerance:.0%}")
        return 1
    print(f"bench_guard: pass -- {len(rows)} guarded legs within "
          f"{args.tolerance:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
