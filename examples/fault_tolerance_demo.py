"""Fault-tolerance demo: kill a training run mid-flight, restart, verify the
loss curve continues exactly where it left off (checkpoint/restart), then
restart once more with a different process count analog (elastic restore).

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import shutil
import signal
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
CKPT = "/tmp/repro_ft_demo"


def run_train(steps, extra=(), wait=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2-1.5b", "--smoke", "--steps", str(steps),
           "--batch", "4", "--seq", "64", "--ckpt-dir", CKPT,
           "--ckpt-every", "5"] + list(extra)
    if wait:
        return subprocess.run(cmd, env=env, capture_output=True, text=True)
    return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)

    print("=== phase 1: start training, then simulate preemption (SIGTERM)")
    proc = run_train(1000, wait=False)
    time.sleep(75)                     # let it compile + take a checkpoint
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=300)
    print("exit code:", proc.returncode, "(75 = reschedule-me)")
    tail = [l for l in out.splitlines() if l][-3:]
    print("\n".join("  " + l for l in tail))

    print("\n=== phase 2: restart from the preemption checkpoint")
    out2 = run_train(0, extra=["--resume"])
    # figure out where phase 1 stopped
    resumed = [l for l in out2.stdout.splitlines() if "resumed" in l]
    steps_done = int(resumed[0].split()[-1]) if resumed else 0
    out3 = run_train(steps_done + 5, extra=["--resume"])
    print("\n".join("  " + l for l in out3.stdout.splitlines()
                    if "resumed" in l or l.startswith("step")))
    assert f"resumed from step {steps_done}" in out3.stdout
    print("\ncheckpoint/restart verified: no step re-done, loss continuous")


if __name__ == "__main__":
    main()
