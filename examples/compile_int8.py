"""Compile -> calibrate -> execute: the paper's instruction-driven flow.

Lowers a CNN to the engine op-graph, calibrates per-edge activation scales
from representative batches (the Vitis-AI step), folds the requants into the
engines' fused epilogues, and runs the resulting static-int8 program --
activations stay int8 from the stem to the classifier head, vs the eager
dynamic path that round-trips every edge through f32.

    PYTHONPATH=src python examples/compile_int8.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.configs.cnn_zoo import MOBILENET_V2, RESNET50
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import cnn
from repro.models.params import init_params


def main():
    for base in (RESNET50, MOBILENET_V2):
        cfg = dataclasses.replace(base, input_hw=64)
        params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        calib = [jnp.asarray(rng.normal(
            size=(4, cfg.input_hw, cfg.input_hw, 3)).astype(np.float32) * 0.5)
            for _ in range(2)]
        images = calib[0]

        # 1. compile + calibrate: float params, representative batches
        program = compiler.compile_calibrated(cfg, params, calib)
        st = program.plan.stats
        unfused = compiler.launch_count(compiler.build_graph(cfg))
        print(f"{cfg.name}: {len(program.graph.nodes)} ops, "
              f"{st['fused_ops']} fused epilogue chains, "
              f"launches/img {st['launches']} vs {unfused} unfused, "
              f"{st['folded_requants']} requants folded, "
              f"f32 round-trips: static={program.f32_roundtrips()} "
              f"dynamic={st['dynamic_f32_roundtrips']}")

        # 2. quantize weights and execute the static int8 program
        eng = eng_lib.paper_engine()                 # w8a8 + all engines
        qparams = eng_lib.quantize_params(params, eng)
        run = jax.jit(lambda p, im: compiler.execute(program, p, im, eng))
        logits_static = run(qparams, images)

        # 3. compare against the float ref and the eager dynamic path
        logits_f = cnn.cnn_forward(
            params, images, cfg, EngineConfig(quant="none", backend="ref"))
        logits_dyn = cnn.cnn_forward(qparams, images, cfg, eng)
        # (random-init logits are near-ties, so correlation -- not argmax
        # agreement -- is the meaningful closeness metric here)
        for tag, other in [("float", logits_f), ("dynamic-int8", logits_dyn)]:
            corr = np.corrcoef(np.array(logits_static).ravel(),
                               np.array(other).ravel())[0, 1]
            print(f"  static-int8 vs {tag}: corr={corr:.4f}")


if __name__ == "__main__":
    main()
