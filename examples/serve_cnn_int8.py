"""Serve multiple CNNs from one engine: compile -> cache -> batch -> schedule.

Registers two zoo models on a CNNServeEngine, then serves a repeated-model
request trace: each (model, calibration, engine) triple compiles to a
static-int8 program exactly once (program-cache hits after that), requests
batch into fixed-size waves, and the programs dispatch through the
concurrent-PE level schedule.

    PYTHONPATH=src python examples/serve_cnn_int8.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.configs.cnn_zoo import MOBILENET_V2, SQUEEZENET
from repro.core import engine as eng_lib
from repro.models import cnn
from repro.models.params import init_params
from repro.serve.cnn_engine import CNNServeEngine


def main():
    rng = np.random.default_rng(0)
    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=4,
                            cache_capacity=4)

    # 1. register models: float params + representative calibration batches
    for i, base in enumerate((SQUEEZENET, MOBILENET_V2)):
        cfg = dataclasses.replace(base, input_hw=32)
        params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(i))
        calib = jnp.asarray(rng.normal(
            size=(4, cfg.input_hw, cfg.input_hw, 3)).astype(np.float32) * 0.5)
        engine.register(cfg, params, calib_batches=[calib])

    # 2. a request trace that revisits the models: the first request per
    #    model compiles + calibrates, every later one is a program-cache hit
    trace = [engine.models()[int(i)] for i in
             rng.integers(0, 2, size=12)]
    served = 0
    for start in range(0, len(trace), 4):        # requests arrive in bursts
        for name in trace[start:start + 4]:
            img = rng.normal(size=(32, 32, 3)).astype(np.float32)
            engine.submit(name, img)
        served += len(engine.flush())            # waves per model
    print(f"served {served} requests")

    # 3. the evidence: compiles happened once per model, waves were batched,
    #    and the programs carry the concurrent-PE schedule
    for k, v in engine.stats().items():
        print(f"  {k}: {v}")
    prog = engine.program_for("squeezenet")      # fire e1/e3 convs co-level
    print(f"  schedule: {prog.schedule.stats}")
    print(f"  f32 round-trips (static): {prog.f32_roundtrips()} "
          f"(dynamic {compiler.compile_cnn(prog.cfg).f32_roundtrips()})")


if __name__ == "__main__":
    main()
