"""Quickstart: the paper's core story in 60 lines.

Quantize a CNN to INT8 and run it through the DPUV4E engines (Conv PE with
the fused MAC->ACC->NL epilogue, DWC PE for depthwise layers, the
Low-Channel unit for the stem, MISC fusion for residuals), then compare
against the XVDPU-analog baseline configuration.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cnn_zoo import MOBILENET_V2
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import cnn
from repro.models.params import init_params


def main():
    # A reduced-resolution MobileNetV2 (DWC-heavy -- the paper's favourite).
    cfg = dataclasses.replace(MOBILENET_V2, input_hw=64)
    params = init_params(cnn.cnn_schema(cfg), jax.random.PRNGKey(0))
    images = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, cfg.input_hw, cfg.input_hw, 3)).astype(np.float32) * 0.5)

    # 1. float reference
    eng_f = EngineConfig(quant="none", backend="ref")
    ref = cnn.cnn_forward(params, images, cfg, eng_f)

    # 2. the DPUV4E configuration: INT8 + all engines
    eng = eng_lib.paper_engine()                 # w8a8, DWC PE, LowPE, MISC
    qparams = eng_lib.quantize_params(params, eng)
    t0 = time.perf_counter()
    out = jax.jit(lambda p, x: cnn.cnn_forward(p, x, cfg, eng)
                  )(qparams, images).block_until_ready()
    t_ours = time.perf_counter() - t0

    # 3. the XVDPU-analog baseline (no DWC engine, unfused epilogues)
    eng_b = eng_lib.baseline_engine()
    t0 = time.perf_counter()
    base = jax.jit(lambda p, x: cnn.cnn_forward(p, x, cfg, eng_b)
                   )(qparams, images).block_until_ready()
    t_base = time.perf_counter() - t0

    agree = float(jnp.mean(jnp.argmax(out, -1) == jnp.argmax(ref, -1)))
    drift = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
    print(f"top-1 agreement int8 vs float: {agree:.0%}")
    print(f"mean relative drift:           {drift:.3f}")
    print(f"engine walltime (incl compile): ours {t_ours:.2f}s, "
          f"baseline {t_base:.2f}s")
    print("engines exercised: Conv PE (fused epilogue), DWC PE, "
          "Low-Channel unit, MISC fusion")


if __name__ == "__main__":
    main()
