"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A llama-family config (granite-8b's little sibling) trained on the synthetic
pipeline with the full production loop: AdamW, remat, checkpointing every 50
steps, resume on restart.

    PYTHONPATH=src python examples/train_100m.py --steps 300
(CPU: ~1-2 s/step at the default batch; use --steps 20 for a quick look.)
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import engine as eng_lib
from repro.core.config import ArchConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro.models import transformer as T
from repro.models.params import init_params, param_count
from repro.train import checkpoint as ckpt_lib
from repro.train.train_step import init_train_state, make_train_step

ARCH_100M = ArchConfig(
    name="llama-100m", family="dense",
    n_layers=8, d_model=640, n_heads=10, n_kv_heads=5,
    d_ff=1792, vocab_size=32768, head_dim=64,
    block_pattern=("global",), mlp_act="silu", tie_embeddings=True,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args(argv)

    arch = ARCH_100M
    schema = T.lm_schema(arch)
    n = param_count(schema)
    print(f"model: {arch.name}, {n / 1e6:.1f}M params")

    shape = ShapeConfig("ex", args.seq, args.batch, "train")
    tcfg = TrainConfig(lr=6e-4, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5),
                       remat="block", ckpt_every=50,
                       ckpt_dir=args.ckpt_dir)
    params = init_params(schema, jax.random.PRNGKey(0))
    state = init_train_state(params)
    mgr = ckpt_lib.CheckpointManager(tcfg.ckpt_dir, keep=2)
    start = 0
    if mgr.latest_step() is not None:
        state = mgr.restore(state)
        start = int(jax.device_get(state["opt"]["step"]))
        print(f"resumed from step {start}")

    pipe = SyntheticTokens(arch, shape, PipelineConfig(seed=0))
    step_fn = jax.jit(make_train_step(arch, eng_lib.train_engine(), tcfg),
                      donate_argnums=(0,))
    t_start = time.perf_counter()
    for step in range(start, args.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch_at(step))
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({(time.perf_counter() - t_start) / max(step - start + 1, 1):.2f} s/step)",
                  flush=True)
        if (step + 1) % tcfg.ckpt_every == 0:
            mgr.save(step + 1, state)
    mgr.save(args.steps, state)
    mgr.wait()
    print("done; checkpoints in", tcfg.ckpt_dir)


if __name__ == "__main__":
    main()
