"""Serve a W8A8-quantized LM with batched requests through the DPUV4E
serving path: quantize -> prefill -> batched greedy decode, with the int8 KV
cache (beyond-paper) switchable.

    PYTHONPATH=src python examples/serve_quantized.py --kv int8
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.config import EngineConfig
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args(argv)

    arch = configs.reduced(configs.get_arch(args.arch))
    params = init_params(T.lm_schema(arch), jax.random.PRNGKey(0))
    eng = EngineConfig(quant="w8a8", backend="ref", kv_cache_dtype=args.kv)
    engine = ServeEngine(arch, params, eng, batch_size=3, max_seq=64)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab_size, size=rng.integers(4, 12))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    tok = sum(map(len, outs))
    print(f"arch={arch.name} quant=w8a8 kv={args.kv}")
    print(f"{len(outs)} requests, {tok} tokens, {tok / dt:.1f} tok/s "
          f"(CPU, incl compile)")
    for i, o in enumerate(outs):
        print(f"  request {i} ({len(prompts[i])} prompt tokens) -> "
              f"{o[:8].tolist()}...")


if __name__ == "__main__":
    main()
