"""minitron-4b [dense]: pruned nemotron (squared-ReLU, ungated MLP).
[arXiv:2407.14679; hf]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
    block_pattern=("global",), mlp_act="relu2", mlp_gated=False,
    tie_embeddings=False,
)
