"""granite-moe-1b-a400m [moe]: 32 experts top-8, narrow d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    block_pattern=("global",), mlp_act="silu",
    n_experts=32, topk=8,
    tie_embeddings=True,
)
