"""The paper's CNN evaluation zoo (Table III / IV models).

Structures are the published architectures (YOLO backbones approximated as
their conv feature extractors); GOPs are the paper-reported per-inference
workloads used by the modeled-throughput benchmarks.
"""
from repro.core.config import CNNConfig, ConvSpec as C

RESNET50 = CNNConfig(
    name="resnet50", input_hw=224, input_ch=3,
    stem_kernel=7, stem_stride=2, stem_ch=64,
    stages=(
        C("pool", kernel=3, stride=2),
        C("bottleneck", out_ch=256, kernel=3, stride=1, repeat=3),
        C("bottleneck", out_ch=512, kernel=3, stride=2, repeat=4),
        C("bottleneck", out_ch=1024, kernel=3, stride=2, repeat=6),
        C("bottleneck", out_ch=2048, kernel=3, stride=2, repeat=3),
    ), gops=8.19)

RESNET152 = CNNConfig(
    name="resnet152", input_hw=224, input_ch=3,
    stem_kernel=7, stem_stride=2, stem_ch=64,
    stages=(
        C("pool", kernel=3, stride=2),
        C("bottleneck", out_ch=256, kernel=3, stride=1, repeat=3),
        C("bottleneck", out_ch=512, kernel=3, stride=2, repeat=8),
        C("bottleneck", out_ch=1024, kernel=3, stride=2, repeat=36),
        C("bottleneck", out_ch=2048, kernel=3, stride=2, repeat=3),
    ), gops=21.8)

MOBILENET_V1 = CNNConfig(
    name="mobilenetv1", input_hw=224, input_ch=3,
    stem_kernel=3, stem_stride=2, stem_ch=32,
    stages=(
        C("dwsep", out_ch=64, kernel=3, stride=1, repeat=1),
        C("dwsep", out_ch=128, kernel=3, stride=2, repeat=2),
        C("dwsep", out_ch=256, kernel=3, stride=2, repeat=2),
        C("dwsep", out_ch=512, kernel=3, stride=2, repeat=6),
        C("dwsep", out_ch=1024, kernel=3, stride=2, repeat=2),
    ), gops=1.02)

MOBILENET_V2 = CNNConfig(
    name="mobilenetv2", input_hw=224, input_ch=3,
    stem_kernel=3, stem_stride=2, stem_ch=32,
    stages=(
        C("inverted", out_ch=16, kernel=3, stride=1, repeat=1, expand=1),
        C("inverted", out_ch=24, kernel=3, stride=2, repeat=2, expand=6),
        C("inverted", out_ch=32, kernel=3, stride=2, repeat=3, expand=6),
        C("inverted", out_ch=64, kernel=3, stride=2, repeat=4, expand=6),
        C("inverted", out_ch=96, kernel=3, stride=1, repeat=3, expand=6),
        C("inverted", out_ch=160, kernel=3, stride=2, repeat=3, expand=6),
        C("inverted", out_ch=320, kernel=3, stride=1, repeat=1, expand=6),
        C("conv", out_ch=1280, kernel=1, stride=1, repeat=1),
    ), gops=0.60)

EFFICIENTNET_B0 = CNNConfig(
    name="efficientnet", input_hw=224, input_ch=3,
    stem_kernel=3, stem_stride=2, stem_ch=32,
    stages=(
        C("inverted", out_ch=16, kernel=3, stride=1, repeat=1, expand=1),
        C("inverted", out_ch=24, kernel=3, stride=2, repeat=2, expand=6),
        C("inverted", out_ch=40, kernel=5, stride=2, repeat=2, expand=6),
        C("inverted", out_ch=80, kernel=3, stride=2, repeat=3, expand=6),
        C("inverted", out_ch=112, kernel=5, stride=1, repeat=3, expand=6),
        C("inverted", out_ch=192, kernel=5, stride=2, repeat=4, expand=6),
        C("inverted", out_ch=320, kernel=3, stride=1, repeat=1, expand=6),
        C("conv", out_ch=1280, kernel=1, stride=1, repeat=1),
    ), gops=4.7)

SQUEEZENET = CNNConfig(
    name="squeezenet", input_hw=224, input_ch=3,
    stem_kernel=3, stem_stride=2, stem_ch=64,
    stages=(
        C("pool", kernel=3, stride=2),
        C("fire", out_ch=128, kernel=3, stride=1, repeat=2),
        C("pool", kernel=3, stride=2),
        C("fire", out_ch=256, kernel=3, stride=1, repeat=2),
        C("pool", kernel=3, stride=2),
        C("fire", out_ch=384, kernel=3, stride=1, repeat=2),
        C("fire", out_ch=512, kernel=3, stride=1, repeat=2),
    ), gops=0.7)

YOLOV3 = CNNConfig(
    name="yolov3", input_hw=416, input_ch=3,
    stem_kernel=3, stem_stride=1, stem_ch=32,
    stages=(  # darknet-53 feature extractor
        C("conv", out_ch=64, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=64, kernel=3, stride=1, repeat=1),
        C("conv", out_ch=128, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=128, kernel=3, stride=1, repeat=2),
        C("conv", out_ch=256, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=256, kernel=3, stride=1, repeat=8),
        C("conv", out_ch=512, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=512, kernel=3, stride=1, repeat=8),
        C("conv", out_ch=1024, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=1024, kernel=3, stride=1, repeat=4),
    ), gops=65.9)

YOLOV5N = CNNConfig(
    name="yolov5n", input_hw=640, input_ch=3,
    stem_kernel=6, stem_stride=2, stem_ch=16,
    stages=(
        C("conv", out_ch=32, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=32, kernel=3, stride=1, repeat=1),
        C("conv", out_ch=64, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=64, kernel=3, stride=1, repeat=2),
        C("conv", out_ch=128, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=128, kernel=3, stride=1, repeat=3),
        C("conv", out_ch=256, kernel=3, stride=2, repeat=1),
        C("bottleneck", out_ch=256, kernel=3, stride=1, repeat=1),
    ), gops=4.6)

CNN_ZOO = {c.name: c for c in [
    RESNET50, RESNET152, MOBILENET_V1, MOBILENET_V2, EFFICIENTNET_B0,
    SQUEEZENET, YOLOV3, YOLOV5N]}

# Paper Table III reference FPS (XVDPU C32B6 and our 6PE+DWC / 8PE columns).
PAPER_TABLE3 = {
    # name: (gops, b4096, xvdpu_c32b6, ours_6pe_dwc, ours_8pe, ratio)
    "resnet50":     (8.19, 190.3, 2676.7, 3417.8, 4568.9, 1.27),
    "resnet152":    (21.8, 84.7, 1200.1, 1586.1, 2108.8, 1.32),
    "yolov3":       (65.9, 37.5, 286.8, 382.9, 472.2, 1.33),
    "squeezenet":   (0.7, 1500.8, 5827.0, 6658.9, 7664.4, 1.14),
    "efficientnet": (4.7, 319.0, 2167.1, 3976.5, 3675.7, 1.83),
    "yolov5n":      (4.6, 201.4, 397.6, 868.3, 1379.8, 2.18),
    "mobilenetv1":  (1.02, 993.5, 4913.3, 8787.8, 9123.1, 1.78),
    "mobilenetv2":  (0.60, 764.41, 4930.3, 6565.3, 8315.8, 1.33),
}
