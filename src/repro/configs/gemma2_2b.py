"""gemma2-2b [dense]: local+global alternating attention, logit softcaps,
pre+post block norms, scaled embeddings.  [arXiv:2408.00118; hf]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    block_pattern=("local", "global"), local_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    mlp_act="gelu", tie_embeddings=True,
    post_norms=True, emb_scale=True,
)
