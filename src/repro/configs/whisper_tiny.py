"""whisper-tiny [audio]: encoder-decoder; the mel/conv frontend is a STUB per
the assignment (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    block_pattern=("global",), mlp_act="gelu",
    encoder_layers=4, encoder_seq=1500, cross_attention=True,
    tie_embeddings=True, frontend="audio_stub",
)
