"""Config registry: the 10 assigned architectures (+ reduced smoke variants)
and per-shape input specs."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, ShapeConfig, SHAPES

from repro.configs.granite_8b import ARCH as GRANITE_8B
from repro.configs.qwen2_1_5b import ARCH as QWEN2_1_5B
from repro.configs.gemma2_2b import ARCH as GEMMA2_2B
from repro.configs.minitron_4b import ARCH as MINITRON_4B
from repro.configs.qwen2_vl_7b import ARCH as QWEN2_VL_7B
from repro.configs.grok_1_314b import ARCH as GROK_1_314B
from repro.configs.granite_moe_1b import ARCH as GRANITE_MOE_1B
from repro.configs.recurrentgemma_2b import ARCH as RECURRENTGEMMA_2B
from repro.configs.whisper_tiny import ARCH as WHISPER_TINY
from repro.configs.falcon_mamba_7b import ARCH as FALCON_MAMBA_7B

ARCHS: Dict[str, ArchConfig] = {a.name: a for a in [
    GRANITE_8B, QWEN2_1_5B, GEMMA2_2B, MINITRON_4B, QWEN2_VL_7B,
    GROK_1_314B, GRANITE_MOE_1B, RECURRENTGEMMA_2B, WHISPER_TINY,
    FALCON_MAMBA_7B,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants: same family, tiny dimensions.
# ---------------------------------------------------------------------------

def reduced(arch: ArchConfig) -> ArchConfig:
    n_layers = max(2, len(arch.block_pattern))
    nh = 4
    nkv = max(1, min(arch.n_kv_heads, nh * arch.n_kv_heads // arch.n_heads)) \
        if arch.n_heads >= nh else arch.n_kv_heads
    nkv = max(1, nkv)
    if nh % nkv != 0:
        nkv = 1
    return dataclasses.replace(
        arch,
        name=arch.name + "-smoke",
        n_layers=n_layers,
        d_model=128, n_heads=nh, n_kv_heads=nkv, head_dim=32,
        d_ff=0 if arch.d_ff == 0 else 256,
        vocab_size=512,
        local_window=min(arch.local_window, 64),
        n_experts=min(arch.n_experts, 4) if arch.n_experts else 0,
        topk=min(arch.topk, 2) if arch.topk else 0,
        capacity_factor=4.0,     # lossless dispatch at smoke scale
        encoder_layers=min(arch.encoder_layers, 2),
        encoder_seq=64 if arch.encoder_seq else 0,
        lru_width=128 if arch.lru_width else 0,
        max_seq_len=4096,
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchConfig, shape: ShapeConfig
                ) -> Tuple[dict, dict]:
    """Returns (batch of ShapeDtypeStructs, logical-axes tree).

    train:   {tokens, labels [, positions/embeds/enc_embeds]}
    prefill: same minus labels
    decode:  {tokens [B, 1]} (the cache is supplied by the serving layer)
    """
    b = shape.global_batch
    l = shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    d = arch.d_model
    sds = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        batch = {"tokens": sds((b, 1), i32)}
        axes = {"tokens": ("dp", None)}
        if arch.mrope:
            batch["positions"] = sds((b, 1, 3), i32)
            axes["positions"] = ("dp", None, None)
        return batch, axes

    batch, axes = {}, {}
    if arch.family == "vlm":
        batch["embeds"] = sds((b, l, d), bf16)       # stub patch embeddings
        axes["embeds"] = ("dp", None, None)
        batch["positions"] = sds((b, l, 3), i32)     # M-RoPE t/h/w ids
        axes["positions"] = ("dp", None, None)
    elif arch.family == "audio":
        batch["enc_embeds"] = sds((b, arch.encoder_seq, d), bf16)
        axes["enc_embeds"] = ("dp", None, None)
        batch["tokens"] = sds((b, l), i32)
        axes["tokens"] = ("dp", None)
    else:
        batch["tokens"] = sds((b, l), i32)
        axes["tokens"] = ("dp", None)
    if shape.kind == "train":
        batch["labels"] = sds((b, l), i32)
        axes["labels"] = ("dp", None)
    return batch, axes


def cell_is_runnable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The assignment's skip rules for (arch x shape) cells."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("skip: full O(L^2) attention at 524288 tokens "
                       "(assignment rule; see DESIGN.md)")
    return True, ""
