"""qwen2-vl-7b [vlm]: M-RoPE, dynamic resolution.  Backbone only -- the
vision frontend is a STUB per the assignment (input_specs provides
precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    qkv_bias=True, block_pattern=("global",), mlp_act="silu",
    mrope=True, mrope_sections=(16, 24, 24),
    tie_embeddings=False, rope_theta=1_000_000.0,
    frontend="vision_stub",
)
