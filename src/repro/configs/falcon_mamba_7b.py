"""falcon-mamba-7b [ssm]: attention-free mamba1; d_ff=0 (no MLP blocks);
ssm_state=16.  Sub-quadratic: runs long_500k.  [arXiv:2410.05355; unverified]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=65024,
    block_pattern=("mamba",), ssm_state=16, ssm_expand=2, conv_kernel=4,
    tie_embeddings=False, subquadratic=True,
)
