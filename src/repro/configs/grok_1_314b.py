"""grok-1-314b [moe]: 8 experts top-2, attention/final softcaps.
[hf:xai-org/grok-1; unverified]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    block_pattern=("global",), mlp_act="gelu",
    n_experts=8, topk=2,
    attn_softcap=30.0, final_softcap=30.0,
    tie_embeddings=True, emb_scale=True,
)
