"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 2:1 pattern
(two recurrent blocks per local-attention block).  Sub-quadratic: runs
long_500k.  [arXiv:2402.19427; hf]"""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("recurrent", "recurrent", "local"),
    local_window=2048, lru_width=2560, conv_kernel=4,
    mlp_act="gelu", tie_embeddings=True, emb_scale=True,
    subquadratic=True,
)
