"""State-space blocks: mamba1 (falcon-mamba) and RG-LRU (recurrentgemma).

TPU adaptation notes:
  * The selective scan is CHUNKED: a sequential lax.scan over chunks carries
    the state, and a parallel associative_scan runs inside each chunk.  The
    [B, Q, d_inner, d_state] transients exist per chunk only, so 32k-token
    prefills lower with bounded memory while the VPU still sees wide
    parallel work (the GPU kernel's shared-memory tiling has no TPU port --
    this is the TPU-idiomatic equivalent, per DESIGN.md).
  * The temporal depthwise conv in both blocks dispatches to the DWC PE
    (paper C4): depthwise = exactly the computation the paper built a
    dedicated engine for.
  * Decode is the O(1) recurrence step on a carried state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import probe
from repro.core.config import ArchConfig, EngineConfig
from repro.kernels import ops
from repro.models.params import ParamSpec
from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# Chunked diagonal linear recurrence:  h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def _assoc_op(left, right):
    al, bl = left
    ar, br = right
    return ar * al, ar * bl + br


def linear_scan_chunked(a: jax.Array, b: jax.Array, h0: jax.Array,
                        chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """a, b: [B, L, D]; h0: [B, D].  Returns (h_all [B, L, D], h_last)."""
    bsz, l, d = a.shape
    if probe.enabled():
        chunk = 1024                   # bounded op count in unrolled probes
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    at = a.astype(jnp.float32).reshape(bsz, nc, chunk, d).transpose(1, 2, 0, 3)
    bt = b.astype(jnp.float32).reshape(bsz, nc, chunk, d).transpose(1, 2, 0, 3)

    def step(h, ab):
        ac, bc = ab                                   # [chunk, B, D]
        acum, bcum = jax.lax.associative_scan(_assoc_op, (ac, bc), axis=0)
        h_all = acum * h[None] + bcum
        return h_all[-1], h_all

    h_last, ys = probe.pscan(step, h0.astype(jnp.float32), (at, bt))
    ys = ys.transpose(2, 0, 1, 3).reshape(bsz, l, d)
    return ys.astype(a.dtype), h_last


# ---------------------------------------------------------------------------
# Mamba1 block (falcon-mamba)
# ---------------------------------------------------------------------------

def mamba_dt_rank(arch: ArchConfig) -> int:
    return -(-arch.d_model // 16)


def mamba_schema(arch: ArchConfig) -> dict:
    d, di, ds = arch.d_model, arch.d_inner, arch.ssm_state
    dtr, k = mamba_dt_rank(arch), arch.conv_kernel
    return {
        "in_proj": ParamSpec((d, 2 * di), ("fsdp", "tp")),
        "conv_w": ParamSpec((k, di), (None, "tp"), "small"),
        "conv_b": ParamSpec((di,), ("tp",), "zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * ds), ("tp", None)),
        "dt_proj": ParamSpec((dtr, di), (None, "tp")),
        "dt_bias": ParamSpec((di,), ("tp",), "zeros"),
        "a_log": ParamSpec((di, ds), ("tp", None), "small"),
        "d_skip": ParamSpec((di,), ("tp",), "ones"),
        "out_proj": ParamSpec((di, d), ("tp", "fsdp")),
    }


def _mamba_scan(x, dt, bmat, cmat, a_mat, d_skip, h0, chunk=256):
    """x, dt: [B, L, di]; bmat, cmat: [B, L, ds]; a_mat: [di, ds];
    h0: [B, di, ds].  Returns (y [B, L, di], h_last)."""
    bsz, l, di = x.shape
    ds = bmat.shape[-1]
    if probe.enabled():
        chunk = 1024                   # bounded op count in unrolled probes
    chunk = min(chunk, l)
    assert l % chunk == 0
    nc = l // chunk

    def tm(t):  # -> [nc, chunk, B, ...] time-major chunks
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).transpose(
            1, 2, 0, *range(3, t.ndim + 1))

    xs, dts, bs, cs = tm(x), tm(dt), tm(bmat), tm(cmat)

    def step(h, inp):
        xc, dtc, bc, cc = inp
        xf = xc.astype(jnp.float32)
        dtf = dtc.astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * a_mat[None, None])      # [Q,B,di,ds]
        bb = (dtf * xf)[..., None] * bc.astype(jnp.float32)[:, :, None, :]
        acum, bcum = jax.lax.associative_scan(_assoc_op, (a, bb), axis=0)
        h_all = acum * h[None] + bcum
        y = jnp.einsum("qbds,qbs->qbd", h_all, cc.astype(jnp.float32))
        y = y + d_skip[None, None] * xf
        return h_all[-1], y

    h_last, ys = probe.pscan(step, h0.astype(jnp.float32),
                             (xs, dts, bs, cs))
    y = ys.transpose(2, 0, 1, 3).reshape(bsz, l, di)
    return y.astype(x.dtype), h_last


def mamba_apply(p: dict, x: jax.Array, arch: ArchConfig, eng: EngineConfig,
                state: Optional[dict] = None, chunk: int = 256
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence mamba mixer.  x: [B, L, d].  With `state`, also returns
    the updated {conv, ssm} state for decode continuation."""
    b, l, d = x.shape
    di, ds = arch.d_inner, arch.ssm_state
    dtr = mamba_dt_rank(arch)
    xz = ops.linear(x, p["in_proj"], None, "none", eng)
    xs, z = jnp.split(xz, 2, axis=-1)
    # Temporal depthwise conv -> DWC PE (paper C4).
    xs = ops.dwc1d_causal(xs, p["conv_w"], p["conv_b"], "silu", eng)
    proj = ops.linear(xs, p["x_proj"], None, "none", eng,
                      out_dtype=jnp.float32)
    dt_raw, bmat, cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        ops.linear(dt_raw, p["dt_proj"], None, "none", eng,
                   out_dtype=jnp.float32) + p["dt_bias"])
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((b, di, ds), jnp.float32))
    y, h_last = _mamba_scan(xs, dt, bmat, cmat, a_mat,
                            p["d_skip"].astype(jnp.float32), h0, chunk)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = ops.linear(y, p["out_proj"], None, "none", eng)
    if state is None:
        return out, None
    k = arch.conv_kernel
    xz_tail = jnp.split(xz[:, -(k - 1):], 2, axis=-1)[0] if l >= k - 1 else None
    new_state = {"ssm": h_last,
                 "conv": xz_tail if xz_tail is not None else state["conv"]}
    return out, new_state


def mamba_decode(p: dict, x: jax.Array, arch: ArchConfig, eng: EngineConfig,
                 state: dict) -> Tuple[jax.Array, dict]:
    """Single-token step.  x: [B, 1, d]; state: {conv [B,k-1,di], ssm [B,di,ds]}."""
    b = x.shape[0]
    di, ds = arch.d_inner, arch.ssm_state
    dtr = mamba_dt_rank(arch)
    k = arch.conv_kernel
    xz = ops.linear(x, p["in_proj"], None, "none", eng)      # [B,1,2di]
    xs, z = jnp.split(xz, 2, axis=-1)
    # Rolling conv state.
    win = jnp.concatenate([state["conv"], xs], axis=1)       # [B, k, di]
    conv_out = jnp.einsum("bkd,kd->bd", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xs1 = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)  # [B,1,di]
    proj = ops.linear(xs1, p["x_proj"], None, "none", eng,
                      out_dtype=jnp.float32)
    dt_raw, bmat, cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        ops.linear(dt_raw, p["dt_proj"], None, "none", eng,
                   out_dtype=jnp.float32) + p["dt_bias"])    # [B,1,di]
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None] * a_mat[None])
    bb = (dt[:, 0, :, None] * xs1.astype(jnp.float32)[:, 0, :, None]
          * bmat[:, 0, None, :])
    h = a * state["ssm"] + bb                                # [B, di, ds]
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0]) + \
        p["d_skip"].astype(jnp.float32) * xs1.astype(jnp.float32)[:, 0]
    y = y[:, None, :] * jax.nn.silu(z.astype(jnp.float32))
    out = ops.linear(y.astype(x.dtype), p["out_proj"], None, "none", eng)
    return out, {"conv": win[:, 1:], "ssm": h}


def mamba_init_state(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, arch.conv_kernel - 1, arch.d_inner), dtype),
        "ssm": jnp.zeros((batch, arch.d_inner, arch.ssm_state), jnp.float32),
    }


def mamba_state_schema(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": ParamSpec((batch, arch.conv_kernel - 1, arch.d_inner),
                          ("dp", None, "tp"), "zeros", dtype),
        "ssm": ParamSpec((batch, arch.d_inner, arch.ssm_state),
                         ("dp", "tp", None), "zeros", jnp.float32),
    }


# ---------------------------------------------------------------------------
# RG-LRU block (recurrentgemma / Griffin)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0
RGLRU_BLOCKS = 0  # 0 -> use arch.n_heads diagonal blocks


def rglru_schema(arch: ArchConfig) -> dict:
    d, w, k = arch.d_model, arch.lru_width, arch.conv_kernel
    nb = arch.n_heads
    bs = w // nb
    return {
        "in_x": ParamSpec((d, w), ("fsdp", "tp")),
        "in_gate": ParamSpec((d, w), ("fsdp", "tp")),
        "conv_w": ParamSpec((k, w), (None, "tp"), "small"),
        "conv_b": ParamSpec((w,), ("tp",), "zeros"),
        "gate_in_w": ParamSpec((nb, bs, bs), (None, None, None), "small"),
        "gate_in_b": ParamSpec((w,), ("tp",), "zeros"),
        "gate_rec_w": ParamSpec((nb, bs, bs), (None, None, None), "small"),
        "gate_rec_b": ParamSpec((w,), ("tp",), "zeros"),
        "lam": ParamSpec((w,), ("tp",), "small"),
        "out_proj": ParamSpec((w, d), ("tp", "fsdp")),
    }


def _rglru_gates(p, xs, nb):
    b, l, w = xs.shape
    xb = xs.reshape(b, l, nb, w // nb).astype(jnp.float32)
    gi = jnp.einsum("blnh,nhk->blnk", xb, p["gate_in_w"].astype(jnp.float32))
    gr = jnp.einsum("blnh,nhk->blnk", xb, p["gate_rec_w"].astype(jnp.float32))
    i_t = jax.nn.sigmoid(gi.reshape(b, l, w) + p["gate_in_b"])
    r_t = jax.nn.sigmoid(gr.reshape(b, l, w) + p["gate_rec_b"])
    return i_t, r_t


def rglru_apply(p: dict, x: jax.Array, arch: ArchConfig, eng: EngineConfig,
                state: Optional[dict] = None, chunk: int = 256
                ) -> Tuple[jax.Array, Optional[dict]]:
    b, l, d = x.shape
    w, nb = arch.lru_width, arch.n_heads
    xs_pre = ops.linear(x, p["in_x"], None, "none", eng)
    gate = ops.linear(x, p["in_gate"], None, "gelu", eng)
    xs = ops.dwc1d_causal(xs_pre, p["conv_w"], p["conv_b"], "none", eng)
    i_t, r_t = _rglru_gates(p, xs, nb)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_t
    a = jnp.exp(log_a)
    gated_x = i_t * xs.astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    h0 = (state["rec"] if state is not None else jnp.zeros((b, w), jnp.float32))
    h_all, h_last = linear_scan_chunked(a, b_t, h0, chunk)
    y = (h_all.astype(jnp.float32) * gate.astype(jnp.float32)).astype(x.dtype)
    out = ops.linear(y, p["out_proj"], None, "none", eng)
    if state is None:
        return out, None
    k = arch.conv_kernel
    new_state = {"rec": h_last, "conv": xs_pre[:, -(k - 1):]}
    return out, new_state


def rglru_decode(p: dict, x: jax.Array, arch: ArchConfig, eng: EngineConfig,
                 state: dict) -> Tuple[jax.Array, dict]:
    """x: [B, 1, d]; state: {conv [B, k-1, w], rec [B, w]}."""
    b = x.shape[0]
    w, nb, k = arch.lru_width, arch.n_heads, arch.conv_kernel
    xs = ops.linear(x, p["in_x"], None, "none", eng)          # [B,1,w]
    gate = ops.linear(x, p["in_gate"], None, "gelu", eng)
    win = jnp.concatenate([state["conv"], xs], axis=1)        # [B,k,w]
    conv = jnp.einsum("bkw,kw->bw", win.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    i_t, r_t = _rglru_gates(p, conv[:, None, :], nb)
    i_t, r_t = i_t[:, 0], r_t[:, 0]
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_t)
    gx = i_t * conv
    h = a * state["rec"] + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * gx
    y = (h[:, None, :] * gate.astype(jnp.float32)).astype(x.dtype)
    out = ops.linear(y, p["out_proj"], None, "none", eng)
    return out, {"rec": h, "conv": win[:, 1:]}


def rglru_init_state(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, arch.conv_kernel - 1, arch.lru_width), dtype),
        "rec": jnp.zeros((batch, arch.lru_width), jnp.float32),
    }


def rglru_state_schema(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": ParamSpec((batch, arch.conv_kernel - 1, arch.lru_width),
                          ("dp", None, "tp"), "zeros", dtype),
        "rec": ParamSpec((batch, arch.lru_width), ("dp", "tp"), "zeros",
                         jnp.float32),
    }
