"""Whisper-family encoder-decoder backbone.

Per the assignment the audio frontend (mel conv stem) is a STUB: the encoder
consumes precomputed frame embeddings [B, S_enc, d] from input_specs().
Whisper uses LayerNorm + plain GELU MLPs and learned positions (no RoPE);
we keep that so the arch exercises a different normalization/MLP path than
the llama-family configs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, EngineConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.models.params import ParamSpec


def _ln_schema(d: int) -> dict:
    return {"scale": ParamSpec((d,), (None,), "ones"),
            "bias": ParamSpec((d,), (None,), "zeros")}


def _mlp_schema(arch: ArchConfig) -> dict:
    d, ff = arch.d_model, arch.d_ff
    return {"wi": ParamSpec((d, ff), ("fsdp", "tp")),
            "bi": ParamSpec((ff,), ("tp",), "zeros"),
            "wo": ParamSpec((ff, d), ("tp", "fsdp")),
            "bo": ParamSpec((d,), (None,), "zeros")}


def _enc_block_schema(arch: ArchConfig) -> dict:
    return {"ln1": _ln_schema(arch.d_model),
            "attn": L.attention_schema(arch),
            "ln2": _ln_schema(arch.d_model),
            "mlp": _mlp_schema(arch)}


def _dec_block_schema(arch: ArchConfig) -> dict:
    return {"ln1": _ln_schema(arch.d_model),
            "attn": L.attention_schema(arch),
            "ln_x": _ln_schema(arch.d_model),
            "xattn": L.attention_schema(arch),
            "ln2": _ln_schema(arch.d_model),
            "mlp": _mlp_schema(arch)}


def whisper_schema(arch: ArchConfig, max_dec_pos: int = 32768) -> dict:
    d, v = arch.d_model, arch.vocab_size
    return {
        "embed": ParamSpec((v, d), ("tp", None), "embed"),
        "enc_pos": ParamSpec((arch.encoder_seq, d), (None, None), "small"),
        "dec_pos": ParamSpec((max_dec_pos, d), (None, None), "small"),
        "enc_blocks": [_enc_block_schema(arch)
                       for _ in range(arch.encoder_layers)],
        "enc_ln": _ln_schema(d),
        "dec_blocks": [_dec_block_schema(arch)
                       for _ in range(arch.n_layers)],
        "dec_ln": _ln_schema(d),
    }



def _embed(params, tokens, dtype):
    emb = params["embed"]
    if hasattr(emb, "q"):                  # QTensor (quantized serving)
        rows = jnp.take(emb.q, tokens, axis=0).astype(jnp.float32)
        return (rows * jnp.take(emb.scale, tokens, axis=0)).astype(dtype)
    return jnp.take(emb, tokens, axis=0).astype(dtype)


def _logits(params, x):
    emb = params["embed"]
    xf = x.astype(jnp.float32)
    if hasattr(emb, "q"):
        out = jnp.einsum("bld,vd->blv", xf, emb.q.astype(jnp.float32))
        return out * emb.scale.reshape(1, 1, -1)
    return jnp.einsum("bld,vd->blv", xf, emb.astype(jnp.float32))

def _ln(x, p, eps=1e-5):
    return L.layer_norm(x, p["scale"], p["bias"], eps)


def _mlp(p, x, eng):
    h = ops.linear(x, p["wi"], p["bi"], "gelu", eng)
    return ops.linear(h, p["wo"], p["bo"], "none", eng)


def encode(params: dict, enc_embeds: jax.Array, arch: ArchConfig,
           eng: EngineConfig, act_spec=None) -> jax.Array:
    x = enc_embeds + params["enc_pos"][None].astype(enc_embeds.dtype)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    for p in params["enc_blocks"]:
        h = L.attention_apply(p["attn"], _ln(x, p["ln1"]), arch, eng,
                              layer_kind="global", cos=None, sin=None,
                              causal=False)
        x = x + h
        x = x + _mlp(p["mlp"], _ln(x, p["ln2"]), eng)
    return _ln(x, params["enc_ln"])


def dec_forward(params: dict, enc_out: jax.Array, tokens: jax.Array,
                arch: ArchConfig, eng: EngineConfig,
                act_spec=None) -> jax.Array:
    """Teacher-forced decoder.  Returns logits [B, L, V]."""
    b, l = tokens.shape
    x = _embed(params, tokens, enc_out.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], 0, l, axis=0)[None].astype(x.dtype)
    for p in params["dec_blocks"]:
        h = L.attention_apply(p["attn"], _ln(x, p["ln1"]), arch, eng,
                              layer_kind="global", cos=None, sin=None,
                              causal=True)
        x = x + h
        # Cross-attention: KV from the encoder output, not causal.
        hin = _ln(x, p["ln_x"])
        kx, vx = L.attention_kv(p["xattn"], enc_out, arch, eng, None, None)
        h = L.attention_apply(p["xattn"], hin, arch, eng, layer_kind="global",
                              cos=None, sin=None, causal=False,
                              kv_override=(kx, vx))
        x = x + h
        x = x + _mlp(p["mlp"], _ln(x, p["ln2"]), eng)
    x = _ln(x, params["dec_ln"])
    return _logits(params, x)


def forward(params: dict, batch: dict, arch: ArchConfig, eng: EngineConfig,
            *, act_spec=None, remat: str = "none",
            compute_dtype=jnp.bfloat16, **_) -> Tuple[jax.Array, jax.Array]:
    enc = encode(params, batch["enc_embeds"].astype(compute_dtype), arch,
                 eng, act_spec)
    logits = dec_forward(params, enc, batch["tokens"], arch, eng, act_spec)
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def whisper_cache_schema(arch: ArchConfig, batch: int, max_seq: int,
                         eng: EngineConfig) -> dict:
    kv_dt = jnp.bfloat16
    nkv, hd = arch.n_kv_heads, arch.head_dim
    kv = lambda s: {
        "k": ParamSpec((batch, s, nkv, hd), ("dp", "tp"), "zeros", kv_dt),
        "v": ParamSpec((batch, s, nkv, hd), ("dp", "tp"), "zeros", kv_dt),
    }
    return {
        "self": [kv(max_seq) for _ in range(arch.n_layers)],
        "cross": [kv(arch.encoder_seq) for _ in range(arch.n_layers)],
        "pos": ParamSpec((), (), "zeros", jnp.int32),
    }


def prefill(params: dict, cache: dict, batch: dict, arch: ArchConfig,
            eng: EngineConfig, *, act_spec=None,
            compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, dict]:
    """Encode audio stub + consume decoder prompt; fill self+cross caches."""
    enc = encode(params, batch["enc_embeds"].astype(compute_dtype), arch,
                 eng, act_spec)
    tokens = batch["tokens"]
    b, l = tokens.shape
    x = _embed(params, tokens, compute_dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], 0, l, axis=0)[None].astype(x.dtype)
    new_self, new_cross = [], []
    for i, p in enumerate(params["dec_blocks"]):
        hin = _ln(x, p["ln1"])
        k, v = L.attention_kv(p["attn"], hin, arch, eng, None, None)
        h = L.attention_apply(p["attn"], hin, arch, eng, layer_kind="global",
                              cos=None, sin=None, causal=True,
                              kv_override=(k, v))
        x = x + h
        ent = dict(cache["self"][i])
        ent["k"] = jax.lax.dynamic_update_slice_in_dim(
            ent["k"], k.astype(ent["k"].dtype), 0, axis=1)
        ent["v"] = jax.lax.dynamic_update_slice_in_dim(
            ent["v"], v.astype(ent["v"].dtype), 0, axis=1)
        new_self.append(ent)
        kx, vx = L.attention_kv(p["xattn"], enc, arch, eng, None, None)
        new_cross.append({"k": kx.astype(compute_dtype),
                          "v": vx.astype(compute_dtype)})
        h = L.attention_apply(p["xattn"], _ln(x, p["ln_x"]), arch, eng,
                              layer_kind="global", cos=None, sin=None,
                              causal=False, kv_override=(kx, vx))
        x = x + h
        x = x + _mlp(p["mlp"], _ln(x, p["ln2"]), eng)
    x = _ln(x, params["dec_ln"])
    logits = _logits(params, x[:, -1:])
    return logits, {"self": new_self, "cross": new_cross,
                    "pos": jnp.asarray(l, jnp.int32)}


def decode(params: dict, cache: dict, tokens: jax.Array, arch: ArchConfig,
           eng: EngineConfig, *, act_spec=None,
           compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, dict]:
    pos = cache["pos"]
    b = tokens.shape[0]
    x = _embed(params, tokens, compute_dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], pos, 1, axis=0)[None].astype(x.dtype)
    new_self = []
    for i, p in enumerate(params["dec_blocks"]):
        hin = _ln(x, p["ln1"])
        k, v = L.attention_kv(p["attn"], hin, arch, eng, None, None)
        ent = dict(cache["self"][i])
        ent["k"] = jax.lax.dynamic_update_slice_in_dim(
            ent["k"], k.astype(ent["k"].dtype), pos, axis=1)
        ent["v"] = jax.lax.dynamic_update_slice_in_dim(
            ent["v"], v.astype(ent["v"].dtype), pos, axis=1)
        new_self.append(ent)
        h = L.attention_decode(p["attn"], hin, arch, eng, layer_kind="global",
                               k_cache=ent["k"], v_cache=ent["v"],
                               length=pos + 1, cos=None, sin=None)
        x = x + h
        xc = cache["cross"][i]
        h = L.attention_decode(p["xattn"], _ln(x, p["ln_x"]), arch, eng,
                               layer_kind="global", k_cache=xc["k"],
                               v_cache=xc["v"],
                               length=jnp.asarray(arch.encoder_seq, jnp.int32),
                               cos=None, sin=None)
        x = x + h
        x = x + _mlp(p["mlp"], _ln(x, p["ln2"]), eng)
    x = _ln(x, params["dec_ln"])
    logits = _logits(params, x)
    return logits, {"self": new_self, "cross": cache["cross"],
                    "pos": pos + 1}
