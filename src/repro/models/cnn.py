"""CNN zoo -- the paper's own evaluation models, running on the DPUV4E engine.

Every model lowers through the compiler (repro.compiler) to an engine
op-graph whose nodes dispatch to the engine API:
  * stage-0 stem      -> ops.first_layer_conv (Low-Channel Conv Unit, C5)
  * standard convs    -> ops.conv2d_pe        (Conv PE im2col GEMM, C2/C3)
  * depthwise convs   -> ops.dwc2d            (DWC PE, C4)
  * residual adds     -> ops.misc_add         (MISC core, C6)
  * pooling           -> ops.avgpool2d / ref.maxpool2d

Stage kinds (CNNConfig.stages):
  conv        -- plain conv(k, s) x repeat
  bottleneck  -- ResNet bottleneck (1x1 red, 3x3, 1x1 x4) x repeat
  inverted    -- MobileNetV2/EfficientNet MBConv (expand, dwc, project)
  dwsep       -- MobileNetV1 depthwise-separable (dwc + 1x1)
  fire        -- SqueezeNet fire module (squeeze 1x1, expand 1x1 + 3x3)
  pool        -- max pool
"""
from __future__ import annotations

import jax

from repro.core.config import CNNConfig, EngineConfig
from repro.models.params import ParamSpec


def _conv_spec(k: int, ic: int, oc: int) -> ParamSpec:
    return ParamSpec((k, k, ic, oc), (None, None, None, "tp"), "he")


def _bias_spec(oc: int) -> ParamSpec:
    return ParamSpec((oc,), (None,), "zeros")


def _dwc_spec(k: int, c: int) -> ParamSpec:
    # depthwise taps: fan-in k*k per channel -> He over the window
    return ParamSpec((k, k, c), (None, None, "tp"), "he")


def cnn_schema(cfg: CNNConfig) -> dict:
    s = {"stem_w": ParamSpec((cfg.stem_kernel, cfg.stem_kernel,
                              cfg.input_ch, cfg.stem_ch),
                             (None, None, None, None), "he"),
         "stem_b": _bias_spec(cfg.stem_ch),
         "stages": []}
    ch = cfg.stem_ch
    for st in cfg.stages:
        blocks = []
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            if st.kind == "conv":
                blocks.append({"w": _conv_spec(st.kernel, ch, st.out_ch),
                               "b": _bias_spec(st.out_ch)})
                ch = st.out_ch
            elif st.kind == "bottleneck":
                mid = st.out_ch // 4
                blk = {"w1": _conv_spec(1, ch, mid), "b1": _bias_spec(mid),
                       "w2": _conv_spec(st.kernel, mid, mid),
                       "b2": _bias_spec(mid),
                       "w3": _conv_spec(1, mid, st.out_ch),
                       "b3": _bias_spec(st.out_ch)}
                if ch != st.out_ch or stride != 1:
                    blk["wskip"] = _conv_spec(1, ch, st.out_ch)
                    blk["bskip"] = _bias_spec(st.out_ch)
                blocks.append(blk)
                ch = st.out_ch
            elif st.kind == "inverted":
                mid = ch * st.expand
                blk = {"we": _conv_spec(1, ch, mid), "be": _bias_spec(mid),
                       "wd": _dwc_spec(st.kernel, mid), "bd": _bias_spec(mid),
                       "wp": _conv_spec(1, mid, st.out_ch),
                       "bp": _bias_spec(st.out_ch)}
                blocks.append(blk)
                ch = st.out_ch
            elif st.kind == "dwsep":
                blk = {"wd": _dwc_spec(st.kernel, ch), "bd": _bias_spec(ch),
                       "wp": _conv_spec(1, ch, st.out_ch),
                       "bp": _bias_spec(st.out_ch)}
                blocks.append(blk)
                ch = st.out_ch
            elif st.kind == "fire":
                sq = st.out_ch // 8
                blk = {"ws": _conv_spec(1, ch, sq), "bs": _bias_spec(sq),
                       "w1": _conv_spec(1, sq, st.out_ch // 2),
                       "b1": _bias_spec(st.out_ch // 2),
                       "w3": _conv_spec(3, sq, st.out_ch // 2),
                       "b3": _bias_spec(st.out_ch // 2)}
                blocks.append(blk)
                ch = st.out_ch
            elif st.kind == "pool":
                blocks.append({})
            else:
                raise ValueError(st.kind)
        s["stages"].append(blocks)
    s["head_w"] = ParamSpec((ch, cfg.num_classes), (None, "tp"))
    s["head_b"] = _bias_spec(cfg.num_classes)
    return s


def cnn_forward(params: dict, images: jax.Array, cfg: CNNConfig,
                eng: EngineConfig) -> jax.Array:
    """images: [N, H, W, C] float in [-1, 1].  Returns logits [N, classes].

    Thin compile-and-execute wrapper: the CNN lowers to the compiler's
    op-graph IR (epilogue-fused by default: conv->add->pool chains execute
    as single launches) and runs through the dynamic engine program,
    value-identical to the historical eager path (training and the
    existing tests see no difference).  The compiled program comes out of the shared
    bounded program cache (compiler.program_cache()) and carries the
    concurrent-PE level schedule, so repeat calls never re-lower.  For the
    paper's calibrated static-int8 dataflow, compile once with
    repro.compiler.compile_calibrated and execute that program instead --
    or serve many models at once through serve.cnn_engine.CNNServeEngine,
    which keys full (model, engine, calibration) programs in its own cache
    and batches requests into fixed-size waves.
    """
    from repro import compiler
    program = compiler.compile_cnn(cfg)          # program-cache hit after 1st
    return compiler.execute(program, params, images, eng)


def cnn_flops(cfg: CNNConfig, params: dict) -> float:
    """Analytic MAC*2 count per image (for modeled-FPS benchmarks)."""
    import numpy as np

    total = 0.0
    hw = cfg.input_hw
    k, s = cfg.stem_kernel, cfg.stem_stride
    hw = -(-hw // s)
    total += 2 * k * k * cfg.input_ch * cfg.stem_ch * hw * hw
    ch = cfg.stem_ch
    for st in cfg.stages:
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            if st.kind == "pool":
                stride = 1                  # pool handled below
            hw_out = -(-hw // stride)
            px = hw_out * hw_out
            if st.kind == "conv":
                total += 2 * st.kernel ** 2 * ch * st.out_ch * px
                ch = st.out_ch
            elif st.kind == "bottleneck":
                mid = st.out_ch // 4
                total += 2 * px * (ch * mid + st.kernel ** 2 * mid * mid
                                   + mid * st.out_ch)
                if ch != st.out_ch or stride != 1:
                    total += 2 * px * ch * st.out_ch
                ch = st.out_ch
            elif st.kind == "inverted":
                mid = ch * st.expand
                total += 2 * px * (ch * mid + st.kernel ** 2 * mid
                                   + mid * st.out_ch)
                ch = st.out_ch
            elif st.kind == "dwsep":
                total += 2 * px * (st.kernel ** 2 * ch + ch * st.out_ch)
                ch = st.out_ch
            elif st.kind == "fire":
                sq = st.out_ch // 8
                total += 2 * px * (ch * sq + sq * st.out_ch // 2
                                   + 9 * sq * st.out_ch // 2)
                ch = st.out_ch
            hw = hw_out
            if st.kind == "pool":
                hw = -(-hw // st.stride)
    total += 2 * ch * cfg.num_classes
    return total


def dwc_op_fraction(cfg: CNNConfig) -> float:
    """Fraction of conv MACs that are depthwise (drives Table III ratios)."""
    hw = cfg.input_hw
    hw = -(-hw // cfg.stem_stride)
    ch = cfg.stem_ch
    dwc, total = 0.0, 0.0
    for st in cfg.stages:
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            if st.kind == "pool":
                stride = 1                  # pool handled below
            hw_out = -(-hw // stride)
            px = hw_out * hw_out
            if st.kind == "inverted":
                mid = ch * st.expand
                d = st.kernel ** 2 * mid * px
                t = px * (ch * mid + mid * st.out_ch) + d
                dwc += d
                total += t
                ch = st.out_ch
            elif st.kind == "dwsep":
                d = st.kernel ** 2 * ch * px
                dwc += d
                total += d + px * ch * st.out_ch
                ch = st.out_ch
            elif st.kind == "conv":
                total += st.kernel ** 2 * ch * st.out_ch * px
                ch = st.out_ch
            elif st.kind == "bottleneck":
                mid = st.out_ch // 4
                total += px * (ch * mid + st.kernel ** 2 * mid * mid
                               + mid * st.out_ch)
                ch = st.out_ch
            elif st.kind == "fire":
                sq = st.out_ch // 8
                total += px * (ch * sq + sq * st.out_ch // 2
                               + 9 * sq * st.out_ch // 2)
                ch = st.out_ch
            hw = hw_out
            if st.kind == "pool":
                hw = -(-hw // st.stride)
    return dwc / max(total, 1.0)
