"""Transformer building blocks, engine-integrated.

All projections route through kernels/ops.linear so the DPUV4E Conv PE
(int8 GEMM + fused NL epilogue) serves every QKV/O/MLP/MoE matmul when the
engine is in a quantized mode; the float path is used for training.

Attention is a chunked online-softmax ("flash") implementation in pure JAX:
memory is O(block) regardless of sequence length, which is what lets the
32k-prefill cells lower.  GQA is computed in grouped form (no KV head
materialized repetition).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import probe
from repro.core.config import ArchConfig, EngineConfig
from repro.kernels import ops
from repro.models.params import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                mrope_sections: Optional[Tuple[int, int, int]] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """positions: [B, L] (plain) or [B, L, 3] (M-RoPE: t/h/w components).

    Returns cos, sin of shape [B, L, head_dim].
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 3:
        # qwen2-vl M-RoPE: frequency index i uses the position component
        # chosen by its section (temporal / height / width).
        s0, s1, _ = mrope_sections
        comp = jnp.where(jnp.arange(half) < s0, 0,
                         jnp.where(jnp.arange(half) < s0 + s1, 1, 2))
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(comp[None, None, :],
                             positions.shape[:2] + (half,)), axis=-1)
        ang = pos * inv_freq[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv_freq[None, None, :]
    ang = jnp.concatenate([ang, ang], axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def _rotate_half(x):
    h = x.shape[-1] // 2
    return jnp.concatenate([-x[..., h:], x[..., :h]], axis=-1)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, L, ..., head_dim]; cos/sin: [B, L, head_dim]."""
    while cos.ndim < x.ndim:
        cos = cos[:, :, None]
        sin = sin[:, :, None]
    xf = x.astype(jnp.float32)
    return (xf * cos + _rotate_half(xf) * sin).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (train / prefill)
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0,
                    scale: Optional[float] = None,
                    q_offset: int = 0,
                    block_q: int = 512, block_kv: int = 1024,
                    triangle_skip: bool = False) -> jax.Array:
    """Online-softmax attention.

    q: [B, L, Hkv, G, D]   (G = query heads per KV head)
    k, v: [B, S, Hkv, D]
    q_offset: absolute position of q[0] (prefill continuation / enc-dec = 0).
    window > 0: local attention (kv within `window` of the query).
    triangle_skip: skip fully-masked KV blocks via a dynamic inner loop
      (exact-triangle FLOPs; the default full-rectangle scan is the
      paper-faithful baseline the §Perf log iterates on).
    """
    b, l, hkv, g, d = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    if probe.enabled():
        # probe programs fully unroll the block loops; keep the op count
        # bounded with coarser tiles (same math, same flop totals)
        block_q = max(block_q, 2048)
        block_kv = max(block_kv, 2048)
    bq = min(block_q, _round_up(l, 128))
    bkv = min(block_kv, _round_up(s, 128))
    lp, sp = _round_up(l, bq), _round_up(s, bkv)
    qp = jnp.pad(q, ((0, 0), (0, lp - l), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    nq, nkv = lp // bq, sp // bkv

    def q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qp, qi * bq, bq, axis=1)
        qpos = qi * bq + jnp.arange(bq) + q_offset

        if window > 0:
            # Local attention: slice a static-size KV window (linear flops).
            wsize = min(sp, _round_up(window + bq, bkv))
            start = jnp.clip(qi * bq + q_offset - (window - 1), 0, sp - wsize)
            kw = jax.lax.dynamic_slice_in_dim(kp, start, wsize, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(vp, start, wsize, axis=1)
            kpos0 = start
            nb = wsize // bkv
        else:
            kw, vw, kpos0, nb = kp, vp, 0, nkv

        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)

        def kv_step(carry, ki):
            m, lsum, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kw, ki * bkv, bkv, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vw, ki * bkv, bkv, axis=1)
            st = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * scale
            if logit_softcap > 0:
                st = logit_softcap * jnp.tanh(st / logit_softcap)
            kpos = kpos0 + ki * bkv + jnp.arange(bkv)
            mask = (kpos[None, :] < s)                # valid (unpadded) keys
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            st = jnp.where(mask[None, None, None], st, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(st, axis=-1))
            p = jnp.exp(st - m2[..., None])
            alpha = jnp.exp(m - m2)
            l2 = lsum * alpha + jnp.sum(p, axis=-1)
            acc2 = (acc * alpha[..., None]
                    + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                 vb.astype(jnp.float32)))
            return (m2, l2, acc2), None

        if triangle_skip and causal and window == 0:
            # Static per-block bound: only KV blocks intersecting the
            # triangle.  qi is a python int here (the q loop unrolls when
            # triangle_skip is on), so the bound is static and the loop is
            # reverse-mode differentiable (a dynamic fori_loop is not).
            hi = (qi * bq + bq + q_offset + bkv - 1) // bkv
            carry = (m0, l0, a0)
            for ki in range(min(int(hi), nb)):
                carry, _ = kv_step(carry, ki)
            m2, l2, acc = carry
        else:
            (m2, l2, acc), _ = probe.pscan(kv_step, (m0, l0, a0),
                                           jnp.arange(nb))
        lsafe = jnp.where(l2 == 0, 1.0, l2)
        out = acc / lsafe[..., None]
        return out.transpose(0, 3, 1, 2, 4)          # [B, bq, Hkv, G, D]

    if triangle_skip and causal and window == 0:
        # unrolled q loop (python ints -> static triangle bounds)
        out = jnp.stack([q_block(i) for i in range(nq)])
    else:
        out = probe.pmap_blocks(q_block, nq)         # [nq, B, bq, ...]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, lp, hkv, g, d)
    return out[:, :l].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, window: int = 0,
                     logit_softcap: float = 0.0,
                     scale: Optional[float] = None,
                     ring: bool = False) -> jax.Array:
    """Single-token attention against a cache.

    q: [B, 1, Hkv, G, D];  k_cache/v_cache: [B, S, Hkv, D];
    length: [] int32 -- number of valid cache entries (including this token)
      -- or [B] int32 with one length per batch slot (the continuous-batching
      serve path, where refilled slots sit at different sequence positions).
    ring: cache is a ring buffer of size `window` (local layers).

    Under a seq-sharded cache spec ([.., 'model', ..]), GSPMD lowers the
    reductions below to the flash-decode partial-softmax combine (partial
    max/sum + small all-reduces) automatically.
    """
    b, _, hkv, g, d = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else d ** -0.5
    st = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) * scale
    if logit_softcap > 0:
        st = logit_softcap * jnp.tanh(st / logit_softcap)
    kpos = jnp.arange(s)
    lb = jnp.asarray(length)
    lb = lb[None] if lb.ndim == 0 else lb              # [1] or [B]
    if ring:
        valid = kpos[None, :] < jnp.minimum(lb, s)[:, None]
    else:
        valid = kpos[None, :] < lb[:, None]
        if window > 0:
            valid = valid & (kpos[None, :] > (lb - 1 - window)[:, None])
    st = jnp.where(valid[:, None, None, None, :], st, NEG_INF)
    m = jnp.max(st, axis=-1, keepdims=True)
    p = jnp.exp(st - m)
    lsum = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p / lsum,
                     v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (schema + apply)
# ---------------------------------------------------------------------------

def attention_schema(arch: ArchConfig) -> dict:
    d, hd = arch.d_model, arch.head_dim
    nh, nkv = arch.n_heads, arch.n_kv_heads
    s = {
        "wq": ParamSpec((d, nh * hd), ("fsdp", "tp")),
        "wk": ParamSpec((d, nkv * hd), ("fsdp", "tp")),
        "wv": ParamSpec((d, nkv * hd), ("fsdp", "tp")),
        "wo": ParamSpec((nh * hd, d), ("tp", "fsdp")),
    }
    if arch.qkv_bias:
        s["bq"] = ParamSpec((nh * hd,), ("tp",), "zeros")
        s["bk"] = ParamSpec((nkv * hd,), ("tp",), "zeros")
        s["bv"] = ParamSpec((nkv * hd,), ("tp",), "zeros")
    return s


def attention_apply(p: dict, x: jax.Array, arch: ArchConfig,
                    eng: EngineConfig, *, layer_kind: str,
                    cos: jax.Array, sin: jax.Array,
                    q_offset: int = 0,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    causal: bool = True,
                    triangle_skip: bool = False) -> jax.Array:
    """Full-sequence attention (train / prefill).  Returns [B, L, d]."""
    b, l, _ = x.shape
    nh, nkv, hd = arch.n_heads, arch.n_kv_heads, arch.head_dim
    g = nh // nkv
    q = ops.linear(x, p["wq"], p.get("bq"), "none", eng)
    q = q.reshape(b, l, nkv, g, hd)
    if kv_override is None:
        k = ops.linear(x, p["wk"], p.get("bk"), "none", eng).reshape(b, l, nkv, hd)
        v = ops.linear(x, p["wv"], p.get("bv"), "none", eng).reshape(b, l, nkv, hd)
        if cos is not None:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        k, v = kv_override
        if cos is not None:
            q = apply_rope(q, cos, sin)
    window = arch.local_window if layer_kind == "local" else 0
    out = flash_attention(
        q, k, v, causal=causal, window=window,
        logit_softcap=arch.attn_softcap, q_offset=q_offset,
        triangle_skip=triangle_skip)
    out = out.reshape(b, l, nh * hd)
    return ops.linear(out, p["wo"], None, "none", eng)


def attention_kv(p: dict, x: jax.Array, arch: ArchConfig, eng: EngineConfig,
                 cos, sin) -> Tuple[jax.Array, jax.Array]:
    """Project K/V (for cache fill / cross-attention precompute)."""
    b, l, _ = x.shape
    nkv, hd = arch.n_kv_heads, arch.head_dim
    k = ops.linear(x, p["wk"], p.get("bk"), "none", eng).reshape(b, l, nkv, hd)
    v = ops.linear(x, p["wv"], p.get("bv"), "none", eng).reshape(b, l, nkv, hd)
    if cos is not None:
        k = apply_rope(k, cos, sin)
    return k, v


def attention_decode(p: dict, x: jax.Array, arch: ArchConfig,
                     eng: EngineConfig, *, layer_kind: str,
                     k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, cos, sin,
                     ring: bool = False) -> jax.Array:
    b = x.shape[0]
    nh, nkv, hd = arch.n_heads, arch.n_kv_heads, arch.head_dim
    g = nh // nkv
    q = ops.linear(x, p["wq"], p.get("bq"), "none", eng).reshape(b, 1, nkv, g, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
    window = arch.local_window if layer_kind == "local" else 0
    out = decode_attention(q, k_cache, v_cache, length, window=window,
                           logit_softcap=arch.attn_softcap, ring=ring)
    out = out.reshape(b, 1, nh * hd)
    return ops.linear(out, p["wo"], None, "none", eng)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_schema(arch: ArchConfig) -> dict:
    d, ff = arch.d_model, arch.d_ff
    s = {
        "wu": ParamSpec((d, ff), ("fsdp", "tp")),
        "wd": ParamSpec((ff, d), ("tp", "fsdp")),
    }
    if arch.mlp_gated:
        s["wg"] = ParamSpec((d, ff), ("fsdp", "tp"))
    return s


def mlp_apply(p: dict, x: jax.Array, arch: ArchConfig,
              eng: EngineConfig) -> jax.Array:
    # The activation rides the Conv PE's fused NL epilogue (paper C2).
    if arch.mlp_gated:
        gate = ops.linear(x, p["wg"], None, arch.mlp_act, eng)
        up = ops.linear(x, p["wu"], None, "none", eng)
        h = (gate * up).astype(x.dtype)
    else:
        h = ops.linear(x, p["wu"], None, arch.mlp_act, eng).astype(x.dtype)
    return ops.linear(h, p["wd"], None, "none", eng)


# ---------------------------------------------------------------------------
# MoE (top-k routing, sort-based capacity dispatch)
# ---------------------------------------------------------------------------

def moe_schema(arch: ArchConfig) -> dict:
    d, ff, e = arch.d_model, arch.d_ff, arch.n_experts
    return {
        "router": ParamSpec((d, e), (None, None), "small"),
        "wg": ParamSpec((e, d, ff), (None, "fsdp", "tp")),
        "wu": ParamSpec((e, d, ff), (None, "fsdp", "tp")),
        "wd": ParamSpec((e, ff, d), (None, "tp", "fsdp")),
    }


def moe_apply(p: dict, x: jax.Array, arch: ArchConfig,
              eng: EngineConfig, act_spec=None
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (out [B, L, d], aux_loss []).

    With eng.moe_local_groups = dp, routing runs vmapped over a leading
    group axis explicitly CONSTRAINED to the data sharding: the argsort /
    rank / scatter machinery becomes shard-local and emits no collectives
    (the global-dispatch baseline gathers routing state across dp every
    layer -- measured as the dominant collective on grok-1 train, §Perf).
    Without the constraint GSPMD replicates the vmapped routing (measured:
    collective 3.2x WORSE), so the constraint is load-bearing."""
    b, l, d = x.shape
    g = eng.moe_local_groups
    if g > 1 and b % g == 0:
        xg = x.reshape(g, (b // g) * l, d)
        if act_spec is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            dp = act_spec.spec[0] if len(act_spec.spec) else None
            gspec = NamedSharding(act_spec.mesh, PartitionSpec(dp))
            xg = jax.lax.with_sharding_constraint(xg, gspec)
        out, aux = jax.vmap(
            lambda xx: _moe_tokens(p, xx, arch, eng))(xg)
        if act_spec is not None:
            out = jax.lax.with_sharding_constraint(out, gspec)
        return out.reshape(b, l, d), jnp.mean(aux)
    out, aux = _moe_tokens(p, x.reshape(b * l, d), arch, eng)
    return out.reshape(b, l, d), aux


def _moe_tokens(p: dict, xt: jax.Array, arch: ArchConfig,
                eng: EngineConfig) -> Tuple[jax.Array, jax.Array]:
    """Token-level MoE: xt [T, d] -> (out [T, d], aux [])."""
    t, d = xt.shape
    e, k = arch.n_experts, arch.topk
    logits = ops.linear(xt, p["router"], None, "none", eng,
                        out_dtype=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    gate_k, idx_k = jax.lax.top_k(gates, k)                     # [T, k]
    gate_k = gate_k / jnp.sum(gate_k, axis=-1, keepdims=True)

    # Load-balance auxiliary loss (Switch-style).
    density = jnp.mean(jax.nn.one_hot(idx_k[:, 0], e), axis=0)
    density_prob = jnp.mean(gates, axis=0)
    aux = jnp.sum(density * density_prob) * e

    # --- capacity dispatch (no [T, E, C] tensor) ---------------------------
    cap = int(math.ceil(t * k / e * arch.capacity_factor))
    flat_e = idx_k.reshape(-1)                                  # [T*k]
    if eng.moe_local_groups > 1:
        # cumsum-based rank (Switch-style): no sort op.  GSPMD replicates
        # sorts across the mesh (measured: 3x collective blowup), while a
        # cumsum along the local token axis partitions cleanly -- this is
        # the variant the local-dispatch path uses.
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # [T*k, E]
        rank = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)
        tok_of = jnp.arange(t * k) // k
        e_slot = flat_e
    else:
        order = jnp.argsort(flat_e, stable=True)
        tok_of = order // k                                     # source token
        e_slot = flat_e[order]
        # Rank within expert: position in sorted segment.
        counts = jnp.bincount(flat_e, length=e)
        seg_start = jnp.cumsum(counts) - counts
        rank = jnp.arange(t * k) - seg_start[e_slot]
    keep = rank < cap
    slot = jnp.where(keep, e_slot * cap + rank, e * cap)        # overflow slot
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt[tok_of])
    hb = buf[:e * cap].reshape(e, cap, d)

    # --- expert FFN (batched Conv PE GEMMs) --------------------------------
    def expert_mm(h, w):
        if hasattr(w, "q"):      # QTensor: per-expert quantized matmul
            from repro.core.quant import QTensor
            scale = w.scale.reshape(1, -1)
            outs = [ops.linear(h[i], QTensor(w.q[i], scale), None,
                               "none", eng) for i in range(e)]
            return jnp.stack(outs)
        return jnp.einsum("ecd,edf->ecf", h, w.astype(h.dtype))

    gate_h = expert_mm(hb, p["wg"])
    import repro.kernels.ref as _ref
    gate_h = _ref.act_fn(arch.mlp_act)(gate_h)
    up_h = expert_mm(hb, p["wu"])
    out_b = expert_mm((gate_h * up_h).astype(xt.dtype), p["wd"])  # [E, C, d]

    # --- combine ------------------------------------------------------------
    out_flat = jnp.concatenate(
        [out_b.reshape(e * cap, d), jnp.zeros((1, d), out_b.dtype)])
    gathered = out_flat[slot]                                    # [T*k, d]
    if eng.moe_local_groups > 1:
        w_of = gate_k.reshape(-1)                # token-major, matches slot
    else:
        w_of = gate_k.reshape(-1)[order]
    contrib = gathered * w_of[:, None].astype(gathered.dtype)
    out = jnp.zeros((t, d), xt.dtype).at[tok_of].add(
        jnp.where(keep[:, None], contrib, 0).astype(xt.dtype))
    return out, aux
