"""Declarative parameter schemas.

A model declares a nested dict of `ParamSpec`s (shape, logical sharding axes,
init kind).  From one schema we derive:

  * init_params()      -- materialized pytree (PRNG init) for real runs,
  * abstract_params()  -- ShapeDtypeStruct pytree for the dry-run (no alloc),
  * pspec_tree()       -- PartitionSpec pytree resolved against a mesh.

Logical axes used in schemas:
  "tp"    -> the `model` mesh axis (tensor parallel)
  "fsdp"  -> the `data` mesh axis (parameter/optimizer-state sharding)
  "dp"    -> batch: ("pod", "data") on the multi-pod mesh, "data" otherwise
  None    -> replicated

Resolution silently falls back to replication when a dimension is not
divisible by the mesh-axis size (e.g. 8 kv heads on a 16-way model axis).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple = ()                 # logical axis per dim (None = replicated)
    init: str = "normal"             # normal | zeros | ones | embed | small
    dtype: object = jnp.float32

    def __post_init__(self):
        if len(self.axes) < len(self.shape):
            object.__setattr__(
                self, "axes",
                tuple(self.axes) + (None,) * (len(self.shape) - len(self.axes)))


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_map(fn, schema):
    """Map over ParamSpec leaves; pass non-spec leaves through unchanged."""
    return jax.tree_util.tree_map(lambda s: fn(s) if is_spec(s) else s,
                                  schema, is_leaf=is_spec)


def logical_axis_to_mesh(mesh: Mesh, logical):
    if logical is None:
        return None
    names = mesh.axis_names
    if logical == "tp":
        return "model" if "model" in names else None
    if logical == "fsdp":
        return "data" if "data" in names else None
    if logical == "dp":
        if "pod" in names and "data" in names:
            return ("pod", "data")
        return "data" if "data" in names else None
    raise ValueError(f"unknown logical axis {logical!r}")


def _axis_size(mesh: Mesh, mesh_axis) -> int:
    if mesh_axis is None:
        return 1
    if isinstance(mesh_axis, tuple):
        return int(np.prod([mesh.shape[a] for a in mesh_axis]))
    return mesh.shape[mesh_axis]


def resolve_pspec(mesh: Mesh, shape: Sequence[int], axes: Sequence) -> P:
    """Logical axes -> PartitionSpec, dropping non-divisible shardings."""
    out = []
    used = set()
    for dim, logical in zip(shape, axes):
        mesh_axis = logical_axis_to_mesh(mesh, logical)
        if mesh_axis is None or dim % _axis_size(mesh, mesh_axis) != 0:
            out.append(None)
            continue
        key = tuple(mesh_axis) if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if used & set(key):          # a mesh axis may appear only once
            out.append(None)
            continue
        used.update(key)
        out.append(mesh_axis)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def pspec_tree(schema, mesh: Mesh):
    return _leaf_map(lambda s: resolve_pspec(mesh, s.shape, s.axes), schema)


def sharding_tree(schema, mesh: Mesh):
    return _leaf_map(
        lambda s: NamedSharding(mesh, resolve_pspec(mesh, s.shape, s.axes)),
        schema)


def abstract_params(schema, dtype=None):
    return _leaf_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), schema)


def _init_leaf(key, s: ParamSpec, dtype):
    dt = dtype or s.dtype
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    # fan-in = everything but the output dim (conv [k,k,ic,oc] -> k*k*ic).
    fan_in = (int(np.prod(s.shape[:-1])) if len(s.shape) >= 2
              else max(s.shape[-1], 1))
    if s.init == "embed":
        std = 0.02
    elif s.init == "small":
        std = 0.02
    elif s.init == "he":               # relu networks (CNN zoo)
        std = math.sqrt(2.0 / fan_in)
    else:
        std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dt)


def init_params(schema, rng_key, dtype=None):
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(rng_key, max(len(leaves), 1))
    vals = [_init_leaf(k, s, dtype) if is_spec(s) else s
            for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def param_bytes(schema, dtype_bytes: int = 4) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(schema, is_leaf=is_spec):
        if is_spec(leaf):
            total += int(np.prod(leaf.shape)) * dtype_bytes
    return total


def param_count(schema) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(schema, is_leaf=is_spec)
               if is_spec(l))
