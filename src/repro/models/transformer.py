"""Decoder-only LM assembled from block specs (all 10 assigned arch families
except whisper, which composes this with an encoder in whisper.py).

Three entry points:
  forward()  -- full-sequence logits (train / eval).
  prefill()  -- full-sequence forward that also fills the serving cache.
  decode()   -- single-token step against the cache.

Layer kinds come from arch.layer_kind(i) (never stored in the param tree, so
the tree stays jit-legal).  Sharding: models are mesh-agnostic; the launcher
passes `act_spec` (PartitionSpec for [B, L, d] activations) used as a
residual-stream constraint, and GSPMD propagates the rest from params.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.config import ArchConfig, EngineConfig
from repro.core.quant import QTensor, quantize_act_dynamic
from repro.kernels import ops
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.params import ParamSpec


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def block_schema(arch: ArchConfig, i: int) -> dict:
    kind = arch.layer_kind(i)
    d = arch.d_model
    norm = lambda: ParamSpec((d,), (None,), "zeros")
    s: Dict[str, Any] = {}
    if kind == "mamba":
        s["norm"] = norm()
        s["mixer"] = S.mamba_schema(arch)
        return s
    if kind == "recurrent":
        s["norm"] = norm()
        s["mixer"] = S.rglru_schema(arch)
    else:
        s["norm"] = norm()
        s["attn"] = L.attention_schema(arch)
        if arch.post_norms:
            s["post_attn_norm"] = norm()
    if arch.d_ff > 0:
        s["mlp_norm"] = norm()
        s["mlp"] = (L.moe_schema(arch) if arch.is_moe
                    else L.mlp_schema(arch))
        if arch.post_norms:
            s["post_mlp_norm"] = norm()
    return s


def lm_schema(arch: ArchConfig) -> dict:
    d, v = arch.d_model, arch.vocab_size
    s = {
        "embed": ParamSpec((v, d), ("tp", None), "embed"),
        "blocks": [block_schema(arch, i) for i in range(arch.n_layers)],
        "final_norm": ParamSpec((d,), (None,), "zeros"),
    }
    if not arch.tie_embeddings:
        s["head"] = ParamSpec((d, v), ("fsdp", "tp"))
    return s


# ---------------------------------------------------------------------------
# Scan-over-layers (stacked params): compile-time O(1) in depth
# ---------------------------------------------------------------------------

def _stack_spec(spec, groups: int):
    if not isinstance(spec, ParamSpec):
        return spec
    return ParamSpec((groups,) + tuple(spec.shape), (None,) + tuple(spec.axes),
                     spec.init, spec.dtype)


def scan_groups(arch: ArchConfig) -> Tuple[int, int, int]:
    """(period, full_groups, tail_layers): layers = period*groups + tail."""
    p = len(arch.block_pattern)
    g, tail = divmod(arch.n_layers, p)
    return p, g, tail


def lm_schema_scanned(arch: ArchConfig) -> dict:
    """Same model as lm_schema, with the first period*groups layers stacked
    on a leading group dim (lax.scan'd at apply time); `tail` layers stay
    unrolled.  Production trains use this: HLO size / compile time become
    depth-independent."""
    d, v = arch.d_model, arch.vocab_size
    p, g, tail = scan_groups(arch)
    stack = [jax.tree_util.tree_map(
        lambda s: _stack_spec(s, g), block_schema(arch, i),
        is_leaf=lambda x: isinstance(x, ParamSpec))
        for i in range(p)]
    s = {
        "embed": ParamSpec((v, d), ("tp", None), "embed"),
        "stack": stack,
        "tail": [block_schema(arch, p * g + i) for i in range(tail)],
        "final_norm": ParamSpec((d,), (None,), "zeros"),
    }
    if not arch.tie_embeddings:
        s["head"] = ParamSpec((d, v), ("fsdp", "tp"))
    return s


def stack_params(arch: ArchConfig, params: dict) -> dict:
    """Re-layout unrolled params (lm_schema) into the scanned layout."""
    p, g, tail = scan_groups(arch)
    blocks = params["blocks"]
    stack = []
    for i in range(p):
        group = [blocks[j * p + i] for j in range(g)]
        stack.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *group))
    out = {k: v for k, v in params.items() if k != "blocks"}
    out["stack"] = stack
    out["tail"] = [blocks[p * g + i] for i in range(tail)]
    return out


def forward_scanned(params: dict, batch: dict, arch: ArchConfig,
                    eng: EngineConfig, *, act_spec=None, remat: str = "none",
                    triangle_skip: bool = False, return_hidden: bool = False,
                    compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """forward() with lax.scan over layer groups (stacked params)."""
    if "embeds" in batch:
        x = batch["embeds"].astype(compute_dtype)
        b, l, _ = x.shape
    else:
        tokens = batch["tokens"]
        b, l = tokens.shape
        x = embed_tokens(params, tokens, arch, compute_dtype)
    x = _constrain(x, act_spec)
    pos = _positions(batch, b, l)
    cos, sin = L.rope_angles(pos, arch.head_dim, arch.rope_theta,
                             arch.mrope_sections if arch.mrope else None)
    p_period, g, tail = scan_groups(arch)

    def group_body(carry, group_params):
        x, aux = carry
        for i in range(p_period):
            x, (_, a) = block_apply(group_params[i], x, arch.layer_kind(i),
                                    arch, eng, cos=cos, sin=sin,
                                    act_spec=act_spec,
                                    triangle_skip=triangle_skip)
            aux = aux + a
        return (x, aux), None

    if remat in ("block", "full"):
        policy = (None if remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        group_body = jax.checkpoint(group_body, policy=policy)

    (x, aux_total), _ = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), params["stack"])

    for i, p in enumerate(params["tail"]):
        x, (_, a) = block_apply(p, x, arch.layer_kind(p_period * g + i),
                                arch, eng, cos=cos, sin=sin,
                                act_spec=act_spec,
                                triangle_skip=triangle_skip)
        aux_total = aux_total + a

    x = L.rms_norm(x, params["final_norm"], arch.norm_eps)
    if return_hidden:
        return x, aux_total
    return lm_logits(params, x, arch), aux_total


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _mlp_half(p: dict, x: jax.Array, arch: ArchConfig, eng: EngineConfig,
              act_spec) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if "mlp" not in p:
        return x, aux
    hin = L.rms_norm(x, p["mlp_norm"], arch.norm_eps)
    if arch.is_moe:
        h, aux = L.moe_apply(p["mlp"], hin, arch, eng, act_spec=act_spec)
    else:
        h = L.mlp_apply(p["mlp"], hin, arch, eng)
    if arch.post_norms:
        h = L.rms_norm(h, p["post_mlp_norm"], arch.norm_eps)
    return _constrain(x + h, act_spec), aux


def block_apply(p: dict, x: jax.Array, kind: str, arch: ArchConfig,
                eng: EngineConfig, *, cos, sin, act_spec=None,
                triangle_skip: bool = False, q_offset: int = 0,
                state: Optional[dict] = None) -> Tuple[jax.Array, Any]:
    """One residual block, full-sequence.  Returns (x, (new_state, aux))."""
    new_state = None
    if kind == "mamba":
        h, new_state = S.mamba_apply(
            p["mixer"], L.rms_norm(x, p["norm"], arch.norm_eps), arch, eng,
            state=state)
        x = _constrain(x + h, act_spec)
        return x, (new_state, jnp.zeros((), jnp.float32))
    if kind == "recurrent":
        h, new_state = S.rglru_apply(
            p["mixer"], L.rms_norm(x, p["norm"], arch.norm_eps), arch, eng,
            state=state)
        x = _constrain(x + h, act_spec)
    else:
        h = L.attention_apply(
            p["attn"], L.rms_norm(x, p["norm"], arch.norm_eps), arch, eng,
            layer_kind=kind, cos=cos, sin=sin, q_offset=q_offset,
            triangle_skip=triangle_skip)
        if arch.post_norms:
            h = L.rms_norm(h, p["post_attn_norm"], arch.norm_eps)
        x = _constrain(x + h, act_spec)
    x, aux = _mlp_half(p, x, arch, eng, act_spec)
    return x, (new_state, aux)


# ---------------------------------------------------------------------------
# Embedding / head (QTensor-aware for quantized serving)
# ---------------------------------------------------------------------------

def embed_tokens(params: dict, tokens: jax.Array, arch: ArchConfig,
                 dtype=jnp.bfloat16) -> jax.Array:
    emb = params["embed"]
    if isinstance(emb, QTensor):
        rows = jnp.take(emb.q, tokens, axis=0).astype(jnp.float32)
        x = (rows * jnp.take(emb.scale, tokens, axis=0)).astype(dtype)
    else:
        x = jnp.take(emb, tokens, axis=0).astype(dtype)
    if arch.emb_scale:
        x = x * jnp.asarray(arch.d_model ** 0.5, dtype)
    return x


def lm_logits(params: dict, x: jax.Array, arch: ArchConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if arch.tie_embeddings:
        emb = params["embed"]
        if isinstance(emb, QTensor):
            logits = jnp.einsum("bld,vd->blv", xf, emb.q.astype(jnp.float32))
            logits = logits * emb.scale.reshape(1, 1, -1)
        else:
            logits = jnp.einsum("bld,vd->blv", xf, emb.astype(jnp.float32))
    else:
        head = params["head"]
        if isinstance(head, QTensor):
            logits = jnp.einsum("bld,dv->blv", xf, head.q.astype(jnp.float32))
            logits = logits * head.scale.reshape(1, 1, -1)
        else:
            logits = jnp.einsum("bld,dv->blv", xf, head.astype(jnp.float32))
    if arch.final_softcap > 0:
        logits = jnp.tanh(logits / arch.final_softcap) * arch.final_softcap
    return logits


# ---------------------------------------------------------------------------
# Full-sequence forward (train / eval)
# ---------------------------------------------------------------------------

def _positions(batch: dict, b: int, l: int) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(l)[None], (b, l))


def forward(params: dict, batch: dict, arch: ArchConfig, eng: EngineConfig,
            *, act_spec=None, remat: str = "none",
            triangle_skip: bool = False, return_hidden: bool = False,
            compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B, L, V] f32, aux_loss []).  With return_hidden,
    returns the post-norm hidden states instead of logits (fused-CE path)."""
    if "embeds" in batch:                      # stubbed modality frontend
        x = batch["embeds"].astype(compute_dtype)
        b, l, _ = x.shape
    else:
        tokens = batch["tokens"]
        b, l = tokens.shape
        x = embed_tokens(params, tokens, arch, compute_dtype)
    x = _constrain(x, act_spec)
    pos = _positions(batch, b, l)
    cos, sin = L.rope_angles(pos, arch.head_dim, arch.rope_theta,
                             arch.mrope_sections if arch.mrope else None)

    aux_total = jnp.zeros((), jnp.float32)

    def run_block(x, p, kind):
        x, (_, aux) = block_apply(p, x, kind, arch, eng, cos=cos, sin=sin,
                                  act_spec=act_spec,
                                  triangle_skip=triangle_skip)
        return x, aux

    if remat in ("block", "full"):
        policy = (None if remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        run_block = jax.checkpoint(run_block, policy=policy,
                                   static_argnums=(2,))

    for i, p in enumerate(params["blocks"]):
        x, aux = run_block(x, p, arch.layer_kind(i))
        aux_total = aux_total + aux

    x = L.rms_norm(x, params["final_norm"], arch.norm_eps)
    if return_hidden:
        return x, aux_total
    return lm_logits(params, x, arch), aux_total


# ---------------------------------------------------------------------------
# Serving cache
# ---------------------------------------------------------------------------

def cache_schema(arch: ArchConfig, batch: int, max_seq: int,
                 eng: EngineConfig) -> dict:
    """Cache schema (ParamSpec leaves).

    KV layout [B, S, Hkv, D] with the SEQUENCE dim sharded over the model
    axis ('tp'): always divisible (unlike kv heads), and decode attention
    lowers to the flash-decode partial-softmax combine under GSPMD.
    """
    kv_dt = jnp.int8 if eng.kv_cache_dtype == "int8" else jnp.bfloat16
    nkv, hd = arch.n_kv_heads, arch.head_dim
    per_layer = []
    for i in range(arch.n_layers):
        kind = arch.layer_kind(i)
        if kind == "mamba":
            per_layer.append(S.mamba_state_schema(arch, batch, jnp.bfloat16))
        elif kind == "recurrent":
            per_layer.append(S.rglru_state_schema(arch, batch, jnp.bfloat16))
        else:
            s = min(arch.local_window, max_seq) if kind == "local" else max_seq
            d = {
                "k": ParamSpec((batch, s, nkv, hd), ("dp", "tp"), "zeros", kv_dt),
                "v": ParamSpec((batch, s, nkv, hd), ("dp", "tp"), "zeros", kv_dt),
            }
            if eng.kv_cache_dtype == "int8":
                d["k_scale"] = ParamSpec((batch, s, nkv), ("dp", "tp"),
                                         "zeros", jnp.float32)
                d["v_scale"] = ParamSpec((batch, s, nkv), ("dp", "tp"),
                                         "zeros", jnp.float32)
            per_layer.append(d)
    return {"layers": per_layer,
            "pos": ParamSpec((), (), "zeros", jnp.int32)}


def _kv_store(entry: dict, k, v, idx, eng: EngineConfig):
    """Write k/v [B, L, Hkv, D] into the cache at position idx.

    idx is a scalar (one shared position, the historical path) or a [B]
    vector (per-slot positions: the continuous-batching serve path writes
    each slot's single new token at that slot's own sequence position;
    vector idx requires L == 1)."""
    entry = dict(entry)
    per_slot = jnp.asarray(idx).ndim == 1

    def store(buf, val):
        if per_slot:
            b = val.shape[0]
            return buf.at[jnp.arange(b), idx].set(val[:, 0])
        return jax.lax.dynamic_update_slice_in_dim(buf, val, idx, axis=1)

    if eng.kv_cache_dtype == "int8":
        kq = quantize_act_dynamic(k, per_token=True)
        vq = quantize_act_dynamic(v, per_token=True)
        entry["k"] = store(entry["k"], kq.q)
        entry["v"] = store(entry["v"], vq.q)
        entry["k_scale"] = store(entry["k_scale"], kq.scale[..., 0])
        entry["v_scale"] = store(entry["v_scale"], vq.scale[..., 0])
        return entry
    entry["k"] = store(entry["k"], k.astype(entry["k"].dtype))
    entry["v"] = store(entry["v"], v.astype(entry["v"].dtype))
    return entry


def _kv_read(entry: dict, eng: EngineConfig):
    if eng.kv_cache_dtype == "int8":
        k = entry["k"].astype(jnp.float32) * entry["k_scale"][..., None]
        v = entry["v"].astype(jnp.float32) * entry["v_scale"][..., None]
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    return entry["k"], entry["v"]


# ---------------------------------------------------------------------------
# Block-paged serving cache (global-attention layers)
# ---------------------------------------------------------------------------

def num_pages(max_seq: int, page_size: int) -> int:
    """Table width: pages per slot at worst-case length."""
    return -(-max_seq // page_size)


def paged_cache_schema(arch: ArchConfig, batch: int, max_seq: int,
                       eng: EngineConfig, page_size: int,
                       num_blocks: Optional[int] = None) -> dict:
    """Block-paged variant of cache_schema.

    Global-attention layers keep their K/V in a SHARED block pool
    [num_blocks, page_size, Hkv, D] indexed through one block table
    cache["tables"] [B, max_pages] (block b of every layer's pool belongs
    to the same slot, so one table serves all layers).  `num_blocks`
    defaults to dense capacity (batch * max_pages); a serving engine hands
    out fewer and admits by free blocks instead of worst-case length.

    Local (ring) and SSM layers stay dense per-slot: the ring window /
    state size already bounds their memory, so paging them buys nothing.
    max_seq must be a page multiple (the engine rounds it up) so the
    gathered dense view is shape-identical to the dense cache -- the
    bit-identity contract of the paged path.
    """
    if max_seq % page_size:
        raise ValueError(f"max_seq={max_seq} must be a multiple of "
                         f"page_size={page_size} (round it up)")
    pages = num_pages(max_seq, page_size)
    if num_blocks is None:
        num_blocks = batch * pages
    kv_dt = jnp.int8 if eng.kv_cache_dtype == "int8" else jnp.bfloat16
    nkv, hd = arch.n_kv_heads, arch.head_dim
    per_layer = []
    for i in range(arch.n_layers):
        kind = arch.layer_kind(i)
        if kind == "mamba":
            per_layer.append(S.mamba_state_schema(arch, batch, jnp.bfloat16))
        elif kind == "recurrent":
            per_layer.append(S.rglru_state_schema(arch, batch, jnp.bfloat16))
        elif kind == "local":
            s = min(arch.local_window, max_seq)
            d = {
                "k": ParamSpec((batch, s, nkv, hd), ("dp", "tp"), "zeros", kv_dt),
                "v": ParamSpec((batch, s, nkv, hd), ("dp", "tp"), "zeros", kv_dt),
            }
            if eng.kv_cache_dtype == "int8":
                d["k_scale"] = ParamSpec((batch, s, nkv), ("dp", "tp"),
                                         "zeros", jnp.float32)
                d["v_scale"] = ParamSpec((batch, s, nkv), ("dp", "tp"),
                                         "zeros", jnp.float32)
            per_layer.append(d)
        else:
            d = {
                "k": ParamSpec((num_blocks, page_size, nkv, hd), (None, None),
                               "zeros", kv_dt),
                "v": ParamSpec((num_blocks, page_size, nkv, hd), (None, None),
                               "zeros", kv_dt),
            }
            if eng.kv_cache_dtype == "int8":
                d["k_scale"] = ParamSpec((num_blocks, page_size, nkv),
                                         (None, None), "zeros", jnp.float32)
                d["v_scale"] = ParamSpec((num_blocks, page_size, nkv),
                                         (None, None), "zeros", jnp.float32)
            per_layer.append(d)
    return {"layers": per_layer,
            "tables": ParamSpec((batch, pages), (None, None), "zeros",
                                jnp.int32),
            "pos": ParamSpec((), (), "zeros", jnp.int32)}


def _paged_flat_idx(tables: jax.Array, idx: jax.Array, page: int
                    ) -> jax.Array:
    """Flat pool index of per-slot position idx [B]: the slot's block id
    (from its table row) times the page size plus the in-page offset.
    Unallocated table entries hold the POSITIVE sentinel `num_blocks`
    (negative indices would wrap in a JAX scatter), so their flat index is
    out of bounds and a mode="drop" scatter discards the write -- an idle
    slot can never corrupt a freed (or reassigned) block."""
    blk = jnp.take_along_axis(tables, (idx // page)[:, None], axis=1)[:, 0]
    return blk * page + idx % page


def _paged_kv_store(entry: dict, k, v, tables: jax.Array, idx,
                    eng: EngineConfig, page: int, mask=None) -> dict:
    """Write ONE new token's k/v [B, 1, Hkv, D] into the block pool at
    per-slot positions idx ([B] or scalar), through the block table.
    `mask` [B] bool, when given, gates the write per slot (False rows are
    redirected out of bounds and dropped -- the speculative-commit path)."""
    entry = dict(entry)
    b = k.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (b,))
    flat = _paged_flat_idx(tables, idx, page)
    if mask is not None:
        flat = jnp.where(mask, flat, entry["k"].shape[0] * page)

    def store(pool, val):
        fp = pool.reshape((-1,) + pool.shape[2:])
        fp = fp.at[flat].set(val[:, 0].astype(pool.dtype), mode="drop")
        return fp.reshape(pool.shape)

    if eng.kv_cache_dtype == "int8":
        kq = quantize_act_dynamic(k, per_token=True)
        vq = quantize_act_dynamic(v, per_token=True)
        entry["k"] = store(entry["k"], kq.q)
        entry["v"] = store(entry["v"], vq.q)
        entry["k_scale"] = store(entry["k_scale"], kq.scale[..., 0])
        entry["v_scale"] = store(entry["v_scale"], vq.scale[..., 0])
        return entry
    entry["k"] = store(entry["k"], k)
    entry["v"] = store(entry["v"], v)
    return entry


def _masked_kv_store(entry: dict, k, v, idx, mask, eng: EngineConfig
                     ) -> dict:
    """Dense single-token store with a per-slot write gate: like _kv_store
    with vector idx, but rows where `mask` [B] is False are redirected one
    past the sequence end and dropped (mode="drop" ignores positive OOB;
    negative sentinels would wrap) -- the speculative-commit path, where a
    rejected draft must leave the slot's cache untouched."""
    entry = dict(entry)
    b = k.shape[0]
    s = entry["k"].shape[1]
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (b,))
    slot = jnp.where(mask, idx, s)

    def store(buf, val):
        return buf.at[jnp.arange(b), slot].set(
            val[:, 0].astype(buf.dtype), mode="drop")

    if eng.kv_cache_dtype == "int8":
        kq = quantize_act_dynamic(k, per_token=True)
        vq = quantize_act_dynamic(v, per_token=True)
        entry["k"] = store(entry["k"], kq.q)
        entry["v"] = store(entry["v"], vq.q)
        entry["k_scale"] = store(entry["k_scale"], kq.scale[..., 0])
        entry["v_scale"] = store(entry["v_scale"], vq.scale[..., 0])
        return entry
    entry["k"] = store(entry["k"], k)
    entry["v"] = store(entry["v"], v)
    return entry


def _paged_kv_read(entry: dict, tables: jax.Array, eng: EngineConfig):
    """Gather the slot-ordered dense view [B, pages*page, Hkv, D] of a
    block pool through the table -- a pure copy, so attention over the view
    is bit-identical to the dense cache (positions >= the slot's length
    hold garbage from stale/unallocated blocks, but the decode mask sends
    them to exp-underflow zero exactly like dense zero-padding)."""
    from repro.kernels import ops
    k = ops.paged_gather(entry["k"], tables, eng)
    v = ops.paged_gather(entry["v"], tables, eng)
    if eng.kv_cache_dtype == "int8":
        ks = ops.paged_gather(entry["k_scale"], tables, eng)
        vs = ops.paged_gather(entry["v_scale"], tables, eng)
        k = (k.astype(jnp.float32) * ks[..., None]).astype(jnp.bfloat16)
        v = (v.astype(jnp.float32) * vs[..., None]).astype(jnp.bfloat16)
        return k, v
    return k, v


def _paged_prefill_store(entry: dict, k, v, tables: jax.Array,
                         mask: jax.Array, eng: EngineConfig, page: int
                         ) -> dict:
    """Scatter a prefill's whole k/v span [B, L, Hkv, D] into the block
    pool through the table, rows gated by `mask` [B] (the serving engine's
    refilled slots; foreign rows' writes drop)."""
    entry = dict(entry)
    b, l = k.shape[0], k.shape[1]
    pidx = jnp.arange(l)
    blk = jnp.take_along_axis(
        tables, jnp.broadcast_to((pidx // page)[None, :], (b, l)), axis=1)
    flat = blk * page + (pidx % page)[None, :]          # [B, L]
    oob = entry["k"].shape[0] * page                    # mode="drop" target
    flat = jnp.where(mask[:, None], flat, oob)          # foreign rows drop

    def store(pool, val):
        fp = pool.reshape((-1,) + pool.shape[2:])
        fp = fp.at[flat.reshape(-1)].set(
            val.reshape((-1,) + val.shape[2:]).astype(pool.dtype),
            mode="drop")
        return fp.reshape(pool.shape)

    if eng.kv_cache_dtype == "int8":
        kq = quantize_act_dynamic(k, per_token=True)
        vq = quantize_act_dynamic(v, per_token=True)
        entry["k"] = store(entry["k"], kq.q)
        entry["v"] = store(entry["v"], vq.q)
        entry["k_scale"] = store(entry["k_scale"], kq.scale[..., 0])
        entry["v_scale"] = store(entry["v_scale"], vq.scale[..., 0])
        return entry
    entry["k"] = store(entry["k"], k)
    entry["v"] = store(entry["v"], v)
    return entry


def _paged_tail_store(entry: dict, k, v, tables: jax.Array,
                      mask: jax.Array, eng: EngineConfig, page: int,
                      base, row_starts: jax.Array) -> dict:
    """Scatter a chunked prefill's TAIL span [B, T, Hkv, D] into the block
    pool through the table.  Column j of the span sits at absolute cache
    position `base + j`; a row writes only positions `>= row_starts[r]`
    (its first non-shared token), so pages matched out of the prefix index
    -- owned by other tables too -- are never written (the copy-on-write
    boundary).  Rows gated by `mask` [B] as in _paged_prefill_store."""
    entry = dict(entry)
    b, t = k.shape[0], k.shape[1]
    pidx = base + jnp.arange(t)                         # absolute positions
    blk = jnp.take_along_axis(
        tables, jnp.broadcast_to((pidx // page)[None, :], (b, t)), axis=1)
    flat = blk * page + (pidx % page)[None, :]          # [B, T]
    oob = entry["k"].shape[0] * page                    # mode="drop" target
    write = mask[:, None] & (pidx[None, :] >= row_starts[:, None])
    flat = jnp.where(write, flat, oob)                  # shared pages drop

    def store(pool, val):
        fp = pool.reshape((-1,) + pool.shape[2:])
        fp = fp.at[flat.reshape(-1)].set(
            val.reshape((-1,) + val.shape[2:]).astype(pool.dtype),
            mode="drop")
        return fp.reshape(pool.shape)

    if eng.kv_cache_dtype == "int8":
        kq = quantize_act_dynamic(k, per_token=True)
        vq = quantize_act_dynamic(v, per_token=True)
        entry["k"] = store(entry["k"], kq.q)
        entry["v"] = store(entry["v"], vq.q)
        entry["k_scale"] = store(entry["k_scale"], kq.scale[..., 0])
        entry["v_scale"] = store(entry["v_scale"], vq.scale[..., 0])
        return entry
    entry["k"] = store(entry["k"], k)
    entry["v"] = store(entry["v"], v)
    return entry


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params: dict, cache: dict, batch: dict, arch: ArchConfig,
            eng: EngineConfig, *, act_spec=None,
            compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, dict]:
    """Run the prompt, fill the cache.  Returns (last-token logits, cache)."""
    if "embeds" in batch:
        x = batch["embeds"].astype(compute_dtype)
        b, l, _ = x.shape
    else:
        tokens = batch["tokens"]
        b, l = tokens.shape
        x = embed_tokens(params, tokens, arch, compute_dtype)
    x = _constrain(x, act_spec)
    pos = _positions(batch, b, l)
    cos, sin = L.rope_angles(pos, arch.head_dim, arch.rope_theta,
                             arch.mrope_sections if arch.mrope else None)

    new_layers = []
    for i, p in enumerate(params["blocks"]):
        kind = arch.layer_kind(i)
        entry = cache["layers"][i]
        if kind in ("mamba", "recurrent"):
            x, (st, _) = block_apply(p, x, kind, arch, eng, cos=cos, sin=sin,
                                     act_spec=act_spec, state=entry)
            new_layers.append(st)
            continue
        # Attention layer: compute k/v once, reuse for both cache and attn.
        hin = L.rms_norm(x, p["norm"], arch.norm_eps)
        k, v = L.attention_kv(p["attn"], hin, arch, eng, cos, sin)
        h = L.attention_apply(p["attn"], hin, arch, eng, layer_kind=kind,
                              cos=cos, sin=sin, kv_override=(k, v))
        if arch.post_norms:
            h = L.rms_norm(h, p["post_attn_norm"], arch.norm_eps)
        x = _constrain(x + h, act_spec)
        x, _ = _mlp_half(p, x, arch, eng, act_spec)
        if kind == "local":
            w = entry["k"].shape[1]
            entry = _kv_store(entry, k[:, -w:], v[:, -w:], 0, eng)
        else:
            entry = _kv_store(entry, k, v, 0, eng)
        new_layers.append(entry)

    x = L.rms_norm(x, params["final_norm"], arch.norm_eps)
    logits = lm_logits(params, x[:, -1:], arch)
    return logits, {"layers": new_layers,
                    "pos": jnp.asarray(l, jnp.int32)}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def decode(params: dict, cache: dict, tokens: jax.Array, arch: ArchConfig,
           eng: EngineConfig, *, act_spec=None,
           positions: Optional[jax.Array] = None,
           compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, dict]:
    """One decode step.  tokens: [B, 1].  Returns (logits [B,1,V], cache).

    cache["pos"] is a scalar (all slots at one position) or a [B] vector
    (per-slot positions, the continuous-batching serve path)."""
    pos = cache["pos"]
    b = tokens.shape[0]
    x = embed_tokens(params, tokens, arch, compute_dtype)
    x = _constrain(x, act_spec)
    if positions is None:
        positions = (pos[:, None] if jnp.asarray(pos).ndim == 1
                     else jnp.broadcast_to(pos[None, None], (b, 1)))
    cos, sin = L.rope_angles(positions, arch.head_dim, arch.rope_theta,
                             arch.mrope_sections if arch.mrope else None)

    new_layers = []
    for i, p in enumerate(params["blocks"]):
        kind = arch.layer_kind(i)
        entry = cache["layers"][i]
        hin = L.rms_norm(x, p["norm"], arch.norm_eps)
        if kind == "mamba":
            h, st = S.mamba_decode(p["mixer"], hin, arch, eng, entry)
            new_layers.append(st)
            x = x + h
            continue
        if kind == "recurrent":
            h, st = S.rglru_decode(p["mixer"], hin, arch, eng, entry)
            new_layers.append(st)
            x = x + h
        else:
            k, v = L.attention_kv(p["attn"], hin, arch, eng, cos, sin)
            if kind == "local":
                w = entry["k"].shape[1]
                entry = _kv_store(entry, k, v, pos % w, eng)
                ring = True
            else:
                entry = _kv_store(entry, k, v, pos, eng)
                ring = False
            kc, vc = _kv_read(entry, eng)
            h = L.attention_decode(
                p["attn"], hin, arch, eng, layer_kind=kind,
                k_cache=kc, v_cache=vc, length=pos + 1, cos=cos, sin=sin,
                ring=ring)
            if arch.post_norms:
                h = L.rms_norm(h, p["post_attn_norm"], arch.norm_eps)
            new_layers.append(entry)
            x = x + h
        x, _ = _mlp_half(p, x, arch, eng, act_spec)

    x = L.rms_norm(x, params["final_norm"], arch.norm_eps)
    logits = lm_logits(params, x, arch)
    return logits, {"layers": new_layers, "pos": pos + 1}
