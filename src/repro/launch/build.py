"""Program builder: assemble (step_fn, abstract args, shardings, model FLOPs)
for every (arch x shape x mesh x engine) cell.  Used by the dry-run, the
training driver, and the serving driver."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import engine as eng_lib
from repro.core.config import ArchConfig, EngineConfig, ShapeConfig, TrainConfig
from repro.launch import mesh as mesh_lib
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as W
from repro.train import optim
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class Program:
    name: str
    fn: Callable            # jitted
    args: tuple             # abstract (ShapeDtypeStruct pytrees)
    model_flops: float
    chips: int
    peak_flops: float       # per-chip peak for the roofline compute term


# ---------------------------------------------------------------------------
# Useful-FLOP accounting (the roofline's MODEL_FLOPS)
# ---------------------------------------------------------------------------

def _emb_params(arch: ArchConfig) -> int:
    n = arch.vocab_size * arch.d_model
    return n if arch.tie_embeddings else 2 * n


def _attn_flops_per_token(arch: ArchConfig, ctx: int, fwd_mult: float) -> float:
    """QK^T + PV flops per token, summed over layers (local layers use the
    window; ssm/recurrent scan flops are ~6*d_state per element, negligible
    and folded into param flops)."""
    total = 0.0
    for i in range(arch.n_layers):
        kind = arch.layer_kind(i)
        if kind in ("mamba", "recurrent"):
            continue
        eff = min(ctx, arch.local_window) if kind == "local" else ctx
        total += fwd_mult * 2.0 * eff * arch.n_heads * arch.head_dim
    return total


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B per step (decode), N = active
    non-embedding params, plus head and attention terms."""
    n_active = arch.active_param_count() - _emb_params(arch)
    d, v = arch.d_model, arch.vocab_size
    b, l = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * l
        head = 6.0 * d * v * tokens
        return 6.0 * n_active * tokens + head \
            + tokens * _attn_flops_per_token(arch, l / 2, 6.0)
    if shape.kind == "prefill":
        tokens = b * l
        head = 2.0 * d * v * b            # last-token logits only
        return 2.0 * n_active * tokens + head \
            + tokens * _attn_flops_per_token(arch, l / 2, 2.0)
    # decode: one token per sequence against a ctx-long cache
    return (2.0 * n_active * b + 2.0 * d * v * b
            + b * _attn_flops_per_token(arch, l, 2.0))


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def _is_audio(arch: ArchConfig) -> bool:
    return arch.family == "audio"


def _schema(arch: ArchConfig, shape: Optional[ShapeConfig] = None):
    if _is_audio(arch):
        max_pos = max(32768, shape.seq_len if shape else 32768)
        return W.whisper_schema(arch, max_dec_pos=max_pos)
    return T.lm_schema(arch)


def auto_microbatches(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                      budget_bytes: float = 6e9) -> int:
    """Gradient-accumulation factor sized so the per-device layer-boundary
    activations (full remat) fit the budget."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    local_b = max(shape.global_batch // dp, 1)
    boundary = local_b * shape.seq_len * arch.d_model * 2 * arch.n_layers
    mb = 1
    while boundary / mb > budget_bytes and mb < local_b:
        mb *= 2
    return mb


def default_train_cfg(arch: ArchConfig, shape: ShapeConfig,
                      mesh: Mesh) -> TrainConfig:
    return TrainConfig(remat="full",
                       microbatches=auto_microbatches(arch, shape, mesh),
                       scan_layers=not _is_audio(arch) and arch.n_layers >= 8)


def build_train(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                eng: EngineConfig, tcfg: TrainConfig) -> Program:
    if tcfg.scan_layers and not _is_audio(arch):
        schema = T.lm_schema_scanned(arch)
    else:
        schema = _schema(arch, shape)
    pdt = jnp.bfloat16 if tcfg.param_dtype == "bf16" else jnp.float32
    p_abs = prm.abstract_params(schema, pdt)
    p_specs = prm.pspec_tree(schema, mesh)
    p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), p_specs)

    opt_abs = {
        "m": jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), p_abs),
        "v": jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), p_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    opt_specs = optim.opt_state_pspecs(p_specs, p_abs, mesh,
                                       zero1=tcfg.zero1)
    opt_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), opt_specs,
        is_leaf=lambda x: isinstance(x, P))

    state_abs = {"params": p_abs, "opt": opt_abs}
    state_sh = {"params": p_sh, "opt": opt_sh}

    batch_abs, batch_axes = configs.input_specs(arch, shape)
    batch_sh = mesh_lib.input_shardings(mesh, batch_abs, batch_axes)

    aspec = mesh_lib.act_pspec(mesh, shape.global_batch,
                               tcfg.seq_shard_activations)
    step = make_train_step(arch, eng, tcfg, act_spec=NamedSharding(mesh, aspec))
    fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                 donate_argnums=(0,))
    return Program(
        name=f"{arch.name}:{shape.name}", fn=fn,
        args=(state_abs, batch_abs),
        model_flops=model_flops(arch, shape), chips=mesh_lib.chips(mesh),
        peak_flops=197e12)


def _serving_params(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    eng: EngineConfig):
    schema = _schema(arch, shape)
    qschema = eng_lib.quantize_schema(schema, eng)
    # Serving: drop the fsdp axis for models that fit in TP-sharded HBM
    # (< ~12B weights); keep 2-D sharding for the big ones (grok, mamba-7b).
    big = arch.param_count() * (1 if eng.quant != "none" else 2) > 12e9
    drop = () if big else ("fsdp",)
    p_abs = prm.abstract_params(qschema, None)
    # Serving weights are bf16 (f32 is a training-only dtype).
    p_abs = jax.tree_util.tree_map(
        lambda a: (jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
                   if a.dtype == jnp.float32 else a), p_abs)

    def resolve(s: prm.ParamSpec):
        axes = tuple(None if (a == "fsdp" and not big) else a
                     for a in s.axes)
        return NamedSharding(mesh, prm.resolve_pspec(mesh, s.shape, axes))

    p_sh = prm._leaf_map(resolve, qschema)
    return qschema, p_abs, p_sh


def build_serve(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                eng: EngineConfig) -> Program:
    """prefill or decode step, per shape.kind."""
    qschema, p_abs, p_sh = _serving_params(arch, shape, mesh, eng)
    b, s = shape.global_batch, shape.seq_len
    if _is_audio(arch):
        cs = W.whisper_cache_schema(arch, b, s, eng)
    else:
        cs = T.cache_schema(arch, b, s, eng)
    c_abs = prm.abstract_params(cs, None)
    c_sh = prm.sharding_tree(cs, mesh)
    aspec = NamedSharding(mesh, mesh_lib.act_pspec(mesh, b))

    batch_abs, batch_axes = configs.input_specs(arch, shape)
    batch_sh = mesh_lib.input_shardings(mesh, batch_abs, batch_axes)

    mod = W if _is_audio(arch) else T

    if shape.kind == "prefill":
        def fn(params, cache, batch):
            return mod.prefill(params, cache, batch, arch, eng,
                               act_spec=aspec)
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, batch_sh),
                      donate_argnums=(1,))
        args = (p_abs, c_abs, batch_abs)
    else:
        def fn(params, cache, batch):
            kw = {}
            if arch.mrope and "positions" in batch:
                kw["positions"] = batch["positions"]
            return mod.decode(params, cache, batch["tokens"], arch, eng,
                              act_spec=aspec, **kw)
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, batch_sh),
                      donate_argnums=(1,))
        args = (p_abs, c_abs, batch_abs)

    peak = 394e12 if eng.quant == "w8a8" else 197e12
    return Program(
        name=f"{arch.name}:{shape.name}", fn=jfn, args=args,
        model_flops=model_flops(arch, shape), chips=mesh_lib.chips(mesh),
        peak_flops=peak)


def build(arch_name: str, shape_name: str, mesh: Mesh,
          eng: Optional[EngineConfig] = None,
          tcfg: Optional[TrainConfig] = None,
          arch: Optional[ArchConfig] = None) -> Program:
    arch = arch or configs.get_arch(arch_name)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_is_runnable(arch, shape)
    if not ok:
        raise ValueError(f"{arch_name} x {shape_name}: {why}")
    if shape.kind == "train":
        return build_train(arch, shape, mesh,
                           eng or eng_lib.train_engine(),
                           tcfg or default_train_cfg(arch, shape, mesh))
    return build_serve(arch, shape, mesh, eng or eng_lib.w8_engine())
