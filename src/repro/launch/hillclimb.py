"""§Perf hillclimbing driver: run a cell under a series of configurations
(paper-faithful baseline first, then beyond-paper optimizations) and record
the roofline-term progression.

Must run as a module entry point (sets the 512-device flag before jax):

  python -m repro.launch.hillclimb --cell decode --out experiments/perf
  python -m repro.launch.hillclimb --cell moe_train --out experiments/perf
  python -m repro.launch.hillclimb --cell bigvocab_train --out experiments/perf

Each variant is an explicit hypothesis (recorded in the JSON + EXPERIMENTS.md
§Perf); run_cell measures before/after with identical methodology.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402

from repro.core.config import EngineConfig, TrainConfig  # noqa: E402
from repro.core import engine as eng_lib                 # noqa: E402
from repro.launch import build as build_lib              # noqa: E402
from repro.launch import mesh as mesh_lib                # noqa: E402
from repro.launch.dryrun import run_cell                 # noqa: E402


def _serve_variants():
    """granite-8b x decode_32k: the cell most representative of the paper's
    technique (the INT8 engine pipeline applied to serving)."""
    return "granite-8b", "decode_32k", [
        ("v0_bf16", "pre-paper reference: bf16 weights, bf16 KV -- memory "
         "term dominated by 2B/param weight reads",
         dict(eng=EngineConfig(quant="none", backend="ref"))),
        ("v1_paper_w8a8", "PAPER-FAITHFUL: W8A8 engine (int8 weights halve "
         "weight-read bytes; fused dequant epilogue) -- hypothesis: memory "
         "term ~ -45% of the weight component",
         dict(eng=EngineConfig(quant="w8a8", backend="ref"))),
        ("v2_int8_kv", "beyond-paper: + int8 KV cache (halves the dominant "
         "KV-read bytes at 32k context) -- hypothesis: memory term -25-40%",
         dict(eng=EngineConfig(quant="w8a8", backend="ref",
                               kv_cache_dtype="int8"))),
    ]


def _moe_train_variants(mesh):
    """grok-1-314b x train_4k: the most collective-bound cell."""
    from repro import configs
    from repro.core.config import SHAPES
    arch = configs.get_arch("grok-1-314b")
    shape = SHAPES["train_4k"]
    base = build_lib.default_train_cfg(arch, shape, mesh)
    return "grok-1-314b", "train_4k", [
        ("v0_baseline", "baseline: fsdp+tp, full remat, auto microbatches, "
         "standard CE", dict(tcfg=base)),
        ("v1_fused_ce", "fused chunked-vocab CE: never materialize "
         "[B,L,131k] f32 logits -- hypothesis: memory term down several "
         "seconds, loss-side bytes ~ -90%",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384))),
        ("v2_seq_shard", "+ sequence-sharded residual stream (SP): per-layer "
         "all-reduces become reduce-scatter+all-gather (half the bytes) -- "
         "hypothesis: collective term -20-40%",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384,
                                       seq_shard_activations=True))),
        ("v3_triangle", "exact-triangle causal attention on top of v1 (SP "
         "refuted, dropped) -- hypothesis: attention flops -~2x; small at "
         "L=4k vs FFN, compute term -5-15%",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384,
                                       triangle_skip=True))),
        ("v4_bf16_params", "mixed precision: bf16 params+grads, f32 Adam "
         "moments -- hypothesis: gradient all-reduce bytes halve "
         "(collective term -~40%), param-read bytes halve",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384,
                                       triangle_skip=True,
                                       param_dtype="bf16"))),
    ]


def _bigvocab_train_variants(mesh):
    """gemma2-2b x train_4k: worst useful-flop ratio among trains (256k
    vocab -> the CE loss dominates bytes)."""
    from repro import configs
    from repro.core.config import SHAPES
    arch = configs.get_arch("gemma2-2b")
    shape = SHAPES["train_4k"]
    base = build_lib.default_train_cfg(arch, shape, mesh)
    return "gemma2-2b", "train_4k", [
        ("v0_baseline", "baseline: standard CE over 256k vocab",
         dict(tcfg=base)),
        ("v1_fused_ce", "fused chunked-vocab CE (rematted chunk body) -- "
         "hypothesis: peak GB/dev drops (logits never materialize); "
         "round-1 unremated version REFUTED at 162 GB/dev",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384))),
        ("v2_triangle", "+ exact-triangle attention on the global layers -- "
         "hypothesis: compute term -15-30% (L/2d large for d=2304)",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384,
                                       triangle_skip=True))),
        ("v3_bf16_params", "+ mixed precision (bf16 params+grads) -- "
         "hypothesis: collective term -~40%, memory term -~20%",
         dict(tcfg=dataclasses.replace(base, loss_chunk_vocab=16384,
                                       triangle_skip=True,
                                       param_dtype="bf16"))),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=["decode", "moe_train", "bigvocab_train"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    if args.cell == "decode":
        arch, shape, variants = _serve_variants()
    elif args.cell == "moe_train":
        arch, shape, variants = _moe_train_variants(mesh)
    else:
        arch, shape, variants = _bigvocab_train_variants(mesh)

    results = []
    for name, hypothesis, kw in variants:
        print(f"\n=== {args.cell}/{name}: {hypothesis}", flush=True)
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       tag=f"/{name}", **kw)
        rec["variant"] = name
        rec["hypothesis"] = hypothesis
        results.append(rec)
        path = os.path.join(args.out, f"{args.cell}__{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)

    print(f"\n=== {args.cell} progression ===")
    for r in results:
        if r["status"] != "ok":
            print(f"{r['variant']}: {r['status']} {r.get('error', '')[:120]}")
            continue
        print(f"{r['variant']:>16}: compute {r['t_compute_s'] * 1e3:9.1f}ms  "
              f"memory {r['t_memory_s'] * 1e3:9.1f}ms  "
              f"collective {r['t_collective_s'] * 1e3:9.1f}ms  "
              f"bound={r['bottleneck']}  "
              f"roofline={100 * r['roofline_fraction']:.2f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
