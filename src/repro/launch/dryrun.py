"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

MUST be executed as a module entry point (python -m repro.launch.dryrun);
the XLA_FLAGS line below runs before any jax import so the 512 placeholder
host devices exist when jax initializes.

Cost-analysis methodology (see core/probe.py): XLA counts while-loop bodies
once, so the production programs (scan-over-layers, flash-attention block
loops, ssm chunk scans, microbatch accumulation) under-report.  Per cell we
therefore:
  1. compile the PRODUCTION program -> proves the sharding config and gives
     memory_analysis (the fits-on-device evidence);
  2. for train/prefill LM cells, compile two PROBE programs (1 and 2 layer
     groups, probe_mode on = every structural loop unrolled) and extrapolate
     flops / bytes / collective-bytes linearly in the group count;
  3. decode cells and the whisper family have no hidden loops at full size
     (whisper runs with probe_mode on directly), so they are measured
     directly.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod | --both-meshes]
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro import configs                       # noqa: E402
from repro.core import probe                    # noqa: E402
from repro.core import roofline as rl           # noqa: E402
from repro.core.config import SHAPES, TrainConfig  # noqa: E402
from repro.core import engine as eng_lib        # noqa: E402
from repro.launch import build as build_lib     # noqa: E402
from repro.launch import mesh as mesh_lib       # noqa: E402


def _cost_get(cost, key):
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


def _compile_metrics(prog) -> dict:
    """Lower+compile; return per-device flops/bytes/collective bytes."""
    lowered = prog.fn.lower(*prog.args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    per = rl.parse_collective_bytes(text)
    mem = compiled.memory_analysis()
    return {
        "flops": _cost_get(cost, "flops"),
        "bytes": _cost_get(cost, "bytes accessed"),
        "coll": per,
        "mem": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        },
    }


def _extrapolate(m1: dict, m2: dict, groups: float) -> dict:
    """Linear in group count: cost(g) = m1 + (m2 - m1) * (g - 1)."""
    out = {"flops": m1["flops"] + (m2["flops"] - m1["flops"]) * (groups - 1),
           "bytes": m1["bytes"] + (m2["bytes"] - m1["bytes"]) * (groups - 1)}
    coll = {}
    for k in rl.COLLECTIVE_KINDS:
        a, b = m1["coll"].get(k, 0), m2["coll"].get(k, 0)
        coll[k] = max(a + (b - a) * (groups - 1), 0.0)
    out["coll"] = coll
    return out


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             eng=None, tcfg=None, verbose: bool = True,
             tag: str = "", probes: bool = True) -> dict:
    """Lower + compile one cell; return the roofline record (JSON-able)."""
    arch = configs.get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = configs.cell_is_runnable(arch, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    base = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind, "tag": tag}
    if not ok:
        return {**base, "status": "skipped", "reason": why}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    is_audio = arch.family == "audio"
    if tcfg is None and shape.kind == "train":
        tcfg = build_lib.default_train_cfg(arch, shape, mesh)

    t0 = time.time()
    try:
        # --- 1. the production program: sharding validity + memory fit ----
        prog = build_lib.build(arch_name, shape_name, mesh, eng=eng,
                               tcfg=tcfg)
        if is_audio:
            with probe.probe_mode():           # small model: exact directly
                main = _compile_metrics(prog)
            probe_used = "direct-probe"
            metrics = main
        else:
            main = _compile_metrics(prog)
            probe_used = "none"
            metrics = main
        t_main = time.time() - t0

        # --- 2. probe extrapolation for loop-hiding LM cells ---------------
        # (skippable for the multi-pod round: the assignment's roofline table
        # is single-pod only; the multi-pod deliverable is the compile pass.)
        if probes and not is_audio and shape.kind in ("train", "prefill"):
            p = len(arch.block_pattern)
            groups = arch.n_layers / p
            # Probes run at microbatches=1 (unrolling the true accumulation
            # factor would square the probe compile time); the per-step cost
            # is microbatch-invariant except for weight re-reads, corrected
            # analytically below.
            mb = tcfg.microbatches if tcfg else 1
            probes = []
            for k in (1, 2):
                arch_k = dataclasses.replace(arch, n_layers=p * k)
                tcfg_k = (dataclasses.replace(tcfg, scan_layers=False,
                                              microbatches=1)
                          if tcfg else None)
                prog_k = build_lib.build(arch_name, shape_name, mesh,
                                         eng=eng, tcfg=tcfg_k, arch=arch_k)
                with probe.probe_mode():
                    probes.append(_compile_metrics(prog_k))
            metrics = _extrapolate(probes[0], probes[1], groups)
            if mb > 1 and shape.kind == "train":
                # Each accumulation step re-reads the (fsdp-gathered) weights:
                # +(mb-1) x param bytes on HBM traffic, and the per-microbatch
                # weight all-gathers repeat mb times.
                pbytes = 4.0 * arch.param_count() / mesh_lib.chips(mesh)
                metrics["bytes"] += (mb - 1) * pbytes
                metrics["coll"] = dict(metrics["coll"])
                metrics["coll"]["all-gather"] = \
                    metrics["coll"].get("all-gather", 0.0) * mb
            probe_used = f"extrapolated(g={groups:.1f},mb={mb})"
    except Exception as e:
        return {**base, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    t_total = time.time() - t0

    chips = mesh_lib.chips(mesh)
    prog_flops = build_lib.model_flops(arch, shape)
    report = rl.RooflineReport(
        name=f"{arch_name}:{shape_name}", chips=chips,
        hlo_flops=metrics["flops"] * chips,
        hlo_bytes=metrics["bytes"] * chips,
        collective_bytes=float(sum(metrics["coll"].values())) * chips,
        model_flops=prog_flops,
        peak_flops=prog.peak_flops,
        per_collective={k: v * chips for k, v in metrics["coll"].items()},
        bytes_per_device=(main["mem"]["argument_bytes"]
                          + main["mem"]["temp_bytes"]))
    rec = {
        **base, "status": "ok", "chips": chips,
        "compile_s": round(t_total, 1), "main_compile_s": round(t_main, 1),
        "probe": probe_used,
        "hlo_flops": report.hlo_flops, "hlo_bytes": report.hlo_bytes,
        "collective_bytes": report.collective_bytes,
        "per_collective": report.per_collective,
        "model_flops": report.model_flops,
        "t_compute_s": report.t_compute, "t_memory_s": report.t_memory,
        "t_collective_s": report.t_collective,
        "bottleneck": report.bottleneck,
        "useful_flop_ratio": report.useful_flop_ratio,
        "roofline_fraction": report.roofline_fraction,
        "peak_flops": prog.peak_flops,
        "memory_analysis": main["mem"],
        "bytes_per_device": report.bytes_per_device,
    }
    if verbose:
        print(f"[{mesh_name}] {arch_name} x {shape_name}{tag}: "
              f"compute {rl.fmt_seconds(report.t_compute)}  "
              f"memory {rl.fmt_seconds(report.t_memory)}  "
              f"collective {rl.fmt_seconds(report.t_collective)}  "
              f"bound={report.bottleneck}  "
              f"useful={report.useful_flop_ratio:.2f}  "
              f"roofline={100 * report.roofline_fraction:.1f}%  "
              f"fit={report.bytes_per_device / 2**30:.1f}GB/dev  "
              f"({t_total:.0f}s, probe={probe_used})", flush=True)
    return rec


def all_cells():
    for arch in configs.list_archs():
        for shape in SHAPES:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="compile-pass only (multi-pod round)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])

    n_ok = n_skip = n_err = 0
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        for arch, shape in cells:
            path = os.path.join(args.out, f"{mesh_name}__{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        print(f"[{mesh_name}] {arch} x {shape}: cached",
                              flush=True)
                        n_ok += 1
                        continue
            rec = run_cell(arch, shape, multi_pod=multi_pod,
                           probes=not args.no_probes)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                n_ok += 1
            elif rec["status"] == "skipped":
                n_skip += 1
                print(f"[{mesh_name}] {arch} x {shape}: SKIP ({rec['reason']})",
                      flush=True)
            else:
                n_err += 1
                print(f"[{mesh_name}] {arch} x {shape}: ERROR "
                      f"{rec['error']}", flush=True)
    print(f"\ndry-run done: {n_ok} ok, {n_skip} skipped, {n_err} errors",
          flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
