"""End-to-end training driver with the fault-tolerance loop.

Runs REAL training at reduced scale on this host (--smoke / --steps), and is
the same code path a multi-host launch would use (jax.distributed.initialize
guarded behind --coordinator).

Fault-tolerance features exercised here:
  * checkpoint/restart: atomic async checkpoints every --ckpt-every steps;
    on start, resumes from the latest checkpoint (params+opt+step and the
    data-pipeline position).
  * preemption: SIGTERM/SIGINT trigger a final synchronous checkpoint before
    exit (the standard TPU-preemption grace-period protocol).
  * straggler watchdog: per-step wall-clock timeout -> checkpoint + abort
    (at fleet scale the scheduler then reschedules the job minus the bad
    host; here it demonstrates the mechanism).
  * elastic restart: checkpoints are topology-free (see train/checkpoint.py);
    restart with a different --mesh reshards automatically.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import engine as eng_lib
from repro.core.config import ShapeConfig, TrainConfig
from repro.data.pipeline import PipelineConfig, SyntheticTokens
from repro.launch import mesh as mesh_lib
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as Wmod
from repro.train import checkpoint as ckpt_lib
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--step-timeout", type=float, default=0.0)
    ap.add_argument("--coordinator", default="",
                    help="host:port for jax.distributed (multi-host)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator)

    arch = configs.get_arch(args.arch)
    if args.smoke:
        arch = configs.reduced(arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatches=args.microbatches, remat=args.remat,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                       step_timeout_s=args.step_timeout)
    eng = eng_lib.train_engine()

    is_audio = arch.family == "audio"
    schema = (Wmod.whisper_schema(arch, max_dec_pos=max(args.seq, 64))
              if is_audio else T.lm_schema(arch))
    params = prm.init_params(schema, jax.random.PRNGKey(tcfg.seed))
    state = init_train_state(params)

    mgr = ckpt_lib.CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts,
                                     async_save=tcfg.async_ckpt)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state = mgr.restore(state)
        start_step = int(jax.device_get(state["opt"]["step"]))
        print(f"resumed from step {start_step}", flush=True)

    pipe = SyntheticTokens(arch, shape, PipelineConfig(seed=tcfg.seed))
    step_fn = jax.jit(make_train_step(arch, eng, tcfg), donate_argnums=(0,))

    # --- preemption protocol -------------------------------------------------
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True
        print(f"signal {signum}: checkpoint-and-exit requested", flush=True)

    signal.signal(signal.SIGTERM, _handler)
    prev_int = signal.signal(signal.SIGINT, _handler)

    losses = []
    try:
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = jax.tree_util.tree_map(jnp.asarray, pipe.batch_at(step))
            state, metrics = step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            losses.append(loss)
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(jax.device_get(metrics['grad_norm'])):7.3f}  "
                  f"{dt * 1e3:7.1f} ms", flush=True)
            if tcfg.step_timeout_s and dt > tcfg.step_timeout_s:
                print(f"STRAGGLER: step took {dt:.1f}s > "
                      f"{tcfg.step_timeout_s:.1f}s; checkpointing and "
                      f"aborting for reschedule", flush=True)
                mgr.save(step + 1, state)
                mgr.wait()
                return 75                      # EX_TEMPFAIL: reschedule me
            if (step + 1) % tcfg.ckpt_every == 0:
                mgr.save(step + 1, state)
            if preempted["flag"]:
                mgr.save(step + 1, state)
                mgr.wait()
                print("preemption checkpoint complete", flush=True)
                return 75
    finally:
        signal.signal(signal.SIGINT, prev_int)
    mgr.save(args.steps, state)
    mgr.wait()
    if len(losses) >= 5:
        print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
              f"(improved={losses[-1] < losses[0]})", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
