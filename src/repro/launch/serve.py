"""Serving driver: quantize a model with the DPUV4E engine config and serve
batched requests (greedy decode) -- the small-scale executable twin of the
production decode program (launch/build.build_serve).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --new-tokens 16 --quant w8a8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import engine as eng_lib
from repro.core.config import EngineConfig
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as W
from repro.serve.engine import ServeEngine, throughput_probe


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--quant", default="w8a8",
                    choices=["none", "w8", "w8a8"])
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--baseline", action="store_true")
    args = ap.parse_args(argv)

    arch = configs.get_arch(args.arch)
    if args.smoke:
        arch = configs.reduced(arch)
    eng = EngineConfig(quant=args.quant, backend="ref",
                       kv_cache_dtype=args.kv,
                       baseline=args.baseline).resolved()

    schema = (W.whisper_schema(arch, max_dec_pos=256)
              if arch.family == "audio" else T.lm_schema(arch))
    params = prm.init_params(schema, jax.random.PRNGKey(0))
    engine = ServeEngine(arch, params, eng, batch_size=args.batch,
                         max_seq=args.prompt_len + args.new_tokens + 8)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, arch.vocab_size, size=args.prompt_len)
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"served {len(outs)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, quant={args.quant}, kv={args.kv})")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
