"""Production mesh construction + logical-axis helpers.

Meshes (TPU v5e):
  single-pod : (16, 16)     axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)  axes ("pod", "data", "model") = 512 chips

The "pod" axis composes with "data" for batch sharding ("dp" logical axis),
so multi-pod scaling is purely more data parallelism with a hierarchical
gradient reduction (intra-pod ICI reduce-scatter, inter-pod DCN all-reduce --
XLA derives the hierarchy from the nested spec).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import resolve_pspec


def make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with an AxisType guard: older jax (< 0.5) has neither
    `jax.sharding.AxisType` nor the `axis_types=` kwarg; newer jax defaults
    new axes to Auto anyway, so passing it explicitly is only done when the
    API exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh() -> Mesh:
    """1x1 mesh over the single CPU device (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


def chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def batch_axes(mesh: Mesh):
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return "data"


def act_pspec(mesh: Mesh, batch: int, seq_shard: bool = False) -> P:
    """[B, L, d] activation constraint: batch over dp (+ optional SP)."""
    dp = batch_axes(mesh)
    dp_size = (mesh.shape["data"] * mesh.shape.get("pod", 1))
    first = dp if batch % dp_size == 0 else None
    if seq_shard:
        return P(first, "model")
    return P(first)


def input_shardings(mesh: Mesh, batch_sds: dict, axes_tree: dict) -> dict:
    """Resolve configs.input_specs logical axes to NamedShardings."""
    out = {}
    for k, sds in batch_sds.items():
        out[k] = NamedSharding(
            mesh, resolve_pspec(mesh, sds.shape, axes_tree[k]))
    return out
