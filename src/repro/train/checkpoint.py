"""Fault-tolerant checkpointing.

Properties required at 1000-node scale, implemented here at single-host
scale with the same protocol:

  * ATOMIC: write into `step_XXXX.tmp/`, fsync, then os.rename -> a reader
    never sees a partial checkpoint; a crash mid-save leaves the previous
    checkpoint intact.
  * ASYNC: jax.device_get runs on the caller, file I/O on a daemon thread;
    training resumes while bytes hit disk (one outstanding save; back-to-back
    saves block on the previous).
  * ELASTIC: the manifest stores the logical tree (paths, shapes, dtypes) --
    restore() re-materializes onto whatever mesh/sharding the *current* run
    uses via device_put, so restarts may change topology (e.g. 256 -> 512
    chips) freely.  This is the elastic-scaling story: checkpoints are
    topology-free.
  * GC: keep the last `keep` checkpoints.
  * Multi-host extension (documented): each host writes
    `shard_<host>/leaf_*.npy` for its addressable shards; restore reassembles
    by global index.  The manifest format already carries everything needed.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_names(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in paths]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------

    def save(self, step: int, state: Any) -> None:
        self.wait()
        leaves, _ = _flatten(state)
        names = _leaf_names(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for i, (name, arr) in enumerate(zip(names, host_leaves)):
                fn = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append(
                    {"name": name, "file": fn, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `target`.

        `shardings`: optional matching pytree of NamedSharding -- leaves are
        device_put onto it (elastic reshard onto the current mesh).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(target)
        if len(manifest["leaves"]) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target has {len(leaves)}")
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves))
        out = []
        for rec, tgt, shd in zip(manifest["leaves"], leaves, shard_leaves):
            arr = np.load(os.path.join(path, rec["file"]))
            if list(arr.shape) != list(tgt.shape):
                raise ValueError(
                    f"{rec['name']}: checkpoint {arr.shape} vs {tgt.shape}")
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)
