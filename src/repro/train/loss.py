"""Losses: cross-entropy (+ z-loss), with an optional fused chunked-vocab
variant that never materializes the [B, L, V] logits in f32 (a §Perf
memory-term optimization for the 256k-vocab archs)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import probe
from repro.core.quant import QTensor


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> Tuple[jax.Array, dict]:
    """logits [B, L, V] (any float), labels [B, L] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    metrics = {"nll": loss,
               "accuracy": jnp.mean(jnp.argmax(logits, -1) == labels)}
    if z_loss > 0:
        zl = z_loss * jnp.mean(lse ** 2)
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics


def fused_ce_loss(x: jax.Array, emb, labels: jax.Array,
                  *, transpose_emb: bool, z_loss: float = 0.0,
                  chunk: int = 32768,
                  final_softcap: float = 0.0) -> Tuple[jax.Array, dict]:
    """CE from hidden states without a full [B, L, V] f32 materialization.

    x: [B, L, d]; emb: [V, d] (tied, transpose_emb=True) or [d, V] head.
    Scans vocab chunks, carrying running (max, sumexp, gold-logit).
    """
    b, l, d = x.shape
    if isinstance(emb, QTensor):
        emb_q, emb_s = emb.q, emb.scale
    else:
        emb_q, emb_s = emb, None
    v = emb_q.shape[0] if transpose_emb else emb_q.shape[1]
    nchunk = -(-v // chunk)
    vp = nchunk * chunk
    # Pad the vocab dim so every dynamic_slice start is in range (XLA clamps
    # out-of-range starts, which would silently alias the last chunk).
    if vp != v:
        pad = vp - v
        if transpose_emb:
            emb_q = jnp.pad(emb_q, ((0, pad), (0, 0)))
        else:
            emb_q = jnp.pad(emb_q, ((0, 0), (0, pad)))
        if emb_s is not None:
            emb_s = jnp.pad(emb_s.reshape(-1), (0, pad))
    xf = x.astype(jnp.float32).reshape(b * l, d)
    lab = labels.reshape(b * l)

    def body(carry, ci):
        m, s, gold = carry
        start = ci * chunk
        if transpose_emb:
            wc = jax.lax.dynamic_slice_in_dim(emb_q, start, chunk, axis=0)
            logits = xf @ wc.astype(jnp.float32).T
            if emb_s is not None:
                sc = jax.lax.dynamic_slice_in_dim(
                    emb_s.reshape(-1), start, chunk, axis=0)
                logits = logits * sc[None, :]
        else:
            wc = jax.lax.dynamic_slice_in_dim(emb_q, start, chunk, axis=1)
            logits = xf @ wc.astype(jnp.float32)
            if emb_s is not None:
                sc = jax.lax.dynamic_slice_in_dim(
                    emb_s.reshape(-1), start, chunk, axis=0)
                logits = logits * sc[None, :]
        if final_softcap > 0:
            logits = jnp.tanh(logits / final_softcap) * final_softcap
        vid = start + jnp.arange(chunk)
        logits = jnp.where(vid[None, :] < v, logits, -1e30)
        m2 = jnp.maximum(m, jnp.max(logits, axis=-1))
        s2 = s * jnp.exp(m - m2) + jnp.sum(jnp.exp(logits - m2[:, None]), -1)
        hit = (lab[:, None] == vid[None, :])
        gold2 = gold + jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        return (m2, s2, gold2), None

    init = (jnp.full((b * l,), -1e30, jnp.float32),
            jnp.zeros((b * l,), jnp.float32),
            jnp.zeros((b * l,), jnp.float32))
    # remat the chunk body: otherwise autodiff-through-scan saves every
    # chunk's logits as residuals and the "never materialize [B,L,V]" goal
    # is lost (observed: 13 GB/dev -> 162 GB/dev without this).
    body = jax.checkpoint(body)
    (m, s, gold), _ = probe.pscan(body, init, jnp.arange(nchunk))
    lse = m + jnp.log(s)
    nll = lse - gold
    loss = jnp.mean(nll)
    metrics = {"nll": loss}
    if z_loss > 0:
        zl = z_loss * jnp.mean(lse ** 2)
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics
