"""Train-step builder: loss, gradient accumulation, remat, optimizer update.

make_train_step() returns a pure function
    train_step(state, batch) -> (state, metrics)
suitable for jax.jit with sharding specs from the launcher.  `state` is
{"params", "opt": {"m", "v", "step"}}.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import probe
from repro.core.config import ArchConfig, EngineConfig, TrainConfig
from repro.models import transformer as T
from repro.models import whisper as W
from repro.train import loss as loss_lib
from repro.train import optim


def make_loss_fn(arch: ArchConfig, eng: EngineConfig, tcfg: TrainConfig,
                 act_spec=None) -> Callable:
    fused = tcfg.loss_chunk_vocab > 0 and arch.family != "audio"
    fwd = T.forward_scanned if tcfg.scan_layers else T.forward

    def loss_fn(params, batch):
        ts = tcfg.triangle_skip
        if arch.family == "audio":
            logits, aux = W.forward(params, batch, arch, eng,
                                    act_spec=act_spec, remat=tcfg.remat)
            loss, metrics = loss_lib.cross_entropy(
                logits, batch["labels"], z_loss=tcfg.z_loss)
        elif fused:
            hidden, aux = fwd(params, batch, arch, eng,
                              act_spec=act_spec, remat=tcfg.remat,
                              triangle_skip=ts, return_hidden=True)
            emb = params["embed"] if arch.tie_embeddings else params["head"]
            loss, metrics = loss_lib.fused_ce_loss(
                hidden, emb, batch["labels"],
                transpose_emb=arch.tie_embeddings, z_loss=tcfg.z_loss,
                chunk=tcfg.loss_chunk_vocab,
                final_softcap=arch.final_softcap)
        else:
            logits, aux = fwd(params, batch, arch, eng,
                              act_spec=act_spec, remat=tcfg.remat,
                              triangle_skip=ts)
            loss, metrics = loss_lib.cross_entropy(
                logits, batch["labels"], z_loss=tcfg.z_loss)
        loss = loss + 0.01 * aux
        metrics["aux_loss"] = aux
        return loss, metrics

    return loss_fn


def _microbatch(batch: dict, n: int, i) -> dict:
    def slice_one(x):
        mb = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree_util.tree_map(slice_one, batch)


def make_train_step(arch: ArchConfig, eng: EngineConfig, tcfg: TrainConfig,
                    act_spec=None) -> Callable:
    loss_fn = make_loss_fn(arch, eng, tcfg, act_spec)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            n = tcfg.microbatches

            def acc_step(carry, i):
                gacc, lacc = carry
                (l, m), g = grad_fn(params, _microbatch(batch, n, i))
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return (gacc, lacc + l), m

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = probe.pscan(
                acc_step, (gzero, jnp.zeros((), jnp.float32)),
                jnp.arange(n))
            grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
            loss = lsum / n
            metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x, 0), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = optim.adamw_update(
            params, grads, state["opt"], tcfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(params) -> dict:
    return {"params": params, "opt": optim.init_opt_state(params)}
