"""AdamW + gradient clipping + LR schedule, with ZeRO-1 sharding specs.

ZeRO-1 here is *declarative*: the Adam moments get sharding specs with an
extra `data`-axis sharding on their largest replicated dim.  Under GSPMD the
optimizer update then runs on moment shards (grads are reduce-scattered into
the update and the fresh params all-gathered), which is exactly the ZeRO-1
collective schedule -- no manual collectives needed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import TrainConfig


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state, cfg: TrainConfig
                 ) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2 and wd > 0:          # decay matrices only
            delta = delta + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


# ---------------------------------------------------------------------------
# ZeRO-1 sharding specs for the moments
# ---------------------------------------------------------------------------

def zero1_pspec(param_pspec: P, shape, mesh: Mesh) -> P:
    """Add a `data`-axis sharding on the largest still-replicated dim."""
    if "data" not in mesh.axis_names:
        return param_pspec
    used = set()
    for e in param_pspec:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    if "data" in used:
        return param_pspec                       # fsdp param: already sharded
    dsize = mesh.shape["data"]
    entries = list(param_pspec) + [None] * (len(shape) - len(param_pspec))
    best, best_dim = -1, -1
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dsize == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim < 0:
        return param_pspec
    entries[best_dim] = "data"
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def opt_state_pspecs(param_pspecs, param_shapes, mesh: Mesh,
                     zero1: bool = True) -> dict:
    if zero1:
        mom = jax.tree_util.tree_map(
            lambda sp, sh: zero1_pspec(sp, sh.shape, mesh),
            param_pspecs, param_shapes)
    else:
        mom = param_pspecs
    return {"m": mom, "v": mom, "step": P()}
