"""Design-space exploration: the paper's parallelism model, re-derived for TPU.

Part 1 (paper-faithful, Section IV-A, Table I, Eq. 1-2):
  The AIE MAC atom is a 1x16x8 INT8 GEMM: a (1x16) feature vector against a
  (16x8) weight tile. Loading is bandwidth-limited (BW_f, BW_w bits/cycle), so
  the minimum data-reuse factors that reach compute-to-communication (CTC) >= 1
  are

      FMReuse >= fm_bits / BW_f        (feature vector reused across kernels)
      WTReuse >= wt_bits / BW_w        (weight tile reused across pixels)

  which induce the workload constraints  OC >= 8 * FMReuse  and
  IH*IW >= WTReuse  (Eq. 2).  Table I enumerates (BW_f, BW_w) in {16,32}^2.
  DPUV4E picks BW_f=32, BW_w=16 -> FMReuse=4, WTReuse=64, OC=32, IH*IW=64.

Part 2 (paper-faithful, Section IV-B2, Eq. 3-4):
  The ACC core's partial-sum stack must fit the 16 memory banks shared by an
  ACC/NL pair (64 KB).  With ping-pong buffering this bounds IW <= 32 for
  IH=4; DPUV4E selects IH=4, IW=16.

Part 3 (TPU adaptation):
  The same closed-form reasoning with TPU constants.  The MXU atom is a
  128x128 systolic matmul; HBM->VMEM takes the role of PL->AIE streams and the
  VMEM scratch budget takes the role of the ACC bank budget.  For a blocked
  GEMM (BM, BN, BK):

      weight-stationary reuse of an activation block  = BN   (paper: FMReuse*8 = OC)
      activation-stationary reuse of a weight block   = BM   (paper: WTReuse = IH*IW)
      psum scratch                                     = BM*BN*4 B  (paper: PsumStack)

  solve_conv_blocks() maximizes CTC under the VMEM constraint; the result
  feeds kernels/ops.py as the default block shapes.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

# ---------------------------------------------------------------------------
# Part 1: paper Table I
# ---------------------------------------------------------------------------

# AIE MAC atom (INT8): 1 pixel x 16 IC x 8 OC.
ATOM_PIXELS, ATOM_IC, ATOM_OC = 1, 16, 8
FM_BITS = ATOM_PIXELS * ATOM_IC * 8          # 128-bit feature vector
WT_BITS = ATOM_IC * ATOM_OC * 8              # 1024-bit weight tile


@dataclass(frozen=True)
class ReuseRow:
    bw_f: int          # bits/cycle for feature maps
    bw_w: int          # bits/cycle for weights
    fm_reuse: int
    wt_reuse: int
    oc: int            # induced minimum OC
    ihw: int           # induced minimum IH*IW
    ctc: float         # compute-to-communication at the minimum reuse


def solve_reuse(bw_f: int, bw_w: int) -> ReuseRow:
    """Minimum reuse factors achieving CTC >= 1 at the given bandwidth split."""
    fm_reuse = math.ceil(FM_BITS / bw_f)
    wt_reuse = math.ceil(WT_BITS / bw_w)
    # Eq. 1: loads (cycles) and compute time at those reuse factors.
    fm_load = wt_reuse * FM_BITS / bw_f
    wt_load = fm_reuse * WT_BITS / bw_w
    t_mac = fm_reuse * wt_reuse
    ctc = t_mac / max(fm_load, wt_load)
    return ReuseRow(bw_f, bw_w, fm_reuse, wt_reuse,
                    oc=ATOM_OC * fm_reuse, ihw=wt_reuse, ctc=ctc)


def table1() -> List[ReuseRow]:
    """Reproduce paper Table I: reuse requirements under different bandwidths."""
    return [solve_reuse(bw_f, bw_w)
            for bw_f in (16, 32) for bw_w in (16, 32)]


def dpuv4e_choice() -> ReuseRow:
    """The paper's selected design point (BW_f=32, BW_w=16)."""
    return solve_reuse(32, 16)


# ---------------------------------------------------------------------------
# Part 2: paper Eq. 3-4 (ACC/NL buffer sizing)
# ---------------------------------------------------------------------------

AIE_BANKS_PER_PAIR = 16              # 8 banks/core, ACC+NL pair
AIE_BANK_BYTES = 256 * 16            # 256 words x 128-bit
AIE_PAIR_BYTES = AIE_BANKS_PER_PAIR * AIE_BANK_BYTES   # 64 KB


@dataclass(frozen=True)
class AccBufferPlan:
    ih: int
    iw: int
    oc: int
    psum_bytes: int
    accout_bytes: int
    bias_bytes: int
    nlout_bytes: int
    total_bytes: int
    fits: bool


def acc_buffer_plan(ih: int, iw: int, oc: int = 32,
                    pingpong: bool = True) -> AccBufferPlan:
    """Paper Eq. 3: buffer sizes for an ACC/NL pair at a given (IH, IW, OC)."""
    psum = ih * iw * oc * 4          # 4 B intermediate accumulation
    accout = ih * iw * oc * 4
    bias = oc * 4
    nlout = ih * iw * oc * 1         # 1 B quantized output
    mult = 2 if pingpong else 1
    # PsumStack is single-buffered; the *other* buffers ping-pong (Eq. 3 s.t.).
    total = psum + mult * (accout + bias + nlout)
    return AccBufferPlan(ih, iw, oc, psum, accout, bias, nlout, total,
                         fits=total <= AIE_PAIR_BYTES)


def max_iw(ih: int = 4, oc: int = 32) -> int:
    """Paper Eq. 4: largest IW whose AccOut ping-pong fits 2 banks (16 KB)."""
    # AccOutBuf = IH * IW * OC * 4B <= 2 * 8KB
    return (2 * 8 * 1024) // (ih * oc * 4)


# ---------------------------------------------------------------------------
# Part 3: TPU tile solver (the adaptation)
# ---------------------------------------------------------------------------

# TPU v5e single-chip constants (assignment-specified + public specs).
PEAK_BF16_FLOPS = 197e12
PEAK_INT8_OPS = 394e12
HBM_BW = 819e9                        # bytes/s
ICI_BW = 50e9                         # bytes/s per link
MXU_DIM = 128
VMEM_BYTES = 16 * 1024 * 1024         # conservative usable VMEM budget
VMEM_TARGET = int(VMEM_BYTES * 0.75)  # leave headroom for pipeline overhead


@dataclass(frozen=True)
class TileChoice:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    # Correspondences with the paper's model:
    fm_reuse: int       # = BN / ATOM-OC analogue: activation-block reuse count
    wt_reuse: int       # = BM: weight-block reuse count
    ctc: float          # compute time / HBM load time for one output block
    mxu_util: float     # fraction of MXU lanes covered by the block shape


def _block_vmem(bm: int, bn: int, bk: int, in_bytes: int, out_bytes: int) -> int:
    # Double-buffered operand blocks (Pallas pipelines ping-pong automatically:
    # the paper's PingPong factor of 2 in Eq. 3) + revolving int32 accumulator
    # (the paper's single-buffered PsumStack) + double-buffered output block.
    return (2 * (bm * bk + bk * bn) * in_bytes
            + bm * bn * 4
            + 2 * bm * bn * out_bytes)


def _ctc(bm: int, bn: int, bk: int, k: int, in_bytes: int, int8: bool) -> float:
    """Compute-vs-load ratio for producing one (bm x bn) output block."""
    flops = 2.0 * bm * bn * k
    peak = PEAK_INT8_OPS if int8 else PEAK_BF16_FLOPS
    t_compute = flops / peak
    load_bytes = (bm * k + k * bn) * in_bytes     # full K sweep per block
    t_load = load_bytes / HBM_BW
    return t_compute / max(t_load, 1e-30)


def solve_conv_blocks(m: int, n: int, k: int,
                      in_dtype_bytes: int = 1,
                      out_dtype_bytes: int = 4,
                      vmem_budget: int = VMEM_TARGET) -> TileChoice:
    """Pick (BM, BN, BK) for the conv_pe kernel.

    Mirrors the paper's DSE: maximize CTC (their Eq. 1 objective), subject to
    the scratch-memory constraint (their Eq. 3-4), with MXU-aligned shapes
    (their bank-alignment requirement).
    """
    int8 = in_dtype_bytes == 1
    candidates = []
    def _aligned(dim_cap: int) -> List[int]:
        vals = []
        v = MXU_DIM
        while v <= max(dim_cap, MXU_DIM):
            vals.append(min(v, max(_round_up(dim_cap, MXU_DIM), MXU_DIM)))
            if v >= dim_cap:
                break
            v *= 2
        return sorted(set(vals))

    for bm in _aligned(min(m, 1024)):
        for bn in _aligned(min(n, 1024)):
            for bk in _aligned(min(k, 2048)):
                vm = _block_vmem(bm, bn, bk, in_dtype_bytes, out_dtype_bytes)
                if vm > vmem_budget:
                    continue
                ctc = _ctc(bm, bn, bk, k, in_dtype_bytes, int8)
                # Prefer: CTC first, then larger BK (fewer revolving-acc
                # epilogue stalls: the paper's cascade-depth argument), then
                # balanced BM/BN.
                candidates.append((ctc, bk, -abs(bm - bn), bm, bn))
    if not candidates:
        bm = bn = bk = MXU_DIM
        return TileChoice(bm, bn, bk,
                          _block_vmem(bm, bn, bk, in_dtype_bytes, out_dtype_bytes),
                          fm_reuse=bn, wt_reuse=bm,
                          ctc=_ctc(bm, bn, bk, k, in_dtype_bytes, int8),
                          mxu_util=1.0)
    ctc, bk, _, bm, bn = max(candidates)
    return TileChoice(
        bm, bn, bk,
        _block_vmem(bm, bn, bk, in_dtype_bytes, out_dtype_bytes),
        fm_reuse=bn, wt_reuse=bm, ctc=ctc,
        mxu_util=min(bm, MXU_DIM) * min(bn, MXU_DIM) / (MXU_DIM * MXU_DIM))


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# DWC PE efficiency model (paper Fig. 8)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DwcPoint:
    kernel: int
    stride: int
    load_cycles: float
    mac_cycles: float
    ctc: float


def dwc_ctc(kernel: int, stride: int) -> DwcPoint:
    """Analytic CTC model of the DWC PE (paper Fig. 8 reproduction).

    One MAC-RACNL iteration produces 2(OH) x 8(OW) x 16(C) outputs
    (= 8 atomic DWC computations).  Per atomic computation (1 OH x 2 OW x 16 C):
      * MAC cycles: ceil(kernel/2)*2 vector MACs per output row of the window,
        kernel rows -> paper's example: k=3,s=1 -> 12 cycles.
      * FM load: the input tile is (kernel) rows x (kernel + stride) cols x 16C
        int8, streamed over a 32-bit channel, amortized across the 8-atomic
        iteration via row overlap (rows shared between vertically adjacent
        outputs when stride < kernel).
    """
    # MAC cycles per atomic op (1 OH x 2 OW x 16 C): kernel rows, each row
    # needs ceil(kernel/2) dual-vector MAC issues (two 16-lane int8 MACs per
    # cycle with zero-padded weight alignment), x2 for the two output pixels.
    # Paper's example: k=3, s=1 -> 3 * 2 * 2 = 12 cycles.  (Fig. 7)
    mac = kernel * math.ceil(kernel / 2) * 2
    atoms = 8
    mac_cycles = mac * atoms

    # Iteration output tile: 2 x 8 output pixels -> input footprint
    ih = (2 - 1) * stride + kernel
    iw = (8 - 1) * stride + kernel
    fm_bytes = ih * iw * 16                     # int8, 16 channels
    wt_bytes = kernel * kernel * 16             # loaded once per iteration set
    load_cycles = (fm_bytes + wt_bytes) / 4.0   # 32-bit/cycle stream
    return DwcPoint(kernel, stride, load_cycles, mac_cycles,
                    ctc=mac_cycles / max(load_cycles, 1e-9))


def fig8_sweep() -> List[DwcPoint]:
    return [dwc_ctc(k, s) for k in (3, 5, 7) for s in (1, 2)]


# ---------------------------------------------------------------------------
# Low-channel unit utilization model (paper Section V-B)
# ---------------------------------------------------------------------------

def conv_pe_utilization(ic: int, oc: int,
                        ic_par: int = 64, oc_par: int = 128) -> float:
    """Utilization of the graph-level Conv PE on a layer with (IC, OC).

    Paper: ResNet50 stage-0 (IC=3, OC=64) on 64(IC) x 128(OC) parallelism
    -> 13.1 % when accounting for the 7x7 kernel's IC*K*K=147 effective
    contraction against the 64-way IC cascade granularity.
    """
    kk = 49  # 7x7 stage-0 kernel: effective contraction ic*k*k
    eff_ic = ic * kk
    ic_util = eff_ic / (_round_up(eff_ic, ic_par))
    oc_util = oc / (_round_up(oc, oc_par))
    return ic_util * oc_util


def mxu_utilization(ic: int, oc: int, kk: int = 1,
                    mxu: int = MXU_DIM) -> float:
    """TPU analogue: MXU lane coverage of a conv lowered to GEMM."""
    eff_k = ic * kk
    return (min(eff_k, mxu) / mxu) * (min(oc, mxu) / mxu)
