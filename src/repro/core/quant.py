"""INT8 symmetric quantization (Vitis-AI-style) for the DPUV4E engines.

The paper requires all models to be quantized to INT8 before running on the
DPU (Section III-A).  We implement the TPU-side equivalent:

  * per-output-channel symmetric weight quantization (scale = absmax/127),
  * per-tensor (static, calibrated) or per-token (dynamic) activation
    quantization,
  * int32 accumulation with a fused dequant -> bias -> activation -> requant
    epilogue (the NL core's job, Section IV-B2),
  * per-group asymmetric int4 weight-only packing (`Q4Tensor`) for the
    weight-bandwidth-bound LM decode GEMMs: two nibbles per byte along the
    reduction dim, one (scale, zero) pair per `group_size` rows per output
    channel, dequantized in-register by the Conv-PE kernel.

All functions are jit-safe and shard-transparent (elementwise + reductions).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
INT4_LEVELS = 15.0                  # asymmetric codes in [0, 15]


class QTensor(NamedTuple):
    """A quantized tensor: int8 values + float32 scale (broadcastable)."""
    q: jax.Array          # int8
    scale: jax.Array      # f32, shape broadcastable against q along quant axis

    @property
    def shape(self):
        return self.q.shape

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)


class Q4Tensor(NamedTuple):
    """An int4 weight-only packed tensor (XEGEMM_INT4-style).

    Two 4-bit codes per byte along the reduction dim K (row 2i in the low
    nibble of byte-row i, row 2i+1 in the high nibble), with one asymmetric
    (scale, zero) pair per `group_size` K-rows per output column:

        w[k, n] = code[k, n] * scale[k // gs, n] + zero[k // gs, n]

    All fields are arrays, so the container is a plain jax pytree (jit /
    device_put / sharding transparent); the group size is derived from the
    shapes, which keeps it static under tracing.
    """
    packed: jax.Array     # uint8 [K // 2, N], two codes per byte
    scale: jax.Array      # f16 [K // gs, N]
    zero: jax.Array       # f16 [K // gs, N]

    @property
    def shape(self):
        return (2 * self.packed.shape[0],) + self.packed.shape[1:]

    @property
    def group_size(self) -> int:
        return (2 * self.packed.shape[0]) // self.scale.shape[0]

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        k, n = self.shape
        g = self.scale.shape[0]
        codes = unpack_int4(self.packed).reshape(g, k // g, n)
        w = (codes.astype(jnp.float32) * self.scale.astype(jnp.float32)[:, None]
             + self.zero.astype(jnp.float32)[:, None])
        return w.reshape(k, n).astype(dtype)


def snap_group_size(k: int, group_size: int) -> int:
    """Largest divisor of K that is <= group_size and even (nibble pairs
    never straddle a group boundary).  K must be even."""
    if k % 2:
        raise ValueError(f"int4 packing needs an even reduction dim, got {k}")
    gs = math.gcd(int(group_size), k)
    if gs % 2:
        gs = math.gcd(2 * gs, k)    # K even => this lands on an even divisor
    return gs


def unpack_int4(packed: jax.Array) -> jax.Array:
    """[K//2, N] packed bytes -> [K, N] int32 codes in [0, 15]."""
    low = (packed & 0xF).astype(jnp.int32)
    high = (packed >> 4).astype(jnp.int32)
    k2, n = packed.shape
    return jnp.stack([low, high], axis=1).reshape(2 * k2, n)


def pack_int4(w: jax.Array, group_size: int = 64) -> Q4Tensor:
    """Per-group asymmetric int4 packing of a [K, N] GEMM weight.

    scale = (max - min) / 15 and zero = min per (group, column), so the
    codes span the full [0, 15] range of each group.  Scales and zeros are
    stored f16 -- with the default group of 64 that prices the container at
    ~0.55x of the int8 + per-channel-scale layout.
    """
    if w.ndim != 2:
        raise ValueError(f"pack_int4 expects a 2-D GEMM weight, got {w.shape}")
    k, n = w.shape
    gs = snap_group_size(k, group_size)
    g = k // gs
    wg = w.astype(jnp.float32).reshape(g, gs, n)
    lo = jnp.min(wg, axis=1)
    hi = jnp.max(wg, axis=1)
    # Round scale/zero to their stored f16 values BEFORE coding, so the codes
    # minimize error against exactly what dequant will multiply/add.
    scale = jnp.maximum(((hi - lo) / INT4_LEVELS).astype(jnp.float16),
                        jnp.float16(1e-6))
    zero = lo.astype(jnp.float16)
    s32 = scale.astype(jnp.float32)[:, None]
    z32 = zero.astype(jnp.float32)[:, None]
    codes = jnp.clip(jnp.round((wg - z32) / s32), 0, 15)
    codes = codes.reshape(k, n).astype(jnp.uint8)
    packed = (codes[0::2] | (codes[1::2] << 4)).astype(jnp.uint8)
    return Q4Tensor(packed, scale, zero)


def container_nbytes(w) -> int:
    """Weight-container bytes as shipped to the PE (QTensor / Q4Tensor /
    raw array)."""
    if isinstance(w, (QTensor, Q4Tensor)):
        return sum(int(a.size) * a.dtype.itemsize for a in w)
    return int(w.size) * w.dtype.itemsize


def _absmax(x: jax.Array, axis, keepdims=True) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)


def quantize(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Symmetric int8 quantization.

    axis=None   -> per-tensor scale.
    axis=k      -> per-channel scales along all dims *except* k reduced;
                   i.e. one scale per index of dim k (weights: axis=out_dim).
    """
    if axis is None:
        amax = _absmax(x, axis=None, keepdims=False)
        scale = jnp.maximum(amax / INT8_MAX, 1e-8)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))
    axis = axis % x.ndim
    red = tuple(i for i in range(x.ndim) if i != axis)
    amax = _absmax(x, axis=red, keepdims=True)
    scale = jnp.maximum(amax / INT8_MAX, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))


def quantize_act_dynamic(x: jax.Array, per_token: bool = True) -> QTensor:
    """Dynamic activation quantization: scale per leading-dims row (token)."""
    if per_token:
        amax = _absmax(x, axis=-1, keepdims=True)
    else:
        amax = _absmax(x, axis=None, keepdims=False)
    scale = jnp.maximum(amax / INT8_MAX, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))


def quantize_static(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize with a pre-calibrated scale; returns int8 values only."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def fake_quant(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    """Quantize-dequantize (QAT-style straight-through value)."""
    qt = quantize(x, axis)
    return qt.dequant(x.dtype)


class Calibrator:
    """Running absmax calibration over representative batches (per-tensor)."""

    def __init__(self):
        self.amax = {}

    def observe(self, name: str, x: jax.Array) -> None:
        v = float(jnp.max(jnp.abs(x)))
        self.amax[name] = max(self.amax.get(name, 0.0), v)

    def scales(self) -> dict:
        return {k: max(v / INT8_MAX, 1e-8) for k, v in self.amax.items()}


# ---------------------------------------------------------------------------
# Weight-tree quantization: walk a param pytree and quantize matmul weights.
# ---------------------------------------------------------------------------

def quantize_param_tree(params, predicate=None):
    """Quantize every rank>=2 float leaf to (int8, scale) along its last dim.

    Returns a pytree of the same structure where quantized leaves become
    QTensor namedtuples.  `predicate(path, leaf)` may veto quantization
    (e.g. embeddings, norm scales, conv depthwise taps stay fp).
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        keep = (
            leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and (predicate is None or predicate(path, leaf))
        )
        out.append(quantize(leaf, axis=leaf.ndim - 1) if keep else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def requantize(acc_i32: jax.Array, in_scale: jax.Array, w_scale: jax.Array,
               out_scale: Optional[jax.Array] = None) -> jax.Array:
    """int32 accumulator -> float (or int8 when out_scale given)."""
    x = acc_i32.astype(jnp.float32) * in_scale * w_scale
    if out_scale is None:
        return x
    q = jnp.clip(jnp.round(x / out_scale), -127, 127)
    return q.astype(jnp.int8)
