"""INT8 symmetric quantization (Vitis-AI-style) for the DPUV4E engines.

The paper requires all models to be quantized to INT8 before running on the
DPU (Section III-A).  We implement the TPU-side equivalent:

  * per-output-channel symmetric weight quantization (scale = absmax/127),
  * per-tensor (static, calibrated) or per-token (dynamic) activation
    quantization,
  * int32 accumulation with a fused dequant -> bias -> activation -> requant
    epilogue (the NL core's job, Section IV-B2).

All functions are jit-safe and shard-transparent (elementwise + reductions).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


class QTensor(NamedTuple):
    """A quantized tensor: int8 values + float32 scale (broadcastable)."""
    q: jax.Array          # int8
    scale: jax.Array      # f32, shape broadcastable against q along quant axis

    @property
    def shape(self):
        return self.q.shape

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)


def _absmax(x: jax.Array, axis, keepdims=True) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)


def quantize(x: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Symmetric int8 quantization.

    axis=None   -> per-tensor scale.
    axis=k      -> per-channel scales along all dims *except* k reduced;
                   i.e. one scale per index of dim k (weights: axis=out_dim).
    """
    if axis is None:
        amax = _absmax(x, axis=None, keepdims=False)
        scale = jnp.maximum(amax / INT8_MAX, 1e-8)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))
    axis = axis % x.ndim
    red = tuple(i for i in range(x.ndim) if i != axis)
    amax = _absmax(x, axis=red, keepdims=True)
    scale = jnp.maximum(amax / INT8_MAX, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))


def quantize_act_dynamic(x: jax.Array, per_token: bool = True) -> QTensor:
    """Dynamic activation quantization: scale per leading-dims row (token)."""
    if per_token:
        amax = _absmax(x, axis=-1, keepdims=True)
    else:
        amax = _absmax(x, axis=None, keepdims=False)
    scale = jnp.maximum(amax / INT8_MAX, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))


def quantize_static(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize with a pre-calibrated scale; returns int8 values only."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def fake_quant(x: jax.Array, axis: Optional[int] = None) -> jax.Array:
    """Quantize-dequantize (QAT-style straight-through value)."""
    qt = quantize(x, axis)
    return qt.dequant(x.dtype)


class Calibrator:
    """Running absmax calibration over representative batches (per-tensor)."""

    def __init__(self):
        self.amax = {}

    def observe(self, name: str, x: jax.Array) -> None:
        v = float(jnp.max(jnp.abs(x)))
        self.amax[name] = max(self.amax.get(name, 0.0), v)

    def scales(self) -> dict:
        return {k: max(v / INT8_MAX, 1e-8) for k, v in self.amax.items()}


# ---------------------------------------------------------------------------
# Weight-tree quantization: walk a param pytree and quantize matmul weights.
# ---------------------------------------------------------------------------

def quantize_param_tree(params, predicate=None):
    """Quantize every rank>=2 float leaf to (int8, scale) along its last dim.

    Returns a pytree of the same structure where quantized leaves become
    QTensor namedtuples.  `predicate(path, leaf)` may veto quantization
    (e.g. embeddings, norm scales, conv depthwise taps stay fp).
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        keep = (
            leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and (predicate is None or predicate(path, leaf))
        )
        out.append(quantize(leaf, axis=leaf.ndim - 1) if keep else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def requantize(acc_i32: jax.Array, in_scale: jax.Array, w_scale: jax.Array,
               out_scale: Optional[jax.Array] = None) -> jax.Array:
    """int32 accumulator -> float (or int8 when out_scale given)."""
    x = acc_i32.astype(jnp.float32) * in_scale * w_scale
    if out_scale is None:
        return x
    q = jnp.clip(jnp.round(x / out_scale), -127, 127)
    return q.astype(jnp.int8)
