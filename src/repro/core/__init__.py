"""Core: the paper's contribution (DSE, quantization, engines, roofline)."""
