"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs      / (chips * peak FLOP/s)
    memory term     = HLO_bytes      / (chips * HBM bandwidth)
    collective term = collective_B   / (chips * ICI link bandwidth)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are NOT in cost_analysis, so we parse the (optimized) HLO text and sum the
result-shape sizes of every collective op (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e constants (per chip).
PEAK_BF16 = 197e12          # FLOP/s
PEAK_INT8 = 394e12          # OP/s
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# e.g.  "bf16[256,4096,512]{2,1,0}"  or  "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# op lines:  "%all-reduce.42 = bf16[...] all-reduce(...)"
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum collective result-shape bytes per collective kind.

    '-start' ops are counted; their '-done' twins are skipped to avoid double
    counting async pairs.
    """
    totals: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # Result shape(s): everything between '=' and the op name.
        head = line.split("=", 1)[1].split(kind)[0]
        for sm in _SHAPE_RE.finditer(head):
            totals[kind] += _shape_bytes(sm.group(1), sm.group(2))
    return totals


@dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float                  # 6*N*D (dense) / 6*N_active*D (MoE)
    peak_flops: float = PEAK_BF16
    per_collective: Dict[str, int] = field(default_factory=dict)
    bytes_per_device: float = 0.0       # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step would achieve if it ran at
        the bound given by the dominant term (MFU-at-bound)."""
        if self.t_bound <= 0:
            return 0.0
        return self.model_flops / (self.t_bound * self.chips * self.peak_flops)

    def row(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
        }


def _cost_get(cost, key: str) -> float:
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


def report_from_compiled(name: str, compiled, hlo_text: str,
                         chips: int, model_flops: float,
                         peak_flops: float = PEAK_BF16) -> RooflineReport:
    """`hlo_text` must be the POST-SPMD module (compiled.as_text()):
    collectives only exist after partitioning.  cost_analysis() of the
    compiled artifact reports the PER-DEVICE module, so flops/bytes/
    collective bytes are scaled by `chips` to make the report global --
    the three terms then divide back by chips per the assignment formulas."""
    cost = compiled.cost_analysis()
    flops = _cost_get(cost, "flops") * chips
    byts = _cost_get(cost, "bytes accessed") * chips
    per = {k: v * chips for k, v in parse_collective_bytes(hlo_text).items()}
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    + getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        name=name, chips=chips, hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(sum(per.values())), model_flops=model_flops,
        peak_flops=peak_flops, per_collective=per, bytes_per_device=mem)


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:.3f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.3f}ms"
    return f"{s * 1e6:.1f}us"


def fmt_table(reports) -> str:
    hdr = (f"{'cell':<38}{'chips':>6}{'compute':>12}{'memory':>12}"
           f"{'collect':>12}{'bound':>11}{'useful':>8}{'roofl%':>8}{'GB/dev':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.name:<38}{r.chips:>6}"
            f"{fmt_seconds(r.t_compute):>12}{fmt_seconds(r.t_memory):>12}"
            f"{fmt_seconds(r.t_collective):>12}{r.bottleneck:>11}"
            f"{r.useful_flop_ratio:>8.2f}{100 * r.roofline_fraction:>7.1f}%"
            f"{r.bytes_per_device / 2**30:>8.2f}")
    return "\n".join(lines)
