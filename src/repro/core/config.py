"""Configuration dataclasses for the DPUV4E-on-TPU framework.

Three config families:
  * ArchConfig   -- an LM-family architecture (the assigned arch pool).
  * CNNConfig    -- a CNN from the paper's own evaluation zoo (Table III/IV).
  * EngineConfig -- the DPUV4E engine feature set (the paper's technique),
                    threaded through every model.
  * ShapeConfig  -- an assigned (seq_len, global_batch, kind) input shape.
  * TrainConfig  -- optimizer / schedule / fault-tolerance knobs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# LM architectures
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention variants -------------------------------------------------
    qkv_bias: bool = False
    # Per-layer block pattern, cycled: entries in
    # {"global", "local", "recurrent", "mamba"}.
    block_pattern: Tuple[str, ...] = ("global",)
    local_window: int = 4096
    attn_softcap: float = 0.0        # gemma2 attention-logit softcap (0 = off)
    final_softcap: float = 0.0       # gemma2 final-logit softcap (0 = off)
    rope_theta: float = 10000.0
    mrope: bool = False              # qwen2-vl multimodal RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # --- MLP ----------------------------------------------------------------
    mlp_act: str = "silu"            # silu -> SwiGLU, gelu -> GeGLU
    mlp_gated: bool = True           # False: plain up/act/down (nemotron)
    tie_embeddings: bool = True

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25

    # --- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0               # mamba1 d_state
    ssm_expand: int = 2              # mamba d_inner = expand * d_model
    conv_kernel: int = 4             # mamba / RG-LRU temporal conv width
    lru_width: int = 0               # RG-LRU recurrent width (0 -> d_model)

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # stub frontend emits this many frames
    cross_attention: bool = False

    # --- modality frontend (stubbed per assignment) --------------------------
    frontend: str = ""               # "" | "audio_stub" | "vision_stub"

    # --- norms / misc ---------------------------------------------------------
    norm_eps: float = 1e-6
    post_norms: bool = False         # gemma2-style pre+post block norms
    emb_scale: bool = False          # gemma2 scales embeddings by sqrt(d_model)
    max_seq_len: int = 524288        # RoPE table cap

    # --- paper-technique applicability metadata ------------------------------
    subquadratic: bool = False       # may run long_500k

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("hybrid",) and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived -------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += v * d
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "mamba":
                di, st = self.d_inner, self.ssm_state
                dtr = -(-d // 16)                   # mamba dt_rank
                n += d * di * 2                     # in_proj (x, z)
                n += di * self.conv_kernel + di     # depthwise conv
                n += di * (dtr + 2 * st)            # x_proj
                n += dtr * di + di                  # dt_proj + bias
                n += di * st + 2 * di               # A_log, D, dt_bias
                n += di * d                         # out_proj
            elif kind == "recurrent":
                w = self.lru_width
                n += d * w * 2 + w * self.conv_kernel + w * d + 3 * w
            else:                                   # attention
                n += d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
            if kind != "mamba" and ff > 0:
                nmat = 3 if self.mlp_gated else 2
                if self.is_moe:
                    n += self.n_experts * 3 * d * ff + d * self.n_experts
                else:
                    n += nmat * d * ff
            n += 2 * d                              # norms
        for _ in range(self.encoder_layers):
            n += 4 * d * d + 3 * d * ff + 2 * d
            if self.cross_attention:
                n += 4 * d * d
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        total = self.param_count()
        dense_ff = self.n_layers * self.topk * 3 * d * ff
        all_ff = self.n_layers * self.n_experts * 3 * d * ff
        return total - all_ff + dense_ff


# ---------------------------------------------------------------------------
# CNN zoo (the paper's own evaluation models)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvSpec:
    kind: str                        # conv | dwc | pool | add_branch
    out_ch: int = 0
    kernel: int = 3
    stride: int = 1
    repeat: int = 1
    expand: int = 0                  # inverted-residual expansion factor


@dataclass(frozen=True)
class CNNConfig:
    name: str
    input_hw: int
    input_ch: int
    stem_kernel: int
    stem_stride: int
    stem_ch: int
    stages: Tuple[ConvSpec, ...]
    num_classes: int = 1000
    gops: float = 0.0                # paper-reported GOPs per inference


# ---------------------------------------------------------------------------
# The DPUV4E engine configuration (the paper's technique as a feature)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EngineConfig:
    # Quantization mode for projection/conv compute:
    #   none -> bf16/f32 math (training path)
    #   w8   -> int8 weights, bf16 activations (weight-only)
    #   w8a8 -> int8 x int8 -> int32 (the paper's mode)
    #   w4a8 -> w8a8 everywhere, except LM projection weights pack to
    #           per-group int4 (Q4Tensor) dequantized in-register by the
    #           Conv-PE GEMM -- the weight-bandwidth decode mode
    quant: str = "none"
    # Rows per (scale, zero) group along K for w4a8 packing.  Lives here so
    # it keys ProgramCache entries (EngineConfig is part of ProgramKey):
    # w4/w8 programs -- and different group sizes -- never collide.
    w4_group_size: int = 64
    # Kernel backend: "ref" = pure-jnp oracle path (also the dry-run path:
    # XLA-TPU fuses the same epilogues), "pallas" = Pallas TPU kernels.
    backend: str = "ref"
    interpret: bool = True           # Pallas interpret mode (CPU container)
    # Paper features (each maps to a paper contribution; see DESIGN.md):
    use_dwc_engine: bool = True      # C4  DWC PE
    use_low_channel_unit: bool = True# C5  first-layer unit
    misc_on_engine: bool = True      # C6  fused eltwise/pool epilogues
    cascade_bk: int = 0              # C2  K-block (0 = DSE-chosen)
    block_m: int = 0                 # DSE-chosen when 0
    block_n: int = 0
    # XVDPU-analog baseline (paper's comparison target): unfused epilogue,
    # no DWC engine, no low-channel unit.
    baseline: bool = False
    # Beyond-paper serving features:
    kv_cache_dtype: str = "bf16"     # bf16 | int8
    act_quant: str = "dynamic"       # dynamic | static per-tensor act scales
    # Beyond-paper distribution feature: local (per-dp-shard) MoE dispatch.
    # 0 = global dispatch (baseline); N>1 = route tokens within N groups
    # whose leading axis matches the dp sharding, so the argsort/one-hot
    # routing machinery never crosses shards (see EXPERIMENTS.md §Perf).
    moe_local_groups: int = 0

    def resolved(self) -> "EngineConfig":
        if not self.baseline:
            return self
        return dataclasses.replace(
            self, use_dwc_engine=False, use_low_channel_unit=False,
            misc_on_engine=False)


# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Training configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    # Memory / schedule
    remat: str = "block"             # none | block | full
    microbatches: int = 1            # gradient accumulation
    loss_chunk_vocab: int = 0        # chunked-vocab CE (0 = off)
    scan_layers: bool = False        # lax.scan over stacked layer groups
    triangle_skip: bool = False      # exact-triangle causal attention
    param_dtype: str = "f32"         # f32 | bf16 (mixed precision: bf16
                                     # params+grads, f32 Adam moments)
    # Distribution
    zero1: bool = True               # shard optimizer state over data axis
    seq_shard_activations: bool = False  # SP between blocks (beyond-paper)
    # Fault tolerance
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    keep_ckpts: int = 3
    step_timeout_s: float = 0.0      # straggler watchdog (0 = off)
    seed: int = 0
