"""DPUV4E engine facade: presets + param-tree quantization for serving.

The paper's deployment flow is: train/convert -> Vitis-AI INT8 quantize ->
run on the DPU engines.  Ours: train in bf16/f32 -> quantize_params() ->
serve through the Conv PE / DWC PE paths (kernels/ops.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.config import EngineConfig
from repro.core.quant import (Q4Tensor, QTensor, pack_int4, quantize,
                              snap_group_size)
from repro.models.params import ParamSpec, is_spec

# Param-dict keys that route through ops.linear and therefore quantize.
QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "wi",
    "in_proj", "out_proj", "x_proj", "dt_proj", "in_x", "in_gate",
    "head", "router", "embed",
    # CNN zoo (models/cnn.py schema): stem / stage convs / depthwise taps /
    # squeeze-expand / classifier head -- the engine-program weights.
    "stem_w", "w", "w1", "w2", "w3", "wskip", "we", "wp", "ws", "head_w",
})

# LM projection weights: the weight-bandwidth-bound decode GEMMs that pack
# to int4 under quant="w4a8" (embed/head and everything else stay int8).
W4_KEYS = frozenset({"wq", "wk", "wv", "wo", "wg", "wu", "wd"})


def weight_mode(eng: EngineConfig) -> str:
    """Digest tag for the weight container layout ("" for w8/w8a8/none).

    Folded into the calibration id (serve/base.calibration_digest) so w4 and
    w8 programs of one model never collide in the ProgramCache even if the
    EngineConfig were equal otherwise."""
    if eng.quant == "w4a8":
        return f"w4g{eng.w4_group_size}"
    return ""


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

def train_engine() -> EngineConfig:
    return EngineConfig(quant="none", backend="ref")


def paper_engine(backend: str = "ref", **kw) -> EngineConfig:
    """The DPUV4E configuration: W8A8 + all engine features."""
    return EngineConfig(quant="w8a8", backend=backend, **kw)


def baseline_engine(**kw) -> EngineConfig:
    """XVDPU-analog baseline (paper's comparison target)."""
    return EngineConfig(quant="w8a8", backend="ref", baseline=True,
                        **kw).resolved()


def w8_engine(**kw) -> EngineConfig:
    """Weight-only int8 (memory-bound decode: beyond-paper serving mode)."""
    return EngineConfig(quant="w8", backend="ref", **kw)


def w4_engine(backend: str = "ref", **kw) -> EngineConfig:
    """Int4 weight-only LM projections over the w8a8 fabric (XEGEMM_INT4
    idiom): packed weights dequantize in-register on the Conv PE."""
    return EngineConfig(quant="w4a8", backend=backend, **kw)


# ---------------------------------------------------------------------------
# Param-tree quantization (values and schemas)
# ---------------------------------------------------------------------------

def _quant_axis(key: str, ndim: int) -> int:
    return 0 if key == "embed" else ndim - 1


def _scale_spec(spec: ParamSpec, axis: int) -> ParamSpec:
    shape = tuple(d if i == axis else 1 for i, d in enumerate(spec.shape))
    axes = tuple(spec.axes[i] if i == axis else None
                 for i in range(len(spec.shape)))
    return ParamSpec(shape, axes, "ones", jnp.float32)


def _walk(tree, fn, key=None):
    if isinstance(tree, dict):
        return {k: _walk(v, fn, k) for k, v in tree.items()}
    if isinstance(tree, (QTensor, Q4Tensor)):
        return fn(key, tree)            # quantized container: one leaf
    if isinstance(tree, (list, tuple)):
        t = [_walk(v, fn, key) for v in tree]
        return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
    return fn(key, tree)


def _packs_int4(eng: EngineConfig, key: str, ndim: int) -> bool:
    return eng.quant == "w4a8" and key in W4_KEYS and ndim == 2


def quantize_schema(schema, eng: EngineConfig):
    """ParamSpec tree -> tree where quantized leaves become QTensor nodes
    (Q4Tensor nodes for w4a8 LM projections)."""
    if eng.quant == "none":
        return schema

    def fn(key, leaf):
        if (is_spec(leaf) and key in QUANT_KEYS and len(leaf.shape) >= 2):
            if _packs_int4(eng, key, len(leaf.shape)):
                k, n = leaf.shape
                g = k // snap_group_size(k, eng.w4_group_size)
                mk = lambda shape, dtype: ParamSpec(      # noqa: E731
                    shape, (None,) * len(shape), "ones", dtype)
                return Q4Tensor(packed=mk((k // 2, n), jnp.uint8),
                                scale=mk((g, n), jnp.float16),
                                zero=mk((g, n), jnp.float16))
            ax = _quant_axis(key, len(leaf.shape))
            return QTensor(
                q=dataclasses.replace(leaf, init="small", dtype=jnp.int8),
                scale=_scale_spec(leaf, ax))
        return leaf

    return _walk(schema, fn)


def quantize_params(params, eng: EngineConfig):
    """Value tree -> quantized tree (matching quantize_schema structure)."""
    if eng.quant == "none":
        return params

    def fn(key, leaf):
        if (key in QUANT_KEYS and hasattr(leaf, "ndim") and leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            if _packs_int4(eng, key, leaf.ndim):
                return pack_int4(leaf, eng.w4_group_size)
            return quantize(leaf, axis=_quant_axis(key, leaf.ndim))
        return leaf

    return _walk(params, fn)


def serving_dtype_cast(params, dtype=jnp.bfloat16):
    """Cast float leaves for serving (quantized leaves untouched)."""
    def fn(key, leaf):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(dtype)
        return leaf
    return _walk(params, fn)
