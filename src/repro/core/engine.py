"""DPUV4E engine facade: presets + param-tree quantization for serving.

The paper's deployment flow is: train/convert -> Vitis-AI INT8 quantize ->
run on the DPU engines.  Ours: train in bf16/f32 -> quantize_params() ->
serve through the Conv PE / DWC PE paths (kernels/ops.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.config import EngineConfig
from repro.core.quant import QTensor, quantize
from repro.models.params import ParamSpec, is_spec

# Param-dict keys that route through ops.linear and therefore quantize.
QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "wi",
    "in_proj", "out_proj", "x_proj", "dt_proj", "in_x", "in_gate",
    "head", "router", "embed",
    # CNN zoo (models/cnn.py schema): stem / stage convs / depthwise taps /
    # squeeze-expand / classifier head -- the engine-program weights.
    "stem_w", "w", "w1", "w2", "w3", "wskip", "we", "wp", "ws", "head_w",
})


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

def train_engine() -> EngineConfig:
    return EngineConfig(quant="none", backend="ref")


def paper_engine(backend: str = "ref", **kw) -> EngineConfig:
    """The DPUV4E configuration: W8A8 + all engine features."""
    return EngineConfig(quant="w8a8", backend=backend, **kw)


def baseline_engine(**kw) -> EngineConfig:
    """XVDPU-analog baseline (paper's comparison target)."""
    return EngineConfig(quant="w8a8", backend="ref", baseline=True,
                        **kw).resolved()


def w8_engine(**kw) -> EngineConfig:
    """Weight-only int8 (memory-bound decode: beyond-paper serving mode)."""
    return EngineConfig(quant="w8", backend="ref", **kw)


# ---------------------------------------------------------------------------
# Param-tree quantization (values and schemas)
# ---------------------------------------------------------------------------

def _quant_axis(key: str, ndim: int) -> int:
    return 0 if key == "embed" else ndim - 1


def _scale_spec(spec: ParamSpec, axis: int) -> ParamSpec:
    shape = tuple(d if i == axis else 1 for i, d in enumerate(spec.shape))
    axes = tuple(spec.axes[i] if i == axis else None
                 for i in range(len(spec.shape)))
    return ParamSpec(shape, axes, "ones", jnp.float32)


def _walk(tree, fn, key=None):
    if isinstance(tree, dict):
        return {k: _walk(v, fn, k) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_walk(v, fn, key) for v in tree]
        return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
    return fn(key, tree)


def quantize_schema(schema, eng: EngineConfig):
    """ParamSpec tree -> tree where quantized leaves become QTensor nodes."""
    if eng.quant == "none":
        return schema

    def fn(key, leaf):
        if (is_spec(leaf) and key in QUANT_KEYS and len(leaf.shape) >= 2):
            ax = _quant_axis(key, len(leaf.shape))
            return QTensor(
                q=dataclasses.replace(leaf, init="small", dtype=jnp.int8),
                scale=_scale_spec(leaf, ax))
        return leaf

    return _walk(schema, fn)


def quantize_params(params, eng: EngineConfig):
    """Value tree -> quantized tree (matching quantize_schema structure)."""
    if eng.quant == "none":
        return params

    def fn(key, leaf):
        if (key in QUANT_KEYS and hasattr(leaf, "ndim") and leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return quantize(leaf, axis=_quant_axis(key, leaf.ndim))
        return leaf

    return _walk(params, fn)


def serving_dtype_cast(params, dtype=jnp.bfloat16):
    """Cast float leaves for serving (quantized leaves untouched)."""
    def fn(key, leaf):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(dtype)
        return leaf
    return _walk(params, fn)
