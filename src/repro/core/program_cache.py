"""Keyed LRU store for compiled engine programs.

One fabric serves many models (the f-CNNx setting): a request trace
revisits a small working set, so recompiling -- graph build + calibration +
requant folding + XLA trace -- on every request would dominate serving
latency.  Programs are cached under ``(model config, EngineConfig,
calibration-id)`` where the model config is the frontend the graph lowered
from (a CNNConfig or a transformer ArchConfig): the config pair pins the
lowering and the kernel/quant mode, the calibration id pins the static
scales and the calibrator method, so a hit is guaranteed to be the
byte-identical program a fresh compile would produce.

The store is a plain bounded LRU (this also replaces the unbounded
``functools.lru_cache`` the executor used for dynamic programs): hits
refresh recency, inserts beyond capacity evict the least-recently-used
entry, and hit/miss/eviction counters feed the serving benchmarks.  A lock
makes it safe to share one cache across engines serving from threads.

Lives in core (pure stdlib, no model/compiler imports) because both ends
of the stack depend on it: compiler.executor memoizes dynamic programs
here, and serve.cnn_engine keys full calibrated programs.  The serving
layer re-exports it as ``repro.serve.program_cache``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Optional


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    compiles: int = 0

    @property
    def requests(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.requests if self.requests else 0.0

    def summary(self) -> str:
        return (f"hit-rate {100.0 * self.hit_rate:.1f}% "
                f"({self.hits}/{self.requests} hits, "
                f"{self.compiles} compiles, {self.evictions} evictions)")


@dataclass(frozen=True)
class ProgramKey:
    """The cache key: what uniquely determines a compiled program."""
    model: Hashable                   # the frontend config the graph lowers
                                      # from (CNNConfig or ArchConfig)
    engine: Optional[Hashable]        # EngineConfig, or None when the
                                      # program is backend-agnostic (dynamic)
    calibration: Optional[str]        # digest of the calibration data +
                                      # calibrator method, or None for
                                      # uncalibrated programs
    variant: str = ""                 # e.g. "scheduled" / "sequential" /
                                      # "scheduled:prefill"
    mesh: Optional[Hashable] = None   # mesh topology (device count + axis
                                      # shape) the program was traced for;
                                      # None = single implicit device.  A
                                      # shared cache must never hand a
                                      # program traced for one mesh to an
                                      # engine serving on another.


class ProgramCache:
    """Bounded LRU mapping ProgramKey-like hashables -> compiled programs."""

    def __init__(self, capacity: int = 8,
                 on_evict: Optional[Callable[[Hashable, Any], None]] = None):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self.stats = CacheStats()
        self._store: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._on_evict = on_evict
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store

    def keys(self):
        return list(self._store.keys())

    def get(self, key: Hashable, default=None):
        """Recency-refreshing lookup; does NOT touch hit/miss counters
        (those belong to get_or_compile, the serving path)."""
        with self._lock:
            if key not in self._store:
                return default
            self._store.move_to_end(key)
            return self._store[key]

    def peek(self, key: Hashable, default=None):
        """Non-refreshing lookup for stats/introspection: touches neither
        recency nor counters, so monitoring cannot perturb eviction order."""
        with self._lock:
            return self._store.get(key, default)

    def put(self, key: Hashable, value: Any) -> None:
        evicted = []
        with self._lock:
            if key in self._store:
                self._store.move_to_end(key)
            self._store[key] = value
            while len(self._store) > self.capacity:
                evicted.append(self._store.popitem(last=False))
                self.stats.evictions += 1
        for k, v in evicted:
            if self._on_evict is not None:
                self._on_evict(k, v)

    def get_or_compile(self, key: Hashable, compile_fn: Callable[[], Any]):
        """The serving entry point: hit -> cached program, miss -> compile,
        store, and count.  The compile runs outside the lock (it can take
        seconds); a racing duplicate compile is tolerated -- last write wins
        and both callers get a valid program."""
        with self._lock:
            if key in self._store:
                self.stats.hits += 1
                self._store.move_to_end(key)
                return self._store[key]
            self.stats.misses += 1
        value = compile_fn()
        with self._lock:
            self.stats.compiles += 1
        if self.capacity > 0:
            self.put(key, value)
        return value

    def clear(self) -> None:
        with self._lock:
            items = list(self._store.items())
            self._store.clear()
        for k, v in items:
            if self._on_evict is not None:
                self._on_evict(k, v)
