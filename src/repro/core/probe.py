"""Probe mode: make every structural loop visible to XLA cost analysis.

XLA's HloCostAnalysis counts a while-loop body ONCE, whatever its trip count
(verified empirically on this toolchain).  Production programs use lax.scan /
lax.map for compile-time and memory reasons, so their cost analysis
under-reports flops/bytes/collectives by the trip counts.

The dry-run therefore compiles small PROBE programs (1-2 layer groups) with
this flag on -- every structural loop fully unrolls, cost analysis becomes
exact -- and extrapolates linearly in the layer-group count (launch/dryrun).

Loops that must route through these helpers:
  * flash attention q-block map + kv-block scan   (models/layers.py)
  * ssm chunk scans                               (models/ssm.py)
  * microbatch gradient accumulation              (train/train_step.py)
  * chunked-vocab CE                              (train/loss.py)
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

_state = threading.local()


def enabled() -> bool:
    return getattr(_state, "on", False)


@contextlib.contextmanager
def probe_mode(on: bool = True):
    prev = enabled()
    _state.on = on
    try:
        yield
    finally:
        _state.on = prev


def pscan(f, init, xs, length=None):
    """lax.scan that fully unrolls under probe mode."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if enabled() else 1)


def pmap_blocks(f, n: int):
    """lax.map(f, arange(n)) that becomes a python loop under probe mode
    (f then receives PYTHON ints -> static slicing, exact accounting)."""
    if enabled():
        return jnp.stack([f(i) for i in range(n)])
    return jax.lax.map(f, jnp.arange(n))
