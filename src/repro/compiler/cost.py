"""Engine-tile cost model: modeled seconds per op of a compiled program.

The paper's scheduling wins (Section IV-A/IV-C) come from a per-engine CTC
analysis: each op's time on its engine unit is

    t = max(effective_ops / engine_peak, bytes / HBM_BW)

with the utilization penalties the DSE model (core/dse.py) prices --
contraction/output-channel MXU alignment for Conv-PE GEMMs, VPU-bound
depthwise convs, window folding on the Low-Channel unit.  This module is
the compiler-side home of that pricing so the scheduler itself can be
cost-driven: `level_schedule(policy="cost")` and `merge_schedules` weigh
placement by `{node_id: seconds}` dicts produced here, and
`benchmarks/perf_model.py` re-exports everything for the modeling tables.

Two graph walks price both frontends:

  * `cnn_node_times(graph, cfg)` -- shapes from the model schema
    (models.cnn.cnn_schema) + stride/pool propagation, so fused programs
    are priced as what they execute (a fused conv absorbs its residual
    read; the standalone MISC pass disappears);
  * `lm_node_times(graph, arch, batch, seq)` -- GEMM dims recovered from
    the param-path suffix the lowering wrote (wq/wk/wv/wo/wg/wu/wd), so
    one walk prices prefill and decode programs.

`default_node_times(graph, cfg, kind)` dispatches on the program's config
type -- the hook executor._finish_program uses to price programs compiled
with the cost policy without the caller threading times through.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core import dse

PEAK_INT8 = dse.PEAK_INT8_OPS      # MXU int8
PEAK_VPU = 5.0e12                  # VPU int ops/s (8x128 lanes, ~1 GHz, FMA)
HBM = dse.HBM_BW
PEAK_F32_VPU = PEAK_VPU / 4        # f32 VPU ops/s (MISC float domain)

# Paper Section V-B: measured Conv-PE utilization on ResNet50 stage 0.  Used
# as the stage-0 utilization of the no-low-channel-unit baseline (the
# XVDPU-analog); our unit reaches the window-folded MXU coverage instead.
STAGE0_BASELINE_UTIL = 0.131
VPU_NATIVE_EFF = 0.4               # XLA grouped-conv VPU efficiency


@dataclass
class EngineModel:
    # dwc_mode: "engine" (DWC PE: tiled VPU + fused requant),
    #           "vpu"    (TPU-native XLA grouped conv: VPU, lower efficiency),
    #           "dense"  (XVDPU-analog: depthwise on the GEMM engine --
    #                     channel-diagonalized, ops x C inflation; this is
    #                     what our baseline code path actually executes)
    dwc_mode: str = "engine"
    use_low_channel: bool = True
    fused_epilogue: bool = True    # MISC on engine: no extra eltwise pass
    # static_act: calibrated static scales -> activations stay int8 between
    # engines (the compiled engine-program path).  False = the dynamic-f32
    # pipeline: every edge is carried at f32 and re-quantized per call (an
    # extra read-f32/write-int8 pass in front of every engine).
    static_act: bool = True

    @property
    def use_dwc_engine(self):
        return self.dwc_mode == "engine"

    @property
    def act_bytes(self) -> int:
        return 1 if self.static_act else 4


def _conv_time(px: int, ic: int, oc: int, k: int, eng: EngineModel,
               first_layer: bool = False) -> float:
    """One standard conv: px output pixels, k x k window."""
    ops = 2.0 * px * ic * oc * k * k
    # The engine always reads int8 (static edges, or the int8 the dynamic
    # requant pass just wrote); dynamic additionally pays that pass (read
    # f32 + write int8) and emits its output at f32.
    in_bytes = px * ic            # stride-adjusted approx
    w_bytes = k * k * ic * oc
    out_bytes = px * oc * eng.act_bytes
    # Both pipelines quantize the f32 input image once at the boundary;
    # only the dynamic pipeline repeats the pass at every layer.
    quant_bytes = (px * ic * 5
                   if (first_layer or not eng.static_act) else 0)
    if first_layer:
        if eng.use_low_channel:
            # window folding (contraction = ic*k*k) + concurrency: the unit
            # runs while the main engines proceed (paper Section V-B), so
            # only its memory traffic remains on the critical path.
            return (in_bytes + w_bytes + out_bytes + quant_bytes) / HBM
        util = STAGE0_BASELINE_UTIL
    else:
        util = dse.mxu_utilization(min(ic, 128), min(oc, 128), kk=1)
    util = max(util, 1e-3)
    t_compute = ops / (PEAK_INT8 * util)
    t_mem = (in_bytes + w_bytes + out_bytes + quant_bytes) / HBM
    if not eng.fused_epilogue:
        t_mem += 2.0 * px * oc * 4 / HBM       # i32 psum round-trip
    return max(t_compute, t_mem)


def _dwc_time(px: int, c: int, k: int, eng: EngineModel) -> float:
    ops = 2.0 * px * c * k * k
    # int8 engine read + act_bytes output write (see _conv_time)
    byts = px * c * (1 + eng.act_bytes) + k * k * c
    if not eng.static_act:
        byts += px * c * 5            # dynamic requant pass: read f32/write i8
    if eng.dwc_mode == "engine":
        t_compute = ops / PEAK_VPU
    elif eng.dwc_mode == "vpu":
        t_compute = ops / (PEAK_VPU * VPU_NATIVE_EFF)
    else:
        # "dense": diagonalized GEMM on the MXU (ops x C inflation,
        # utilization capped by the 128-lane contraction)
        dense_ops = 2.0 * px * c * c * k * k
        util = dse.mxu_utilization(min(c, 128), min(c, 128))
        t_compute = dense_ops / (PEAK_INT8 * max(util, 1e-3))
        byts += k * k * c * c                  # dense weight reads
    t_mem = byts / HBM
    if not eng.fused_epilogue:
        t_mem += 2.0 * px * c * 4 / HBM
    return max(t_compute, t_mem)


def _eltwise_time(px: int, c: int, eng: EngineModel) -> float:
    if eng.fused_epilogue:
        return 0.0                 # fused into the producing kernel
    # separate read-read-write pass at the pipeline's activation width
    return 3.0 * px * c * eng.act_bytes / HBM


def _gemm_time(m: int, k: int, n: int, act_bytes: int = 1) -> float:
    """One int8 Conv-PE GEMM: [m, k] @ [k, n]."""
    ops = 2.0 * m * k * n
    util = max(dse.mxu_utilization(min(k, 128), min(n, 128)), 1e-3)
    byts = m * k * act_bytes + k * n + m * n * act_bytes
    return max(ops / (PEAK_INT8 * util), byts / HBM)


def _eltwise_f32_time(elems: int, n_in: int = 1) -> float:
    """A MISC-core f32 elementwise pass: n_in reads + 1 write."""
    return (n_in + 1) * elems * 4 / HBM


OURS = EngineModel()                       # compiled static-int8 pipeline
OURS_DYNAMIC = EngineModel(static_act=False)
BASELINE = EngineModel(dwc_mode="dense", use_low_channel=False,
                       fused_epilogue=False)
TPU_NATIVE = EngineModel(dwc_mode="vpu", use_low_channel=False,
                         fused_epilogue=False)
NO_LOWPE = EngineModel(use_low_channel=False)
NO_DWC = EngineModel(dwc_mode="dense")


# ---------------------------------------------------------------------------
# CNN program node times: the GRAPH walk (prices fused programs)
# ---------------------------------------------------------------------------

def _shape_of(schema, path):
    from repro.compiler.graph import get_param
    return get_param(schema, path).shape


def _pool_hw(h: int, pool: str, k: int, stride: int) -> int:
    """VALID-window output size -- the math the executor and the fused
    kernels actually run (kernels/_epilogue.pooled_hw)."""
    if pool == "global":
        return 1
    return max((h - k) // max(stride, 1) + 1, 1)


def cnn_node_times(graph, cfg, eng: Optional[EngineModel] = None
                   ) -> Dict[int, float]:
    """Modeled seconds per op of a CNN program graph ({node_id: seconds}).

    Walks the compiled graph itself (not the CNNConfig), so epilogue-fused
    programs are priced as what they execute: a fused node costs its
    conv/dwc launch plus the residual operand read, while the absorbed MISC
    add/pool passes (their read-read-write HBM traffic) disappear.  Feeds
    compiler.time_weighted_occupancy and the cost-driven scheduler.

    Channel/spatial shapes come from the model schema (cnn_schema) + stride
    propagation, so the walk needs no parameter values.
    """
    from repro.compiler import graph as G
    from repro.models.cnn import cnn_schema

    eng = eng or OURS
    schema = cnn_schema(cfg)
    hw: dict = {}
    ch: dict = {}
    out: Dict[int, float] = {}
    for n in graph.nodes:
        if isinstance(n, G.InputOp):
            hw[n.id], ch[n.id] = cfg.input_hw, cfg.input_ch
            out[n.id] = 0.0
            continue
        src = n.inputs[0] if n.inputs else None
        if isinstance(n, G.ConvOp):
            k, _, ic, oc = _shape_of(schema, n.w)
            h = -(-hw[src] // n.stride)
            px = h * h
            t = _conv_time(px, ic, oc, k, eng, first_layer=n.first_layer)
            ep = n.epilogue
            if ep is not None and ep.add:
                t += px * oc * eng.act_bytes / HBM     # residual operand read
            hw[n.id], ch[n.id] = h, oc
            if ep is not None and ep.pool != "none":
                hw[n.id] = _pool_hw(h, ep.pool, ep.pool_kernel,
                                    ep.pool_stride)
            out[n.id] = t
        elif isinstance(n, G.DwcOp):
            k, _, c = _shape_of(schema, n.w)
            h = -(-hw[src] // n.stride)
            px = h * h
            t = _dwc_time(px, c, k, eng)
            ep = n.epilogue
            if ep is not None and ep.add:
                t += px * c * eng.act_bytes / HBM
            hw[n.id], ch[n.id] = h, c
            if ep is not None and ep.pool != "none":
                hw[n.id] = _pool_hw(h, ep.pool, ep.pool_kernel,
                                    ep.pool_stride)
            out[n.id] = t
        elif isinstance(n, G.AddOp):
            px = hw[src] * hw[src]
            c = ch[src]
            # a standalone MISC add is a read-read-write pass at the
            # pipeline's activation width (what fusion eliminates)
            out[n.id] = 3.0 * px * c * eng.act_bytes / HBM
            hw[n.id], ch[n.id] = hw[src], c
        elif isinstance(n, G.PoolOp):
            h_out = _pool_hw(hw[src], n.pool, n.kernel, n.stride)
            c = ch[src]
            out[n.id] = ((hw[src] * hw[src] + h_out * h_out)
                         * c * eng.act_bytes / HBM)
            hw[n.id], ch[n.id] = h_out, c
        elif isinstance(n, G.ConcatOp):
            hw[n.id] = hw[src]
            ch[n.id] = sum(ch[i] for i in n.inputs)
            out[n.id] = 0.0                    # bank interleave
        elif isinstance(n, G.LinearOp):
            ci, co = _shape_of(schema, n.w)
            out[n.id] = 2.0 * ci * co / PEAK_INT8
            hw[n.id], ch[n.id] = 1, co
        else:
            out[n.id] = 0.0
            hw[n.id], ch[n.id] = hw.get(src, 1), ch.get(src, 1)
    return out


# ---------------------------------------------------------------------------
# LM program node times
# ---------------------------------------------------------------------------

def lm_node_times(graph, arch, batch: int, seq: int,
                  cache_len: int = 0) -> Dict[int, float]:
    """Modeled seconds per op of an LM program graph.

    `seq` is the query length (1 for a DecodeStep program); `cache_len` the
    ACTUAL cached length attention reads for decode (the slots' mean
    position, NOT max_seq -- pricing update-mode by the worst-case envelope
    overstated attention cost for short sequences).  Block-paged AttnOps
    (n.page_size > 0) round that span up to a page multiple: a request
    occupies -- and the gather moves -- whole blocks.  Linear dims come
    from the param-path suffix the lowering wrote (wq/wk/wv/wo/wg/wu/wd),
    so the same walk prices prefill and decode.
    """
    from repro.compiler import graph as G

    d, ff, v = arch.d_model, arch.d_ff, arch.vocab_size
    nh, nkv, hd = arch.n_heads, arch.n_kv_heads, arch.head_dim
    span = cache_len if cache_len else seq
    m = batch * seq
    dims = {"wq": (d, nh * hd), "wk": (d, nkv * hd), "wv": (d, nkv * hd),
            "wo": (nh * hd, d), "wg": (d, ff), "wu": (d, ff), "wd": (ff, d)}
    out: Dict[int, float] = {}
    for n in graph.nodes:
        if isinstance(n, G.LinearGroupOp):
            # One fused launch over the N-concatenated members: same MACs
            # and A-read as the members, one A-fetch instead of len(ws)
            kns = [dims.get(p[-1] if p else "", (d, d)) for p in n.ws]
            out[n.id] = _gemm_time(m, kns[0][0], sum(kn[1] for kn in kns))
        elif isinstance(n, G.LinearOp):
            kn = dims.get(n.w[-1] if n.w else "", (d, d))
            out[n.id] = _gemm_time(m, *kn)
        elif isinstance(n, G.HeadOp):
            rows = batch * (1 if n.last_only else seq)
            out[n.id] = _gemm_time(rows, d, v, act_bytes=4)
        elif isinstance(n, G.AttnOp):
            aspan = span
            if n.mode == "update" and n.page_size:
                aspan = -(-aspan // n.page_size) * n.page_size
            window = min(n.window, aspan) if n.window else aspan
            flops = 4.0 * batch * seq * window * nh * hd    # qk + pv
            byts = (2 * batch * window * nkv * hd * 2        # kv reads (bf16)
                    + 3 * m * nh * hd * 4)                   # q in, ctx out
            out[n.id] = max(flops / PEAK_F32_VPU, byts / HBM)
        elif isinstance(n, (G.NormOp, G.MulOp, G.AddOp)):
            out[n.id] = _eltwise_f32_time(m * d, n_in=len(n.inputs))
        elif isinstance(n, G.EmbedOp):
            out[n.id] = m * d * 4 / HBM                      # row gather
        else:                                               # InputOp etc.
            out[n.id] = 0.0
    return out


# ---------------------------------------------------------------------------
# Dispatcher: price any program's graph from its frontend config
# ---------------------------------------------------------------------------

# Nominal shapes the cost-driven scheduler prices programs at when the
# caller doesn't thread explicit times: what matters to placement is the
# RATIO between ops (a decode GEMM vs a norm), which is shape-stable.
DEFAULT_LM_BATCH = 1
DEFAULT_PREFILL_SEQ = 128
DEFAULT_DECODE_CACHE = 128


def default_node_times(graph, cfg, kind: str = "forward"
                       ) -> Dict[int, float]:
    """{node_id: seconds} for any compiled program, dispatched on its
    frontend config type (CNNConfig -> cnn walk, ArchConfig -> lm walk).
    Unknown config types price every node at 0.0 (the cost policy then
    degenerates to its earliest-level tie-break, i.e. ASAP)."""
    from repro.core.config import ArchConfig, CNNConfig

    if isinstance(cfg, CNNConfig):
        return cnn_node_times(graph, cfg)
    if isinstance(cfg, ArchConfig):
        if kind == "decode":
            return lm_node_times(graph, cfg, DEFAULT_LM_BATCH, 1,
                                 cache_len=DEFAULT_DECODE_CACHE)
        return lm_node_times(graph, cfg, DEFAULT_LM_BATCH,
                             DEFAULT_PREFILL_SEQ)
    return {n.id: 0.0 for n in graph.nodes}
