"""Compiler passes: requant folding + epilogue fusion (the graph rewrite).

`fuse_epilogues` rewrites Conv/DWC -> {residual Add, pool tail} chains into
single fused nodes (Epilogue spec), so the chain executes as ONE engine
launch with no intermediate tensor materialized between the PE and its MISC
tail; `fold_requant` then plans the static-int8 dataflow over whichever
graph (fused or not) it is handed.

Input: an op graph (graph.py) and per-edge calibrated activation scales
(calibrate.py).  Output: a QuantPlan that the static executor follows --
for every edge, the int8 scale it is carried at, and for every op, whether
its NL/RACNL epilogue requantizes straight to the consumer's scale.

The point (paper Section III-A / IV-B2): with static Vitis-AI-style scales,
activations stay int8 from engine to engine.  Each PE's epilogue performs
  dequant(int32 acc) -> bias -> activation -> requant(out_scale)
in one fused pass, so the only f32 tensor the whole program materializes is
the final logits.  The dynamic path (no plan) instead round-trips every edge
through f32 and re-quantizes per call -- the gap these passes close.

Folding rules:
  * max-pool is scale-preserving: it reuses its producer's scale verbatim
    (int8 values pass through untouched, no requant at all);
  * concat unifies its branch scales: each single-consumer producer requants
    directly to the concat's scale inside its own epilogue, so the concat is
    a pure bank interleave;
  * everything else requants in its producing engine's epilogue to its own
    calibrated scale.

Mixed-domain (LM) graphs: an edge is carried int8 only when its producer can
emit int8 from its epilogue AND every consumer natively consumes int8.  In a
CNN graph that is every internal edge (unchanged semantics).  In an LM graph
the residual stream, the attention q/k/v and the SwiGLU gate stay f32 on the
MISC core, while every edge feeding a Conv PE GEMM -- the norm outputs, the
attention context, the gate product -- is requantized once, statically, in
its producer's epilogue: `ops.linear` then consumes pre-quantized int8
activations with compile-time scales instead of dynamically re-quantizing
per token.

fold_weight_layouts() is the compile-time weight-layout pass: the im2col
reshape of Conv PE weights and the 128-lane zero-padding of DWC weights --
transforms the kernels historically re-applied on every traced call -- are
applied once to the parameter tree when a program is bound for serving.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.compiler.graph import (AddOp, AttnOp, ConcatOp, ConvOp, DwcOp,
                                  EmbedOp, Epilogue, Graph, InputOp,
                                  LinearGroupOp, LinearOp, MulOp, NormOp,
                                  PoolOp, ViewOp, get_param)
from repro.core.quant import QTensor

_MIN_SCALE = 1e-8

# Which op kinds can emit int8 from their engine epilogue, and which consume
# int8 natively.  CNN kinds do both (the historical all-int8 dataflow); the
# LM float-domain ops (norm input, attention math, the gate product inputs,
# the logits head) keep f32 operands on the MISC core.  A LinearGroupOp
# consumes its shared input int8 like the member GEMMs it replaces, but its
# output is a TUPLE of member values read through ViewOps -- the members'
# consumers (attention, the gate product) are float-domain, so neither the
# group nor its views emit int8.
_INT8_EMIT = (InputOp, ConvOp, DwcOp, AddOp, PoolOp, ConcatOp, LinearOp,
              NormOp, AttnOp, MulOp)
_INT8_CONSUME = (ConvOp, DwcOp, LinearOp, LinearGroupOp, AddOp, PoolOp,
                 ConcatOp)
# The quantized-GEMM engines: an f32 edge into one of these is a "roundtrip"
# (the engine would have to re-quantize dynamically per call).
_GEMM_OPS = (ConvOp, DwcOp, LinearOp, LinearGroupOp)


@dataclass(frozen=True)
class QuantPlan:
    """Static-int8 execution plan for one graph."""
    # node id -> scale its OUTPUT edge is carried at (int8 value * scale =
    # f32).  A float is a per-tensor scale; a tuple of floats is a
    # per-channel (last-dim) scale on an edge whose consumers are all
    # channelwise engines (DWC PE).
    out_scale: Dict[int, object]
    # node id -> does the node emit int8 (False only for the logits)
    emit_int8: Dict[int, bool]
    # edges whose requant was folded into the producer epilogue for a
    # *different* consumer scale (concat unification): (producer, consumer)
    folded: Tuple[Tuple[int, int], ...]
    stats: Dict[str, int] = field(default_factory=dict)
    # node id -> out_scale as a ready f32 array (per-channel tuples become
    # [C] vectors).  Precomputed ONCE at compile time so the static executor
    # never rebuilds scale constants per node per execution.
    scale_arr: Dict[int, object] = field(default_factory=dict, compare=False,
                                         repr=False)


def fold_requant(graph: Graph, scales: Dict[int, object],
                 granularity: str = "per_tensor") -> QuantPlan:
    """Assign every edge a static int8 scale and fold requants into the
    producing engines' epilogues.

    granularity="per_channel" (with tuple-valued scales from a per-channel
    calibration) keeps the channel vector only where the hardware can carry
    it: the consuming engine must be channelwise (every consumer a DwcOp --
    a per-K-channel activation scale cannot be factored out of a GEMM
    accumulation), and the producing epilogue must requant per-channel
    (InputOp boundary quant or a Conv PE output epilogue).  Every other
    edge collapses to the channel max -- exactly its per-tensor scale."""
    missing = [n.id for n in graph.nodes if n.id not in scales]
    if missing:
        raise ValueError(
            f"calibration scales missing for nodes {missing}; "
            "run compiler.calibrate over representative batches first")

    def _norm(v):
        if isinstance(v, tuple):
            return tuple(max(float(x), _MIN_SCALE) for x in v)
        return max(float(v), _MIN_SCALE)

    out_scale = {i: _norm(scales[i]) for i in scales}
    consumers = graph.consumers()
    emit_int8 = {
        n.id: (n.id != graph.output
               and isinstance(n, _INT8_EMIT)
               and bool(consumers[n.id])
               and all(isinstance(graph.nodes[c], _INT8_CONSUME)
                       for c in consumers[n.id]))
        for n in graph.nodes
    }

    def _dwc_channelwise(cn, edge: int) -> bool:
        """Does this consumer read the edge through the channelwise DWC
        datapath?  Only the DWC's own data input qualifies -- an edge a
        fused DwcOp consumes as its RESIDUAL operand rides the epilogue's
        scalar-scale add, not the per-lane dequant."""
        if not isinstance(cn, DwcOp) or cn.inputs[0] != edge:
            return False
        ep = cn.epilogue
        if ep is not None and ep.add and cn.inputs[-1] == edge:
            return False                  # also consumed as the residual
        return True

    per_channel = collapsed = 0
    for n in graph.nodes:
        s = out_scale[n.id]
        if not isinstance(s, tuple):
            continue
        keep = (granularity == "per_channel"
                and emit_int8[n.id]
                and all(_dwc_channelwise(graph.nodes[c], n.id)
                        for c in consumers[n.id])
                and (isinstance(n, InputOp)
                     or (isinstance(n, ConvOp) and not n.first_layer
                         # a fused epilogue requants through its absorbed
                         # MISC tail, which carries per-tensor scales (the
                         # unfused twin's add/pool edge would collapse too)
                         and n.epilogue is None)))
        if keep:
            per_channel += 1
        else:
            out_scale[n.id] = max(s)
            collapsed += 1

    folded: List[Tuple[int, int]] = []

    for n in graph.nodes:
        if isinstance(n, PoolOp) and n.pool == "max":
            # Scale-preserving: int8 values flow through the MISC comparator
            # untouched, so the output edge inherits the input's scale.
            out_scale[n.id] = out_scale[n.inputs[0]]
        elif isinstance(n, ConcatOp):
            # Unify branch scales: each branch engine requants to the concat
            # scale in its own epilogue (possible only when this concat is
            # the branch's sole consumer; otherwise the executor rescales
            # int8->int8 at the concat input instead).  A fused node whose
            # epilogue ends in a POOL cannot retarget: its final requant is
            # pinned to the pool stage's math (max is scale-preserving, and
            # avg/global requant after the absorbed add's own edge scale), so
            # it keeps its scale and the concat rescales like any other
            # non-foldable branch.
            s = out_scale[n.id]
            for p in n.inputs:
                pn = graph.nodes[p]
                ep = getattr(pn, "epilogue", None)
                if ep is not None and ep.pool != "none":
                    continue
                if len(consumers[p]) == 1 and isinstance(
                        pn, (ConvOp, DwcOp, AddOp)):
                    out_scale[p] = s
                    folded.append((p, n.id))

    stats = dict(fusion_stats(graph))
    stats["folded_requants"] = len(folded)
    stats["dynamic_f32_roundtrips"] = dynamic_roundtrip_count(graph)
    stats["per_channel_edges"] = per_channel
    stats["per_tensor_collapsed"] = collapsed
    scale_arr = {i: jnp.asarray(s, jnp.float32)
                 for i, s in out_scale.items() if emit_int8.get(i)}
    return QuantPlan(out_scale=out_scale, emit_int8=emit_int8,
                     folded=tuple(folded), stats=stats, scale_arr=scale_arr)


# ---------------------------------------------------------------------------
# Epilogue fusion: rewrite Conv/DWC -> {Add, pool} chains into fused launches
# ---------------------------------------------------------------------------

_FUSABLE_POOLS = ("avg", "global", "max")


def _collapse(s) -> float:
    """A chain-interior scale as a compile-time float (per-channel vectors
    collapse to the channel max -- exactly what fold_requant does for any
    edge not consumed purely by the channelwise DWC engine, which a chain
    interior never is)."""
    if isinstance(s, tuple):
        s = max(s)
    return max(float(s), _MIN_SCALE)


def fuse_epilogues(graph: Graph, scales: Optional[Dict[int, object]] = None):
    """Rewrite Conv/DWC -> {residual Add, avg/global/max pool} chains into
    single fused nodes carrying an Epilogue spec.

    The rewrite that turns `fusion_stats`' counted chains into actual single
    launches: a producing PE whose output feeds exactly one MISC op absorbs
    that op into its in-kernel epilogue (paper Section III -- "extend the
    functionality of each PE" so activations never round-trip the MISC
    path).  A chain fuses when every interior edge has exactly one consumer:

      Conv/Dwc -> Add                      (residual: the add's other
                                            operand becomes the fused
                                            node's LAST input edge)
      Conv/Dwc -> Pool(avg|global|max)     (pool tail)
      Conv/Dwc -> Add -> Pool(...)         (both)
      Linear   -> Add                      (LM residual adds after the O /
                                            down projections ride the Conv
                                            PE GEMM; pool tails never
                                            attach to a LinearOp)

    The fused node sits at the position of the chain's LAST op (so the
    residual operand, which may be lowered after the conv -- a bottleneck's
    skip conv -- stays topologically earlier), and node ids are renumbered
    compactly.

    `scales` (per-edge calibration scales keyed by the UNFUSED graph's node
    ids) are remapped to the fused ids and returned alongside; the absorbed
    interior edges' scales are baked into the Epilogue spec (mid_scale /
    add_scale), which is what keeps fused static execution bit-identical to
    the unfused program: the kernel quantize-dequantizes in-register at the
    same points the unfused dataflow materialized.  A max tail is
    scale-preserving, so the fused node's output edge inherits the pre-pool
    scale, like fold_requant's standalone max-pool rule.

    Returns (fused_graph, remapped_scales) -- scales is None when not given.
    """
    consumers = graph.consumers()

    def sole_consumer(nid: int):
        cs = consumers[nid]
        return graph.nodes[cs[0]] if len(cs) == 1 else None

    # chain end id -> (root node, add id | None, pool id | None, residual id)
    chains: Dict[int, Tuple] = {}
    absorbed: Dict[int, int] = {}        # interior old id -> chain end id
    for n in graph.nodes:
        if (not isinstance(n, (ConvOp, DwcOp, LinearOp))
                or n.epilogue is not None):
            continue
        if n.id == graph.output or n.id in absorbed:
            continue
        c = sole_consumer(n.id)
        if c is None or c.id in absorbed or c.id in chains:
            continue
        add_id = pool_id = res_id = None
        end = None
        if (isinstance(c, AddOp) and len(c.inputs) == 2
                and c.inputs.count(n.id) == 1
                and not (isinstance(n, ConvOp) and n.first_layer)):
            add_id, end = c.id, c
            res_id = c.inputs[1] if c.inputs[0] == n.id else c.inputs[0]
            p = sole_consumer(c.id)
            if (isinstance(p, PoolOp) and p.pool in _FUSABLE_POOLS
                    and p.id not in chains
                    and not isinstance(n, LinearOp)):
                pool_id, end = p.id, p
        elif (isinstance(c, PoolOp) and c.pool in _FUSABLE_POOLS
                and not isinstance(n, LinearOp)):
            pool_id, end = c.id, c
        else:
            continue
        chains[end.id] = (n, add_id, pool_id, res_id)
        absorbed[n.id] = end.id
        if add_id is not None and pool_id is not None:
            absorbed[add_id] = end.id

    if not chains:
        return graph, scales

    new_nodes: List = []
    new_id: Dict[int, int] = {}
    new_scales: Optional[Dict[int, object]] = {} if scales is not None else None
    for n in graph.nodes:
        if n.id in absorbed:
            continue                    # interior: re-emitted at the end op
        nid = len(new_nodes)
        if n.id in chains:
            root, add_id, pool_id, res_id = chains[n.id]
            inputs = tuple(new_id[i] for i in root.inputs)
            if res_id is not None:
                inputs = inputs + (new_id[res_id],)
            pool = graph.nodes[pool_id] if pool_id is not None else None
            mid = add_sc = 0.0
            if scales is not None:
                mid = _collapse(scales[root.id])
                if add_id is not None and pool is not None:
                    add_sc = _collapse(scales[add_id])
            ep = Epilogue(
                add=res_id is not None,
                add_act=graph.nodes[add_id].act if add_id is not None
                else "none",
                pool=pool.pool if pool is not None else "none",
                pool_kernel=pool.kernel if pool is not None else 0,
                pool_stride=pool.stride if pool is not None else 0,
                mid_scale=mid, add_scale=add_sc)
            new_nodes.append(dataclasses.replace(
                root, id=nid, inputs=inputs, epilogue=ep))
            if new_scales is not None:
                if ep.pool == "max":
                    # scale-preserving tail: inherit the pre-pool edge scale
                    new_scales[nid] = add_sc if ep.add else mid
                else:
                    new_scales[nid] = scales[n.id]
        else:
            new_nodes.append(dataclasses.replace(
                n, id=nid, inputs=tuple(new_id[i] for i in n.inputs)))
            if new_scales is not None:
                new_scales[nid] = scales[n.id]
        new_id[n.id] = nid
    fused = Graph(tuple(new_nodes), output=new_id[graph.output],
                  name=graph.name)
    return fused, new_scales


def fuse_projections(graph: Graph,
                     scales: Optional[Dict[int, object]] = None):
    """Collapse same-input LinearOp fan-outs into multi-output groups.

    The Q/K/V projections of an attention block (and the gate/up pair of a
    gated MLP) read the SAME normed activation row and differ only in their
    weight columns.  This pass rewrites each such fan-out -- member
    LinearOps sharing one input edge, each consumed solely by one AttnOp /
    MulOp -- into a single LinearGroupOp (one Conv PE launch with one output
    operand per member; the XEGEMM `hgemm_qkv_wint4(q, out0, out1, out2,
    ...)` dispatch) plus per-member ViewOps so downstream nodes keep
    single-value input edges.  3 launches become 1 for QKV, 2 become 1 for
    gate/up; the shared activation is quantized and streamed once.

    `scales` (per-edge calibration, keyed by the unfused ids) remap to the
    new ids: each ViewOp inherits its member's edge scale and the group node
    carries its first member's (the group's tuple output is never requantized
    as a whole -- member edges keep their own calibration).  Like
    fuse_epilogues, the rewrite is deterministic, so the full and decode
    graphs (identical node sequences) fuse identically and calibration
    transfer by node id survives.

    Returns (fused_graph, remapped_scales) -- scales is None when not given.
    """
    consumers = graph.consumers()
    groups: List[Tuple[int, ...]] = []
    grouped = set()
    for n in graph.nodes:
        if isinstance(n, AttnOp):
            members = n.inputs[:3]
        elif isinstance(n, MulOp) and len(n.inputs) == 2:
            members = n.inputs
        else:
            continue
        if len(set(members)) != len(members):
            continue
        if not all(isinstance(graph.nodes[m], LinearOp)
                   and graph.nodes[m].epilogue is None
                   and len(consumers[m]) == 1
                   and m not in grouped for m in members):
            continue
        shared = {graph.nodes[m].inputs for m in members}
        if len(shared) != 1 or len(next(iter(shared))) != 1:
            continue
        groups.append(tuple(members))
        grouped.update(members)

    if not groups:
        return graph, scales

    first_of = {min(g): g for g in groups}
    member_of = {m: g for g in groups for m in g}
    new_nodes: List = []
    new_id: Dict[int, int] = {}
    new_scales: Optional[Dict[int, object]] = {} if scales is not None else None
    for n in graph.nodes:
        if n.id in member_of:
            if n.id not in first_of:
                continue        # re-emitted as a view at the first member
            g = first_of[n.id]
            mems = [graph.nodes[m] for m in g]
            gid = len(new_nodes)
            new_nodes.append(LinearGroupOp(
                id=gid, inputs=tuple(new_id[i] for i in mems[0].inputs),
                ws=tuple(m.w for m in mems),
                bs=tuple(m.b for m in mems),
                acts=tuple(m.act for m in mems)))
            if new_scales is not None:
                new_scales[gid] = scales[g[0]]
            for idx, m in enumerate(g):
                vid = len(new_nodes)
                new_nodes.append(ViewOp(id=vid, inputs=(gid,), index=idx))
                new_id[m] = vid
                if new_scales is not None:
                    new_scales[vid] = scales[m]
            continue
        nid = len(new_nodes)
        new_nodes.append(dataclasses.replace(
            n, id=nid, inputs=tuple(new_id[i] for i in n.inputs)))
        new_id[n.id] = nid
        if new_scales is not None:
            new_scales[nid] = scales[n.id]
    fused = Graph(tuple(new_nodes), output=new_id[graph.output],
                  name=graph.name)
    return fused, new_scales


def launch_count(graph: Graph) -> int:
    """Engine kernel dispatches one execution of the graph issues.  Memory-
    level ops (input DMA, bank-interleave concat, embedding row gather, a
    group member view) ride the load path, not a PE launch."""
    return sum(1 for n in graph.nodes
               if not isinstance(n, (InputOp, ConcatOp, EmbedOp, ViewOp)))


# ---------------------------------------------------------------------------
# Fusion analysis (conv -> add -> relu residual chains on the MISC core)
# ---------------------------------------------------------------------------

def residual_chains(graph: Graph) -> List[Tuple[int, int]]:
    """(conv_id, add_id) pairs where a Conv PE output feeds a MISC add --
    the paper's conv->add->relu bottleneck epilogue."""
    chains = []
    for n in graph.nodes:
        if isinstance(n, AddOp):
            for p in n.inputs:
                if isinstance(graph.nodes[p], (ConvOp, DwcOp)):
                    chains.append((p, n.id))
    return chains


def fusion_stats(graph: Graph) -> Dict[str, int]:
    """Chain / launch accounting.  On a pre-pass graph `residual_chains`
    counts the fusable conv->add chains; on a post-pass graph `fused_*`
    count the chains actually rewritten into single launches, and
    `launches` is the kernel-dispatch count one execution issues."""
    chains = residual_chains(graph)
    fused = [n.epilogue for n in graph.nodes
             if getattr(n, "epilogue", None) is not None]
    consumers = graph.consumers()
    return {
        "residual_chains": len(chains),
        "misc_adds": graph.count(AddOp),
        "convs": graph.count(ConvOp),
        "dwcs": graph.count(DwcOp),
        "fused_ops": len(fused),
        "fused_adds": sum(1 for e in fused if e.add),
        "fused_pools": sum(1 for e in fused if e.pool != "none"),
        "fused_projections": graph.count(LinearGroupOp),
        "projection_members": sum(len(n.ws) for n in graph.nodes
                                  if isinstance(n, LinearGroupOp)),
        "launches": launch_count(graph),
        # intermediate tensors one execution writes to memory (every
        # consumed edge; the fused graph writes fewer)
        "materialized_edges": sum(1 for n in graph.nodes if consumers[n.id]),
    }


def f32_roundtrip_edges(graph: Graph, plan: QuantPlan
                        ) -> List[Tuple[int, int]]:
    """Edges that materialize f32 into a quantized GEMM engine under the plan.

    An edge (p -> c) round-trips when the producer emits f32 and the consumer
    is a GEMM engine (Conv PE / DWC PE / projection) that would have to
    re-quantize it dynamically.  A correct plan has none: in a CNN program
    everything internal is int8, and in an LM program every `ops.linear`
    input arrives pre-quantized at its static calibrated scale (the
    float-domain MISC edges -- attention math, residual stream -- are not
    roundtrips; those engines compute in f32 natively).
    """
    bad = []
    for n in graph.nodes:
        if not isinstance(n, _GEMM_OPS):
            continue
        ins = n.inputs
        ep = getattr(n, "epilogue", None)
        if ep is not None and ep.add:
            # the fused residual operand (last input) is MISC-side chain
            # math, not a GEMM operand -- an f32 residual stream is not a
            # roundtrip (the unfused AddOp consumed it f32 too)
            ins = ins[:-1]
        for p in ins:
            if not plan.emit_int8.get(p, False) and not isinstance(
                    graph.nodes[p], InputOp):
                bad.append((p, n.id))
    return bad


# ---------------------------------------------------------------------------
# Compile-time weight-layout folding (im2col reshape, DWC lane padding)
# ---------------------------------------------------------------------------

def set_param(params, path, value):
    """Copy-on-write update of a params pytree at a ParamPath."""
    if not path:
        return value
    k = path[0]
    if isinstance(params, dict):
        out = dict(params)
        out[k] = set_param(params[k], path[1:], value)
        return out
    if isinstance(params, (list, tuple)):
        out = list(params)
        out[k] = set_param(out[k], path[1:], value)
        return tuple(out) if isinstance(params, tuple) else out
    raise TypeError(f"cannot descend into {type(params).__name__} at {k!r}")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def fold_weight_layouts(graph: Graph, params):
    """Apply the kernels' weight layout transforms once, at compile time.

    Returns a new params tree (copy-on-write; untouched leaves shared) where

      * every non-stem ConvOp weight [k, k, IC, OC] is pre-reshaped to the
        Conv PE's im2col GEMM layout [k*k*IC, OC] (QTensor scales to
        [1, OC]), and
      * every DwcOp weight [k, k, C] (+ bias / per-channel scales) is
        pre-padded to the DWC engine's 128-lane width.

    kernels/ops.py recognizes both folded forms, so traced programs stop
    re-laying-out weights on every call (the zero-padding / bank-alignment
    steps of the paper move from trace time to compile time).  Results are
    bit-identical: reshape and zero-padding do not touch values.
    """
    out = params
    for n in graph.nodes:
        if isinstance(n, ConvOp) and not n.first_layer:
            w = get_param(out, n.w)
            q = w.q if isinstance(w, QTensor) else w
            if q.ndim != 4:
                continue                       # already folded
            k, _, ic, oc = q.shape
            mat = q.reshape(k * k * ic, oc)
            if isinstance(w, QTensor):
                out = set_param(out, n.w,
                                QTensor(mat, w.scale.reshape(1, oc)))
            else:
                out = set_param(out, n.w, mat)
        elif isinstance(n, DwcOp):
            w = get_param(out, n.w)
            q = w.q if isinstance(w, QTensor) else w
            c = q.shape[2]
            cp = _round_up(c, 128)
            if cp == c:
                continue                       # already aligned (or folded)
            pad = ((0, 0), (0, 0), (0, cp - c))
            if isinstance(w, QTensor):
                out = set_param(out, n.w, QTensor(
                    jnp.pad(q, pad),
                    jnp.pad(w.scale, ((0, 0), (0, 0), (0, cp - c)))))
            else:
                out = set_param(out, n.w, jnp.pad(q, pad))
            if n.b is not None:
                bias = get_param(out, n.b)
                out = set_param(out, n.b, jnp.pad(bias, (0, cp - c)))
    return out


def dynamic_roundtrip_count(graph: Graph) -> int:
    """How many edges the eager dynamic path round-trips through f32:
    every consumed edge between compute ops (the producer dequantizes to f32,
    the consumer re-quantizes per call).  The static plan's contrast line."""
    count = 0
    for n in graph.nodes:
        for p in n.inputs:
            if not isinstance(graph.nodes[p], InputOp):
                count += 1
    return count
