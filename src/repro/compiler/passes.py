"""Compiler passes: requant folding + engine-epilogue fusion planning.

Input: an op graph (graph.py) and per-edge calibrated activation scales
(calibrate.py).  Output: a QuantPlan that the static executor follows --
for every edge, the int8 scale it is carried at, and for every op, whether
its NL/RACNL epilogue requantizes straight to the consumer's scale.

The point (paper Section III-A / IV-B2): with static Vitis-AI-style scales,
activations stay int8 from engine to engine.  Each PE's epilogue performs
  dequant(int32 acc) -> bias -> activation -> requant(out_scale)
in one fused pass, so the only f32 tensor the whole program materializes is
the final logits.  The dynamic path (no plan) instead round-trips every edge
through f32 and re-quantizes per call -- the gap these passes close.

Folding rules:
  * max-pool is scale-preserving: it reuses its producer's scale verbatim
    (int8 values pass through untouched, no requant at all);
  * concat unifies its branch scales: each single-consumer producer requants
    directly to the concat's scale inside its own epilogue, so the concat is
    a pure bank interleave;
  * everything else requants in its producing engine's epilogue to its own
    calibrated scale.

Mixed-domain (LM) graphs: an edge is carried int8 only when its producer can
emit int8 from its epilogue AND every consumer natively consumes int8.  In a
CNN graph that is every internal edge (unchanged semantics).  In an LM graph
the residual stream, the attention q/k/v and the SwiGLU gate stay f32 on the
MISC core, while every edge feeding a Conv PE GEMM -- the norm outputs, the
attention context, the gate product -- is requantized once, statically, in
its producer's epilogue: `ops.linear` then consumes pre-quantized int8
activations with compile-time scales instead of dynamically re-quantizing
per token.

fold_weight_layouts() is the compile-time weight-layout pass: the im2col
reshape of Conv PE weights and the 128-lane zero-padding of DWC weights --
transforms the kernels historically re-applied on every traced call -- are
applied once to the parameter tree when a program is bound for serving.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax.numpy as jnp

from repro.compiler.graph import (AddOp, AttnOp, ConcatOp, ConvOp, DwcOp,
                                  Graph, InputOp, LinearOp, MulOp, NormOp,
                                  PoolOp, get_param)
from repro.core.quant import QTensor

_MIN_SCALE = 1e-8

# Which op kinds can emit int8 from their engine epilogue, and which consume
# int8 natively.  CNN kinds do both (the historical all-int8 dataflow); the
# LM float-domain ops (norm input, attention math, the gate product inputs,
# the logits head) keep f32 operands on the MISC core.
_INT8_EMIT = (InputOp, ConvOp, DwcOp, AddOp, PoolOp, ConcatOp, LinearOp,
              NormOp, AttnOp, MulOp)
_INT8_CONSUME = (ConvOp, DwcOp, LinearOp, AddOp, PoolOp, ConcatOp)
# The quantized-GEMM engines: an f32 edge into one of these is a "roundtrip"
# (the engine would have to re-quantize dynamically per call).
_GEMM_OPS = (ConvOp, DwcOp, LinearOp)


@dataclass(frozen=True)
class QuantPlan:
    """Static-int8 execution plan for one graph."""
    # node id -> scale its OUTPUT edge is carried at (int8 value * scale =
    # f32).  A float is a per-tensor scale; a tuple of floats is a
    # per-channel (last-dim) scale on an edge whose consumers are all
    # channelwise engines (DWC PE).
    out_scale: Dict[int, object]
    # node id -> does the node emit int8 (False only for the logits)
    emit_int8: Dict[int, bool]
    # edges whose requant was folded into the producer epilogue for a
    # *different* consumer scale (concat unification): (producer, consumer)
    folded: Tuple[Tuple[int, int], ...]
    stats: Dict[str, int] = field(default_factory=dict)


def fold_requant(graph: Graph, scales: Dict[int, object],
                 granularity: str = "per_tensor") -> QuantPlan:
    """Assign every edge a static int8 scale and fold requants into the
    producing engines' epilogues.

    granularity="per_channel" (with tuple-valued scales from a per-channel
    calibration) keeps the channel vector only where the hardware can carry
    it: the consuming engine must be channelwise (every consumer a DwcOp --
    a per-K-channel activation scale cannot be factored out of a GEMM
    accumulation), and the producing epilogue must requant per-channel
    (InputOp boundary quant or a Conv PE output epilogue).  Every other
    edge collapses to the channel max -- exactly its per-tensor scale."""
    missing = [n.id for n in graph.nodes if n.id not in scales]
    if missing:
        raise ValueError(
            f"calibration scales missing for nodes {missing}; "
            "run compiler.calibrate over representative batches first")

    def _norm(v):
        if isinstance(v, tuple):
            return tuple(max(float(x), _MIN_SCALE) for x in v)
        return max(float(v), _MIN_SCALE)

    out_scale = {i: _norm(scales[i]) for i in scales}
    consumers = graph.consumers()
    emit_int8 = {
        n.id: (n.id != graph.output
               and isinstance(n, _INT8_EMIT)
               and bool(consumers[n.id])
               and all(isinstance(graph.nodes[c], _INT8_CONSUME)
                       for c in consumers[n.id]))
        for n in graph.nodes
    }

    per_channel = collapsed = 0
    for n in graph.nodes:
        s = out_scale[n.id]
        if not isinstance(s, tuple):
            continue
        keep = (granularity == "per_channel"
                and emit_int8[n.id]
                and all(isinstance(graph.nodes[c], DwcOp)
                        for c in consumers[n.id])
                and (isinstance(n, InputOp)
                     or (isinstance(n, ConvOp) and not n.first_layer)))
        if keep:
            per_channel += 1
        else:
            out_scale[n.id] = max(s)
            collapsed += 1

    folded: List[Tuple[int, int]] = []

    for n in graph.nodes:
        if isinstance(n, PoolOp) and n.pool == "max":
            # Scale-preserving: int8 values flow through the MISC comparator
            # untouched, so the output edge inherits the input's scale.
            out_scale[n.id] = out_scale[n.inputs[0]]
        elif isinstance(n, ConcatOp):
            # Unify branch scales: each branch engine requants to the concat
            # scale in its own epilogue (possible only when this concat is
            # the branch's sole consumer; otherwise the executor rescales
            # int8->int8 at the concat input instead).
            s = out_scale[n.id]
            for p in n.inputs:
                if len(consumers[p]) == 1 and isinstance(
                        graph.nodes[p], (ConvOp, DwcOp, AddOp)):
                    out_scale[p] = s
                    folded.append((p, n.id))

    stats = dict(fusion_stats(graph))
    stats["folded_requants"] = len(folded)
    stats["dynamic_f32_roundtrips"] = dynamic_roundtrip_count(graph)
    stats["per_channel_edges"] = per_channel
    stats["per_tensor_collapsed"] = collapsed
    return QuantPlan(out_scale=out_scale, emit_int8=emit_int8,
                     folded=tuple(folded), stats=stats)


# ---------------------------------------------------------------------------
# Fusion analysis (conv -> add -> relu residual chains on the MISC core)
# ---------------------------------------------------------------------------

def residual_chains(graph: Graph) -> List[Tuple[int, int]]:
    """(conv_id, add_id) pairs where a Conv PE output feeds a MISC add --
    the paper's conv->add->relu bottleneck epilogue."""
    chains = []
    for n in graph.nodes:
        if isinstance(n, AddOp):
            for p in n.inputs:
                if isinstance(graph.nodes[p], (ConvOp, DwcOp)):
                    chains.append((p, n.id))
    return chains


def fusion_stats(graph: Graph) -> Dict[str, int]:
    chains = residual_chains(graph)
    return {
        "residual_chains": len(chains),
        "misc_adds": graph.count(AddOp),
        "convs": graph.count(ConvOp),
        "dwcs": graph.count(DwcOp),
    }


def f32_roundtrip_edges(graph: Graph, plan: QuantPlan
                        ) -> List[Tuple[int, int]]:
    """Edges that materialize f32 into a quantized GEMM engine under the plan.

    An edge (p -> c) round-trips when the producer emits f32 and the consumer
    is a GEMM engine (Conv PE / DWC PE / projection) that would have to
    re-quantize it dynamically.  A correct plan has none: in a CNN program
    everything internal is int8, and in an LM program every `ops.linear`
    input arrives pre-quantized at its static calibrated scale (the
    float-domain MISC edges -- attention math, residual stream -- are not
    roundtrips; those engines compute in f32 natively).
    """
    bad = []
    for n in graph.nodes:
        if not isinstance(n, _GEMM_OPS):
            continue
        for p in n.inputs:
            if not plan.emit_int8.get(p, False) and not isinstance(
                    graph.nodes[p], InputOp):
                bad.append((p, n.id))
    return bad


# ---------------------------------------------------------------------------
# Compile-time weight-layout folding (im2col reshape, DWC lane padding)
# ---------------------------------------------------------------------------

def set_param(params, path, value):
    """Copy-on-write update of a params pytree at a ParamPath."""
    if not path:
        return value
    k = path[0]
    if isinstance(params, dict):
        out = dict(params)
        out[k] = set_param(params[k], path[1:], value)
        return out
    if isinstance(params, (list, tuple)):
        out = list(params)
        out[k] = set_param(out[k], path[1:], value)
        return tuple(out) if isinstance(params, tuple) else out
    raise TypeError(f"cannot descend into {type(params).__name__} at {k!r}")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def fold_weight_layouts(graph: Graph, params):
    """Apply the kernels' weight layout transforms once, at compile time.

    Returns a new params tree (copy-on-write; untouched leaves shared) where

      * every non-stem ConvOp weight [k, k, IC, OC] is pre-reshaped to the
        Conv PE's im2col GEMM layout [k*k*IC, OC] (QTensor scales to
        [1, OC]), and
      * every DwcOp weight [k, k, C] (+ bias / per-channel scales) is
        pre-padded to the DWC engine's 128-lane width.

    kernels/ops.py recognizes both folded forms, so traced programs stop
    re-laying-out weights on every call (the zero-padding / bank-alignment
    steps of the paper move from trace time to compile time).  Results are
    bit-identical: reshape and zero-padding do not touch values.
    """
    out = params
    for n in graph.nodes:
        if isinstance(n, ConvOp) and not n.first_layer:
            w = get_param(out, n.w)
            q = w.q if isinstance(w, QTensor) else w
            if q.ndim != 4:
                continue                       # already folded
            k, _, ic, oc = q.shape
            mat = q.reshape(k * k * ic, oc)
            if isinstance(w, QTensor):
                out = set_param(out, n.w,
                                QTensor(mat, w.scale.reshape(1, oc)))
            else:
                out = set_param(out, n.w, mat)
        elif isinstance(n, DwcOp):
            w = get_param(out, n.w)
            q = w.q if isinstance(w, QTensor) else w
            c = q.shape[2]
            cp = _round_up(c, 128)
            if cp == c:
                continue                       # already aligned (or folded)
            pad = ((0, 0), (0, 0), (0, cp - c))
            if isinstance(w, QTensor):
                out = set_param(out, n.w, QTensor(
                    jnp.pad(q, pad),
                    jnp.pad(w.scale, ((0, 0), (0, 0), (0, cp - c)))))
            else:
                out = set_param(out, n.w, jnp.pad(q, pad))
            if n.b is not None:
                bias = get_param(out, n.b)
                out = set_param(out, n.b, jnp.pad(bias, (0, cp - c)))
    return out


def dynamic_roundtrip_count(graph: Graph) -> int:
    """How many edges the eager dynamic path round-trips through f32:
    every consumed edge between compute ops (the producer dequantizes to f32,
    the consumer re-quantizes per call).  The static plan's contrast line."""
    count = 0
    for n in graph.nodes:
        for p in n.inputs:
            if not isinstance(graph.nodes[p], InputOp):
                count += 1
    return count
