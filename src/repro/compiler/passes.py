"""Compiler passes: requant folding + engine-epilogue fusion planning.

Input: an op graph (graph.py) and per-edge calibrated activation scales
(calibrate.py).  Output: a QuantPlan that the static executor follows --
for every edge, the int8 scale it is carried at, and for every op, whether
its NL/RACNL epilogue requantizes straight to the consumer's scale.

The point (paper Section III-A / IV-B2): with static Vitis-AI-style scales,
activations stay int8 from engine to engine.  Each PE's epilogue performs
  dequant(int32 acc) -> bias -> activation -> requant(out_scale)
in one fused pass, so the only f32 tensor the whole program materializes is
the final logits.  The dynamic path (no plan) instead round-trips every edge
through f32 and re-quantizes per call -- the gap these passes close.

Folding rules:
  * max-pool is scale-preserving: it reuses its producer's scale verbatim
    (int8 values pass through untouched, no requant at all);
  * concat unifies its branch scales: each single-consumer producer requants
    directly to the concat's scale inside its own epilogue, so the concat is
    a pure bank interleave;
  * everything else requants in its producing engine's epilogue to its own
    calibrated scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, Graph,
                                  InputOp, PoolOp)

_MIN_SCALE = 1e-8


@dataclass(frozen=True)
class QuantPlan:
    """Static-int8 execution plan for one graph."""
    # node id -> scale its OUTPUT edge is carried at (int8 value * scale = f32)
    out_scale: Dict[int, float]
    # node id -> does the node emit int8 (False only for the logits)
    emit_int8: Dict[int, bool]
    # edges whose requant was folded into the producer epilogue for a
    # *different* consumer scale (concat unification): (producer, consumer)
    folded: Tuple[Tuple[int, int], ...]
    stats: Dict[str, int] = field(default_factory=dict)


def fold_requant(graph: Graph, scales: Dict[int, float]) -> QuantPlan:
    """Assign every edge a static int8 scale and fold requants into the
    producing engines' epilogues."""
    missing = [n.id for n in graph.nodes if n.id not in scales]
    if missing:
        raise ValueError(
            f"calibration scales missing for nodes {missing}; "
            "run compiler.calibrate over representative batches first")

    out_scale = {i: max(float(scales[i]), _MIN_SCALE) for i in scales}
    emit_int8 = {n.id: True for n in graph.nodes}
    emit_int8[graph.output] = False          # logits stay f32
    consumers = graph.consumers()
    folded: List[Tuple[int, int]] = []

    for n in graph.nodes:
        if isinstance(n, PoolOp) and n.pool == "max":
            # Scale-preserving: int8 values flow through the MISC comparator
            # untouched, so the output edge inherits the input's scale.
            out_scale[n.id] = out_scale[n.inputs[0]]
        elif isinstance(n, ConcatOp):
            # Unify branch scales: each branch engine requants to the concat
            # scale in its own epilogue (possible only when this concat is
            # the branch's sole consumer; otherwise the executor rescales
            # int8->int8 at the concat input instead).
            s = out_scale[n.id]
            for p in n.inputs:
                if len(consumers[p]) == 1 and isinstance(
                        graph.nodes[p], (ConvOp, DwcOp, AddOp)):
                    out_scale[p] = s
                    folded.append((p, n.id))

    stats = dict(fusion_stats(graph))
    stats["folded_requants"] = len(folded)
    stats["dynamic_f32_roundtrips"] = dynamic_roundtrip_count(graph)
    return QuantPlan(out_scale=out_scale, emit_int8=emit_int8,
                     folded=tuple(folded), stats=stats)


# ---------------------------------------------------------------------------
# Fusion analysis (conv -> add -> relu residual chains on the MISC core)
# ---------------------------------------------------------------------------

def residual_chains(graph: Graph) -> List[Tuple[int, int]]:
    """(conv_id, add_id) pairs where a Conv PE output feeds a MISC add --
    the paper's conv->add->relu bottleneck epilogue."""
    chains = []
    for n in graph.nodes:
        if isinstance(n, AddOp):
            for p in n.inputs:
                if isinstance(graph.nodes[p], (ConvOp, DwcOp)):
                    chains.append((p, n.id))
    return chains


def fusion_stats(graph: Graph) -> Dict[str, int]:
    chains = residual_chains(graph)
    return {
        "residual_chains": len(chains),
        "misc_adds": graph.count(AddOp),
        "convs": graph.count(ConvOp),
        "dwcs": graph.count(DwcOp),
    }


def f32_roundtrip_edges(graph: Graph, plan: QuantPlan
                        ) -> List[Tuple[int, int]]:
    """Edges that materialize f32 between two engines under the plan.

    An edge (p -> c) round-trips when the producer emits f32 and the consumer
    is a quantized engine that would have to re-quantize it.  A correct plan
    has none: the only f32 value is the graph output, which has no consumer.
    """
    bad = []
    for n in graph.nodes:
        for p in n.inputs:
            if not plan.emit_int8.get(p, False) and not isinstance(
                    graph.nodes[p], InputOp):
                bad.append((p, n.id))
    return bad


def dynamic_roundtrip_count(graph: Graph) -> int:
    """How many edges the eager dynamic path round-trips through f32:
    every consumed edge between compute ops (the producer dequantizes to f32,
    the consumer re-quantizes per call).  The static plan's contrast line."""
    count = 0
    for n in graph.nodes:
        for p in n.inputs:
            if not isinstance(graph.nodes[p], InputOp):
                count += 1
    return count
