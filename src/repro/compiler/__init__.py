"""Model -> engine-program compiler (the paper's instruction-driven flow).

Pipeline (model-agnostic: the same IR and passes serve both frontends):

    graph.build_graph(cfg)                  # CNN  -> typed op-graph IR
    graph.lower_transformer(arch)           # LM prefill -> same IR
    calibrate.calibrate(g, params, batches) # per-edge activation scales
    passes.fold_requant(g, scales)          # static int8 plan (+ fusion)
    passes.fold_weight_layouts(g, params)   # compile-time weight layouts
    schedule.level_schedule(g, policy)      # concurrent-PE dispatch waves
    executor.execute(program, ...)          # run on ref / pallas / baseline

`compile_cnn(cfg)` / `compile_lm(arch)` yield the dynamic (eager-equivalent)
programs used by models.cnn.cnn_forward and the serving fallback;
`compile_calibrated(...)` / `compile_lm_calibrated(...)` yield static int8
programs: CNN activations stay int8 engine-to-engine, and every LM GEMM
input arrives pre-quantized at its calibrated static scale.  All programs
carry the level schedule by default (`scheduled=False` opts out, for parity
tests; `policy="alap"` slides slack ops toward consumers).  Compiled dynamic
programs are memoized in executor.program_cache(), and the serving layer
(repro.serve) keys full calibrated programs by (model config, EngineConfig,
calibration-id) in its own ProgramCache.
"""
from repro.compiler.calibrate import (ChannelCalibrator, PercentileCalibrator,
                                      calibrate, make_calibrator)
from repro.compiler.executor import (Program, compile_cnn, compile_lm,
                                     execute, execute_decode,
                                     execute_interleaved, prefill_from,
                                     program_cache, rope_table_stats,
                                     schedule_variant)
from repro.compiler.graph import (AddOp, AttnOp, ConcatOp, ConvOp, DwcOp,
                                  EmbedOp, Epilogue, Graph, HeadOp, InputOp,
                                  LinearGroupOp, LinearOp, MulOp, NormOp,
                                  PoolOp, ViewOp, build_graph, can_lower,
                                  get_param, lower_transformer,
                                  lowering_blockers)
from repro.compiler.passes import (QuantPlan, dynamic_roundtrip_count,
                                   f32_roundtrip_edges, fold_requant,
                                   fold_weight_layouts, fuse_epilogues,
                                   fuse_projections, fusion_stats,
                                   launch_count, residual_chains, set_param)
from repro.compiler.schedule import (MergedSchedule, Schedule,
                                     engine_occupancy, engine_unit,
                                     level_schedule, merge_schedules,
                                     modeled_makespan, schedule_stats,
                                     time_weighted_occupancy,
                                     validate_merged, validate_schedule)


def compile_calibrated(cfg, params, batches, eng=None,
                       scheduled: bool = True, policy: str = "asap",
                       method: str = "absmax",
                       granularity: str = "per_tensor",
                       fuse: bool = True) -> Program:
    """Float params + representative batches -> static int8 engine program.

    Calibration observes the UNFUSED graph (its edges are what the scales
    describe); `fuse` (default ON) then rewrites epilogue chains into fused
    launches, remapping the scales onto the fused graph and baking the
    absorbed interior edges' scales into the Epilogue specs."""
    g = build_graph(cfg)
    scales = calibrate(g, params, batches, cfg, eng=eng, method=method,
                       granularity=granularity)
    return compile_cnn(cfg, scales=scales, scheduled=scheduled, policy=policy,
                       granularity=granularity, fuse=fuse)


def calibrate_lm(arch, params, batches, eng=None, method: str = "absmax",
                 granularity: str = "per_tensor"):
    """One LM calibration run -> per-edge scales shared by every program
    variant of the arch.

    Calibration always executes the FULL graph (`lower_transformer(arch)`);
    the prefill variant shares its node sequence exactly, and the decode
    graph mirrors it node for node (graph.lower_transformer docstring), so
    the same {node_id: scale} dict statically quantizes the full, prefill
    AND decode programs -- the serving layer calibrates once per
    registration, not once per program."""
    g = lower_transformer(arch)
    return calibrate(g, params, batches, arch, eng=eng, method=method,
                     granularity=granularity)


def compile_lm_calibrated(arch, params, batches, eng=None,
                          scheduled: bool = True, policy: str = "asap",
                          method: str = "absmax",
                          prefill: bool = False, mode=None,
                          scales=None,
                          granularity: str = "per_tensor") -> Program:
    """Float params + representative token batches -> static int8 LM
    program (every `ops.linear` input gets a static scale).

    mode selects the program ("full" / "prefill" / "decode"); the legacy
    `prefill=True` flag is shorthand for mode="prefill".  All modes share
    one calibration run (calibrate_lm); pass `scales` to reuse a run
    across modes without re-executing the calibration batches."""
    if scales is None:
        scales = calibrate_lm(arch, params, batches, eng=eng, method=method,
                              granularity=granularity)
    return compile_lm(arch, scales=scales, scheduled=scheduled,
                      policy=policy, prefill=prefill, mode=mode,
                      granularity=granularity)


__all__ = [
    "AddOp", "AttnOp", "ChannelCalibrator", "ConcatOp", "ConvOp", "DwcOp",
    "EmbedOp", "Epilogue", "Graph", "HeadOp", "InputOp", "LinearGroupOp",
    "LinearOp", "MergedSchedule", "MulOp", "NormOp", "PercentileCalibrator",
    "PoolOp", "Program", "QuantPlan", "Schedule", "ViewOp", "build_graph",
    "calibrate", "calibrate_lm", "can_lower",
    "compile_calibrated", "compile_cnn", "compile_lm",
    "compile_lm_calibrated", "dynamic_roundtrip_count", "engine_occupancy",
    "engine_unit", "execute", "execute_decode", "execute_interleaved",
    "f32_roundtrip_edges",
    "fold_requant", "fold_weight_layouts", "fuse_epilogues",
    "fuse_projections", "fusion_stats", "get_param", "launch_count",
    "level_schedule", "lower_transformer", "lowering_blockers",
    "make_calibrator", "merge_schedules", "modeled_makespan",
    "prefill_from", "program_cache", "residual_chains",
    "rope_table_stats", "schedule_stats", "schedule_variant", "set_param",
    "time_weighted_occupancy", "validate_merged", "validate_schedule",
]
