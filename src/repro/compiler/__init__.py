"""CNN -> engine-program compiler (the paper's instruction-driven flow).

Pipeline:

    graph.build_graph(cfg)                  # typed op-graph IR
    calibrate.calibrate(g, params, batches) # per-edge activation scales
    passes.fold_requant(g, scales)          # static int8 plan (+ fusion)
    schedule.level_schedule(g)              # concurrent-PE dispatch waves
    executor.execute(program, ...)          # run on ref / pallas / baseline

`compile_cnn(cfg)` yields the dynamic (eager-equivalent) program used by
models.cnn.cnn_forward; `compile_calibrated(...)` yields the static int8
program where activations stay int8 engine-to-engine.  Both carry the
level schedule by default (`scheduled=False` opts out, for parity tests);
compiled dynamic programs are memoized in executor.program_cache(), and the
serving layer (repro.serve.cnn_engine) keys full calibrated programs by
(CNNConfig, EngineConfig, calibration-id) in its own ProgramCache.
"""
from repro.compiler.calibrate import calibrate
from repro.compiler.executor import (Program, compile_cnn, execute,
                                     program_cache)
from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, Graph,
                                  InputOp, LinearOp, PoolOp, build_graph,
                                  get_param)
from repro.compiler.passes import (QuantPlan, dynamic_roundtrip_count,
                                   f32_roundtrip_edges, fold_requant,
                                   fusion_stats, residual_chains)
from repro.compiler.schedule import (Schedule, engine_unit, level_schedule,
                                     schedule_stats, validate_schedule)


def compile_calibrated(cfg, params, batches, eng=None,
                       scheduled: bool = True) -> Program:
    """Float params + representative batches -> static int8 engine program."""
    g = build_graph(cfg)
    scales = calibrate(g, params, batches, cfg, eng=eng)
    return compile_cnn(cfg, scales=scales, scheduled=scheduled)


__all__ = [
    "AddOp", "ConcatOp", "ConvOp", "DwcOp", "Graph", "InputOp", "LinearOp",
    "PoolOp", "Program", "QuantPlan", "Schedule", "build_graph", "calibrate",
    "compile_calibrated", "compile_cnn", "dynamic_roundtrip_count",
    "engine_unit", "execute", "f32_roundtrip_edges", "fold_requant",
    "fusion_stats", "get_param", "level_schedule", "program_cache",
    "residual_chains", "schedule_stats", "validate_schedule",
]
