"""CNN -> engine-program compiler (the paper's instruction-driven flow).

Pipeline:

    graph.build_graph(cfg)                  # typed op-graph IR
    calibrate.calibrate(g, params, batches) # per-edge activation scales
    passes.fold_requant(g, scales)          # static int8 plan (+ fusion)
    executor.execute(program, ...)          # run on ref / pallas / baseline

`compile_cnn(cfg)` yields the dynamic (eager-equivalent) program used by
models.cnn.cnn_forward; `compile_calibrated(...)` yields the static int8
program where activations stay int8 engine-to-engine.
"""
from repro.compiler.calibrate import calibrate
from repro.compiler.executor import Program, compile_cnn, execute
from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, Graph,
                                  InputOp, LinearOp, PoolOp, build_graph,
                                  get_param)
from repro.compiler.passes import (QuantPlan, dynamic_roundtrip_count,
                                   f32_roundtrip_edges, fold_requant,
                                   fusion_stats, residual_chains)


def compile_calibrated(cfg, params, batches, eng=None) -> Program:
    """Float params + representative batches -> static int8 engine program."""
    g = build_graph(cfg)
    scales = calibrate(g, params, batches, cfg, eng=eng)
    return compile_cnn(cfg, scales=scales)


__all__ = [
    "AddOp", "ConcatOp", "ConvOp", "DwcOp", "Graph", "InputOp", "LinearOp",
    "PoolOp", "Program", "QuantPlan", "build_graph", "calibrate",
    "compile_calibrated", "compile_cnn", "dynamic_roundtrip_count",
    "execute", "f32_roundtrip_edges", "fold_requant", "fusion_stats",
    "get_param", "residual_chains",
]
