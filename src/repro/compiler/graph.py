"""Typed op-graph IR: the model-agnostic engine program.

The paper's DPU is instruction-driven (Section III-A): the Vitis-AI compiler
turns a model graph into Conv PE / DWC PE / MISC instructions and the engines
execute the resulting program.  This module is our analogue of that IR: a
flat, topologically-ordered tuple of typed op nodes, each naming its input
edges (producer node ids) and the parameter-tree paths it reads.  Two
frontends lower into it: `build_graph(CNNConfig)` for the paper's CNN zoo
and `lower_transformer(ArchConfig)` for LM prefill -- the paper's thesis
that one engine covers whole models ("extend the functionality of each PE",
Section III) made concrete: GEMM-shaped ops ride the Conv PE, everything
else the MISC core.

Node kinds and the engine that executes them:

  ConvOp    -> Conv PE (im2col GEMM; `first_layer=True` routes the stem to
               the Low-Channel Conv Unit; may carry a fused `Epilogue` --
               residual add / pool tail absorbed by passes.fuse_epilogues)
  DwcOp     -> DWC PE (same optional fused `Epilogue`)
  AddOp     -> MISC core (residual add + NL epilogue)
  PoolOp    -> MISC core ("max" | "avg" | "global")
  ConcatOp  -> bank interleave (channel concat; free at the memory level)
  LinearOp  -> Conv PE (classifier head / LM projection GEMM; may carry a
               fused residual-add `Epilogue` after passes.fuse_epilogues)
  LinearGroupOp -> Conv PE (one launch, several output operands: the fused
               Q/K/V and gate/up projection groups of passes.fuse_projections)
  ViewOp    -> memory level (selects one member of a LinearGroupOp's tuple)
  MulOp     -> MISC core (elementwise gate, SwiGLU/GeGLU)
  NormOp    -> MISC core (RMS norm + requant epilogue)
  AttnOp    -> MISC core (RoPE + online-softmax attention between GEMMs)
  EmbedOp   -> memory level (token-row gather)
  HeadOp    -> Conv PE (the LM logits GEMM, tied or untied)
  InputOp   -> the program input placeholder (edge 0: image or token ids)

A node's id doubles as the id of its output edge, so per-edge metadata
(calibrated activation scales, emit dtypes) is keyed by node id.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.config import ArchConfig, CNNConfig

# A path into the params pytree, e.g. ("stages", 2, 0, "w1").
ParamPath = Tuple


@dataclass(frozen=True)
class OpNode:
    id: int
    inputs: Tuple[int, ...]

    @property
    def kind(self) -> str:
        return type(self).__name__.replace("Op", "").lower()


@dataclass(frozen=True)
class Epilogue:
    """An in-kernel tail fused into a Conv PE / DWC PE launch.

    `passes.fuse_epilogues` collapses Conv/DWC -> {residual Add, pool tail}
    chains into one fused node carrying this spec, so the whole chain is a
    single engine launch: the MISC work rides the producing PE's NL/RACNL
    epilogue instead of materializing an intermediate tensor and paying the
    bandwidth-starved MISC path (the paper's "extend the functionality of
    each PE", Section III).

    add=True appends the residual operand as the node's LAST input edge;
    `add_act` is the post-add activation (the absorbed AddOp's act).  `pool`
    is an absorbed tail pool ("avg" | "global" | "max"); avg uses
    pool_kernel/pool_stride (VALID windows, like the standalone PoolOp).

    `mid_scale` / `add_scale` are the static-plan interior requant points
    (compile-time constants, like the requant shifts a real DPU instruction
    stream carries): the scales the absorbed conv / add output edges carried
    in the unfused graph.  The fused kernel quantize-dequantizes in-register
    at exactly those points, so fused static execution is BIT-IDENTICAL to
    the unfused program while materializing nothing between the stages.
    0.0 = dynamic program (no static plan; the chain stays f32 in-register).
    """
    add: bool = False
    add_act: str = "none"
    pool: str = "none"               # none | avg | global | max
    pool_kernel: int = 0
    pool_stride: int = 0
    mid_scale: float = 0.0           # absorbed conv/dwc output edge scale
    add_scale: float = 0.0           # absorbed add output edge scale
                                     # (set only when a pool follows the add)

    @property
    def stages(self) -> str:
        """Human-readable chain, e.g. "add+relu|global"."""
        parts = []
        if self.add:
            parts.append("add" if self.add_act == "none"
                         else f"add+{self.add_act}")
        if self.pool != "none":
            parts.append(self.pool)
        return "|".join(parts)


@dataclass(frozen=True)
class InputOp(OpNode):
    pass


@dataclass(frozen=True)
class ConvOp(OpNode):
    w: ParamPath = ()
    b: Optional[ParamPath] = None
    stride: int = 1
    padding: str = "SAME"
    act: str = "none"
    first_layer: bool = False        # route through the Low-Channel unit
    epilogue: Optional[Epilogue] = None   # fused MISC tail (fuse_epilogues)


@dataclass(frozen=True)
class DwcOp(OpNode):
    w: ParamPath = ()
    b: Optional[ParamPath] = None
    stride: int = 1
    padding: str = "SAME"
    act: str = "none"
    epilogue: Optional[Epilogue] = None   # fused MISC tail (fuse_epilogues)


@dataclass(frozen=True)
class AddOp(OpNode):
    act: str = "none"


@dataclass(frozen=True)
class PoolOp(OpNode):
    pool: str = "max"                # max | avg | global
    kernel: int = 2
    stride: int = 2


@dataclass(frozen=True)
class ConcatOp(OpNode):
    pass                             # channel (last-axis) concat


@dataclass(frozen=True)
class LinearOp(OpNode):
    """Projection / classifier GEMM on the Conv PE.  `epilogue` (from
    passes.fuse_epilogues) absorbs a residual-add tail -- the MISC add after
    an O/down projection rides the GEMM launch; pool tails never attach to
    LinearOps (LM graphs have none)."""
    w: ParamPath = ()
    b: Optional[ParamPath] = None
    act: str = "none"
    epilogue: Optional[Epilogue] = None


@dataclass(frozen=True)
class LinearGroupOp(OpNode):
    """A fused multi-output projection group: several LinearOps that share
    one input (Q/K/V, gate/up) collapsed by passes.fuse_projections into ONE
    Conv PE launch with one output operand per member (the XEGEMM
    hgemm_qkv_wint4 idiom: the activation row is read and quantized once,
    all member columns MAC in the same grid).

    The node's value is a TUPLE of member outputs, ordered like `ws`; each
    member's consumers read it through a ViewOp (a memory-level alias, free
    like ConcatOp).  Per-member bias paths use None for members without a
    bias; `acts` carries each member's activation.
    """
    ws: Tuple[ParamPath, ...] = ()
    bs: Tuple[Optional[ParamPath], ...] = ()
    acts: Tuple[str, ...] = ()


@dataclass(frozen=True)
class ViewOp(OpNode):
    """Selects member `index` of a LinearGroupOp's output tuple.  Purely a
    memory-level alias (no engine launch, excluded from launch counts);
    exists so downstream nodes keep single-edge inputs and the
    node-id == edge-id invariant survives multi-output fusion."""
    index: int = 0


# --- LM (transformer prefill) op kinds --------------------------------------

@dataclass(frozen=True)
class EmbedOp(OpNode):
    """Token embedding gather.  emb_scale is the resolved multiplier
    (sqrt(d_model) for gemma-style archs, 0.0 = off)."""
    w: ParamPath = ()
    emb_scale: float = 0.0


@dataclass(frozen=True)
class NormOp(OpNode):
    """RMS norm on the MISC core; its requant epilogue is what hands the
    Conv PE GEMMs their static-int8 inputs in a calibrated program."""
    w: ParamPath = ()
    eps: float = 1e-6


@dataclass(frozen=True)
class MulOp(OpNode):
    """Elementwise product (SwiGLU/GeGLU gate) on the MISC core."""
    pass


@dataclass(frozen=True)
class AttnOp(OpNode):
    """RoPE + online-softmax attention between the QKV and output GEMMs.
    inputs = (q, k, v) projection edges, each [B, L, heads*head_dim].
    `layer` keys the collected (k, v) pair for serving-cache fill.

    mode="full":   full-sequence causal attention (prefill / training).
    mode="chunk":  chunked (partial) prefill over a block-paged cache --
      the program input is the TAIL [B, T] of a prompt whose first
      `start` positions already sit in shared prefix pages.  The op
      roundtrips the fresh tail k/v through the cache dtype (so any
      page-aligned split point yields bit-identical logits to running
      the whole prompt through this program), scatters it into the
      slot's OWNED tail pages (`_paged_tail_store`; shared prefix pages
      are never written -- the copy-on-write boundary), and attends the
      tail queries at offset `start` against the gathered cache view.
    mode="update": the cache-state recurrence of a DecodeStep program --
      the new (k, v) pairs are written into the serving KV cache at the
      slot's position index (ring-indexed for local layers), then the
      query attends against the whole cache.  The executor threads the
      cache through `execute_decode`; a [B, k] token input runs the same
      node as a roll-back-free draft-verification step (execute_verify).

    page_size > 0 (update mode, global layers only): the cache state is
    BLOCK-PAGED -- the op indexes a shared [num_blocks, page, Hkv, D] pool
    through the slot's row of the block table (cache["tables"]) instead of
    a dense per-slot [B, max_seq] buffer, so serving admits requests by
    free blocks rather than worst-case length.  Local (ring) layers stay
    dense: their window already bounds per-slot memory."""
    layer: int = 0
    layer_kind: str = "global"
    n_heads: int = 1
    n_kv_heads: int = 1
    head_dim: int = 1
    rope_theta: float = 10000.0
    softcap: float = 0.0
    window: int = 0                  # >0: local attention window
    mode: str = "full"               # full | chunk | update (cache step)
    page_size: int = 0               # >0: block-paged cache (chunk/update)


@dataclass(frozen=True)
class HeadOp(OpNode):
    """LM logits GEMM.  tied=True reads the embedding table ([V, d], used
    transposed); otherwise a [d, V] head matrix.  last_only=True emits only
    the final position's logits (the serving-prefill program)."""
    w: ParamPath = ()
    tied: bool = True
    softcap: float = 0.0
    last_only: bool = False


@dataclass(frozen=True)
class Graph:
    """Topologically ordered op list; nodes[i].id == i."""
    nodes: Tuple[OpNode, ...]
    output: int
    name: str = ""

    def consumers(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for i in n.inputs:
                out[i].append(n.id)
        return out

    def count(self, cls) -> int:
        return sum(isinstance(n, cls) for n in self.nodes)


def get_param(params, path: Optional[ParamPath]):
    """Resolve a ParamPath against the (possibly quantized) params pytree.
    None (an op with no bias) resolves to None."""
    if path is None:
        return None
    v = params
    for k in path:
        v = v[k]
    return v


class _Builder:
    def __init__(self):
        self.nodes: List[OpNode] = []

    def add(self, cls, inputs, **attrs) -> int:
        nid = len(self.nodes)
        self.nodes.append(cls(id=nid, inputs=tuple(inputs), **attrs))
        return nid


def build_graph(cfg: CNNConfig) -> Graph:
    """Lower a CNNConfig to the engine op-graph.

    Mirrors the zoo's stage semantics (models/cnn.py docstring): conv,
    bottleneck, inverted, dwsep, fire, pool.  Channel bookkeeping here must
    match cnn_schema(), which owns the parameter shapes.
    """
    b = _Builder()
    x = b.add(InputOp, [])
    x = b.add(ConvOp, [x], w=("stem_w",), b=("stem_b",),
              stride=cfg.stem_stride, act="relu", first_layer=True)
    ch = cfg.stem_ch
    for si, st in enumerate(cfg.stages):
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            p: ParamPath = ("stages", si, r)
            if st.kind == "conv":
                x = b.add(ConvOp, [x], w=p + ("w",), b=p + ("b",),
                          stride=stride, act="relu")
                ch = st.out_ch
            elif st.kind == "bottleneck":
                h = b.add(ConvOp, [x], w=p + ("w1",), b=p + ("b1",),
                          act="relu")
                h = b.add(ConvOp, [h], w=p + ("w2",), b=p + ("b2",),
                          stride=stride, act="relu")
                h = b.add(ConvOp, [h], w=p + ("w3",), b=p + ("b3",))
                skip = x
                if ch != st.out_ch or stride != 1:
                    skip = b.add(ConvOp, [x], w=p + ("wskip",),
                                 b=p + ("bskip",), stride=stride)
                x = b.add(AddOp, [h, skip], act="relu")
                ch = st.out_ch
            elif st.kind == "inverted":
                h = b.add(ConvOp, [x], w=p + ("we",), b=p + ("be",),
                          act="relu6")
                h = b.add(DwcOp, [h], w=p + ("wd",), b=p + ("bd",),
                          stride=stride, act="relu6")
                h = b.add(ConvOp, [h], w=p + ("wp",), b=p + ("bp",))
                if stride == 1 and ch == st.out_ch:
                    x = b.add(AddOp, [h, x])
                else:
                    x = h
                ch = st.out_ch
            elif st.kind == "dwsep":
                h = b.add(DwcOp, [x], w=p + ("wd",), b=p + ("bd",),
                          stride=stride, act="relu")
                x = b.add(ConvOp, [h], w=p + ("wp",), b=p + ("bp",),
                          act="relu")
                ch = st.out_ch
            elif st.kind == "fire":
                sq = b.add(ConvOp, [x], w=p + ("ws",), b=p + ("bs",),
                           stride=stride, act="relu")
                e1 = b.add(ConvOp, [sq], w=p + ("w1",), b=p + ("b1",),
                           act="relu")
                e3 = b.add(ConvOp, [sq], w=p + ("w3",), b=p + ("b3",),
                           act="relu")
                x = b.add(ConcatOp, [e1, e3])
                ch = st.out_ch
            elif st.kind == "pool":
                x = b.add(PoolOp, [x], pool="max", kernel=st.kernel,
                          stride=st.stride)
            else:
                raise ValueError(f"unknown stage kind {st.kind!r}")
    x = b.add(PoolOp, [x], pool="global")
    x = b.add(LinearOp, [x], w=("head_w",), b=("head_b",))
    return Graph(tuple(b.nodes), output=x, name=cfg.name)


# ---------------------------------------------------------------------------
# Transformer prefill lowering (models/transformer.py forward/prefill)
# ---------------------------------------------------------------------------

def lowering_blockers(arch: ArchConfig) -> List[str]:
    """Why `lower_transformer` would refuse this arch (empty = lowerable).
    SSM / recurrent mixers, MoE, encoder-decoder and modality frontends stay
    on the eager path this generation of the IR."""
    reasons = []
    kinds = {arch.layer_kind(i) for i in range(arch.n_layers)}
    if kinds - {"global", "local"}:
        reasons.append(f"non-attention mixers {sorted(kinds - {'global', 'local'})}")
    if arch.is_moe:
        reasons.append("MoE routing")
    if arch.family == "audio" or arch.encoder_layers > 0:
        reasons.append("encoder-decoder")
    if arch.mrope or arch.frontend:
        reasons.append("modality frontend / M-RoPE")
    if arch.d_ff <= 0:
        reasons.append("no MLP half")
    return reasons


def can_lower(arch: ArchConfig) -> bool:
    return not lowering_blockers(arch)


def lower_transformer(arch: ArchConfig, last_only: bool = False,
                      mode: str = "full", page_size: int = 0) -> Graph:
    """Lower a transformer to the engine op-graph.

    mode="full" (prefill / training): the program input is the token-id
    tensor [B, L]; the output is the logits edge ([B, L, V] full-sequence,
    or [B, 1, V] with `last_only` -- the serving-prefill variant).

    mode="decode": the DecodeStep program -- the same node sequence over a
    [B, 1] token input, with every AttnOp in `update` mode (read/write the
    serving KV cache at the slot's position index).  The executor runs it
    through `execute_decode(program, params, cache, tokens, eng)`.  Because
    the node order is identical to the full graph's, per-edge calibration
    scales recorded on the full graph transfer to the decode graph by node
    id -- one calibration run statically quantizes both programs.

    mode="chunk" (prefix-sharing prefill): the same node sequence over a
    [B, T] tail input, every global AttnOp in `chunk` mode (attend the
    paged cache view at a query offset, store only the slot's owned tail
    pages).  The executor runs it through `prefill_from(program, params,
    cache, tokens, eng, start, ...)`.  Node order is identical to the
    full graph's, so calibration scales transfer by node id here too.
    Local (ring) layers are not chunkable -- their dense window state has
    no page boundary to share at -- so chunk lowering requires an
    all-global arch (the serving engine falls back to whole-prompt
    prefill for archs with local layers).

    page_size > 0 (decode / chunk modes only) marks the global-layer
    AttnOps block-paged: their cache state is a shared block pool indexed
    through cache["tables"] (see AttnOp docstring).  The node sequence is
    unchanged, so calibration scales still transfer by node id and paged
    programs reuse the dense calibration run.

    Every projection is a LinearOp on the Conv PE; norms, residual adds,
    the SwiGLU gate and the attention core run on the MISC core, mirroring
    the paper's non-convolution operator mapping.
    """
    if mode not in ("full", "decode", "chunk"):
        raise ValueError(f"unknown lowering mode {mode!r} "
                         "(want 'full', 'decode' or 'chunk')")
    if page_size and mode == "full":
        raise ValueError("page_size applies to decode/chunk programs only "
                         "(prefill fills the cache through `collect`)")
    if mode == "chunk" and page_size <= 0:
        raise ValueError("chunk lowering needs page_size > 0 "
                         "(it stores through the block table)")
    if page_size < 0:
        raise ValueError(f"page_size must be >= 0, got {page_size}")
    blockers = lowering_blockers(arch)
    if blockers:
        raise NotImplementedError(
            f"{arch.name}: cannot lower to the engine IR "
            f"({'; '.join(blockers)}); serve it eagerly")
    if mode == "chunk" and any(arch.layer_kind(i) == "local"
                               for i in range(arch.n_layers)):
        raise NotImplementedError(
            f"{arch.name}: chunk lowering requires all-global attention "
            "(local ring layers have no page boundary to share at)")
    attn_mode = {"full": "full", "decode": "update", "chunk": "chunk"}[mode]
    b = _Builder()
    tokens = b.add(InputOp, [])
    x = b.add(EmbedOp, [tokens], w=("embed",),
              emb_scale=arch.d_model ** 0.5 if arch.emb_scale else 0.0)
    gated = arch.mlp_gated
    for i in range(arch.n_layers):
        kind = arch.layer_kind(i)
        p: ParamPath = ("blocks", i)
        ap = p + ("attn",)
        hn = b.add(NormOp, [x], w=p + ("norm",), eps=arch.norm_eps)
        q = b.add(LinearOp, [hn], w=ap + ("wq",),
                  b=ap + ("bq",) if arch.qkv_bias else None)
        k = b.add(LinearOp, [hn], w=ap + ("wk",),
                  b=ap + ("bk",) if arch.qkv_bias else None)
        v = b.add(LinearOp, [hn], w=ap + ("wv",),
                  b=ap + ("bv",) if arch.qkv_bias else None)
        a = b.add(AttnOp, [q, k, v], layer=i, layer_kind=kind,
                  n_heads=arch.n_heads, n_kv_heads=arch.n_kv_heads,
                  head_dim=arch.head_dim, rope_theta=arch.rope_theta,
                  softcap=arch.attn_softcap,
                  window=arch.local_window if kind == "local" else 0,
                  mode=attn_mode,
                  page_size=page_size if kind == "global" else 0)
        h = b.add(LinearOp, [a], w=ap + ("wo",))
        if arch.post_norms:
            h = b.add(NormOp, [h], w=p + ("post_attn_norm",),
                      eps=arch.norm_eps)
        x = b.add(AddOp, [x, h])
        # MLP half
        mn = b.add(NormOp, [x], w=p + ("mlp_norm",), eps=arch.norm_eps)
        mp = p + ("mlp",)
        if gated:
            g = b.add(LinearOp, [mn], w=mp + ("wg",), act=arch.mlp_act)
            u = b.add(LinearOp, [mn], w=mp + ("wu",))
            h = b.add(MulOp, [g, u])
        else:
            h = b.add(LinearOp, [mn], w=mp + ("wu",), act=arch.mlp_act)
        h = b.add(LinearOp, [h], w=mp + ("wd",))
        if arch.post_norms:
            h = b.add(NormOp, [h], w=p + ("post_mlp_norm",),
                      eps=arch.norm_eps)
        x = b.add(AddOp, [x, h])
    x = b.add(NormOp, [x], w=("final_norm",), eps=arch.norm_eps)
    x = b.add(HeadOp, [x],
              w=("embed",) if arch.tie_embeddings else ("head",),
              tied=arch.tie_embeddings, softcap=arch.final_softcap,
              last_only=(last_only and mode == "full") or mode == "chunk")
    if mode == "full":
        name = arch.name
    else:
        name = (f"{arch.name}:{mode}"
                + (f":p{page_size}" if page_size else ""))
    return Graph(tuple(b.nodes), output=x, name=name)
