"""Typed op-graph IR: the engine program a CNN lowers to.

The paper's DPU is instruction-driven (Section III-A): the Vitis-AI compiler
turns a model graph into Conv PE / DWC PE / MISC instructions and the engines
execute the resulting program.  This module is our analogue of that IR: a
flat, topologically-ordered tuple of typed op nodes, each naming its input
edges (producer node ids) and the parameter-tree paths it reads.

Node kinds and the engine that executes them:

  ConvOp    -> Conv PE (im2col GEMM; `first_layer=True` routes the stem to
               the Low-Channel Conv Unit)
  DwcOp     -> DWC PE
  AddOp     -> MISC core (residual add + NL epilogue)
  PoolOp    -> MISC core ("max" | "avg" | "global")
  ConcatOp  -> bank interleave (channel concat; free at the memory level)
  LinearOp  -> Conv PE (the classifier head GEMM)
  InputOp   -> the image placeholder (edge 0)

A node's id doubles as the id of its output edge, so per-edge metadata
(calibrated activation scales, emit dtypes) is keyed by node id.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.config import CNNConfig

# A path into the params pytree, e.g. ("stages", 2, 0, "w1").
ParamPath = Tuple


@dataclass(frozen=True)
class OpNode:
    id: int
    inputs: Tuple[int, ...]

    @property
    def kind(self) -> str:
        return type(self).__name__.replace("Op", "").lower()


@dataclass(frozen=True)
class InputOp(OpNode):
    pass


@dataclass(frozen=True)
class ConvOp(OpNode):
    w: ParamPath = ()
    b: Optional[ParamPath] = None
    stride: int = 1
    padding: str = "SAME"
    act: str = "none"
    first_layer: bool = False        # route through the Low-Channel unit


@dataclass(frozen=True)
class DwcOp(OpNode):
    w: ParamPath = ()
    b: Optional[ParamPath] = None
    stride: int = 1
    padding: str = "SAME"
    act: str = "none"


@dataclass(frozen=True)
class AddOp(OpNode):
    act: str = "none"


@dataclass(frozen=True)
class PoolOp(OpNode):
    pool: str = "max"                # max | avg | global
    kernel: int = 2
    stride: int = 2


@dataclass(frozen=True)
class ConcatOp(OpNode):
    pass                             # channel (last-axis) concat


@dataclass(frozen=True)
class LinearOp(OpNode):
    w: ParamPath = ()
    b: Optional[ParamPath] = None
    act: str = "none"


@dataclass(frozen=True)
class Graph:
    """Topologically ordered op list; nodes[i].id == i."""
    nodes: Tuple[OpNode, ...]
    output: int
    name: str = ""

    def consumers(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for i in n.inputs:
                out[i].append(n.id)
        return out

    def count(self, cls) -> int:
        return sum(isinstance(n, cls) for n in self.nodes)


def get_param(params, path: Optional[ParamPath]):
    """Resolve a ParamPath against the (possibly quantized) params pytree.
    None (an op with no bias) resolves to None."""
    if path is None:
        return None
    v = params
    for k in path:
        v = v[k]
    return v


class _Builder:
    def __init__(self):
        self.nodes: List[OpNode] = []

    def add(self, cls, inputs, **attrs) -> int:
        nid = len(self.nodes)
        self.nodes.append(cls(id=nid, inputs=tuple(inputs), **attrs))
        return nid


def build_graph(cfg: CNNConfig) -> Graph:
    """Lower a CNNConfig to the engine op-graph.

    Mirrors the zoo's stage semantics (models/cnn.py docstring): conv,
    bottleneck, inverted, dwsep, fire, pool.  Channel bookkeeping here must
    match cnn_schema(), which owns the parameter shapes.
    """
    b = _Builder()
    x = b.add(InputOp, [])
    x = b.add(ConvOp, [x], w=("stem_w",), b=("stem_b",),
              stride=cfg.stem_stride, act="relu", first_layer=True)
    ch = cfg.stem_ch
    for si, st in enumerate(cfg.stages):
        for r in range(st.repeat):
            stride = st.stride if r == 0 else 1
            p: ParamPath = ("stages", si, r)
            if st.kind == "conv":
                x = b.add(ConvOp, [x], w=p + ("w",), b=p + ("b",),
                          stride=stride, act="relu")
                ch = st.out_ch
            elif st.kind == "bottleneck":
                h = b.add(ConvOp, [x], w=p + ("w1",), b=p + ("b1",),
                          act="relu")
                h = b.add(ConvOp, [h], w=p + ("w2",), b=p + ("b2",),
                          stride=stride, act="relu")
                h = b.add(ConvOp, [h], w=p + ("w3",), b=p + ("b3",))
                skip = x
                if ch != st.out_ch or stride != 1:
                    skip = b.add(ConvOp, [x], w=p + ("wskip",),
                                 b=p + ("bskip",), stride=stride)
                x = b.add(AddOp, [h, skip], act="relu")
                ch = st.out_ch
            elif st.kind == "inverted":
                h = b.add(ConvOp, [x], w=p + ("we",), b=p + ("be",),
                          act="relu6")
                h = b.add(DwcOp, [h], w=p + ("wd",), b=p + ("bd",),
                          stride=stride, act="relu6")
                h = b.add(ConvOp, [h], w=p + ("wp",), b=p + ("bp",))
                if stride == 1 and ch == st.out_ch:
                    x = b.add(AddOp, [h, x])
                else:
                    x = h
                ch = st.out_ch
            elif st.kind == "dwsep":
                h = b.add(DwcOp, [x], w=p + ("wd",), b=p + ("bd",),
                          stride=stride, act="relu")
                x = b.add(ConvOp, [h], w=p + ("wp",), b=p + ("bp",),
                          act="relu")
                ch = st.out_ch
            elif st.kind == "fire":
                sq = b.add(ConvOp, [x], w=p + ("ws",), b=p + ("bs",),
                           stride=stride, act="relu")
                e1 = b.add(ConvOp, [sq], w=p + ("w1",), b=p + ("b1",),
                           act="relu")
                e3 = b.add(ConvOp, [sq], w=p + ("w3",), b=p + ("b3",),
                           act="relu")
                x = b.add(ConcatOp, [e1, e3])
                ch = st.out_ch
            elif st.kind == "pool":
                x = b.add(PoolOp, [x], pool="max", kernel=st.kernel,
                          stride=st.stride)
            else:
                raise ValueError(f"unknown stage kind {st.kind!r}")
    x = b.add(PoolOp, [x], pool="global")
    x = b.add(LinearOp, [x], w=("head_w",), b=("head_b",))
    return Graph(tuple(b.nodes), output=x, name=cfg.name)
