"""Graph-level scheduler pass: topological leveling for concurrent PEs.

The paper's fabric runs its engines concurrently: the Low-Channel Conv Unit
proceeds while the Conv PEs work (Section V-B), the DWC PE is a separate
datapath from the Conv PE, and MISC ops execute on their own core.  The op
graph exposes that parallelism structurally -- e.g. the two expand convs of
a fire module, the skip conv of a bottleneck next to its main branch, or a
DWC branch next to a Conv branch feeding one concat -- but the executor
historically walked `graph.nodes` strictly sequentially.

This pass levels the graph ASAP-style: level(n) = 1 + max(level(inputs)).
Two ops in the same level can never depend on each other (any dependence
forces a strictly larger level), so a level is a dispatch wave the engines
may run concurrently.  The executor consumes the schedule level-by-level,
evaluating every op of a level against the *previous* levels' values only --
a same-level data dependence would fail loudly -- and the perf model credits
the overlap between engine units the same way it already credits the
Low-Channel unit's concurrency.

`policy="alap"` levels as-late-as-possible inside the same critical-path
length: ops with slack slide toward their consumers, which tends to
co-schedule *cross-engine* pairs (a MISC norm next to a Conv PE GEMM) that
ASAP leaves in separate waves.  `policy="slack"` is the bounded-ALAP
hybrid: each op slides anywhere within its [ASAP, ALAP] slack window to
the level where its own engine unit is least contended (two Conv-PE ops in
one wave time-share the Conv PE; a Conv-PE op next to a DWC-PE or MISC op
genuinely overlaps), capped so it never exceeds ASAP's worst same-unit
width.  With `node_times` ({node_id: modeled seconds}, compiler/cost.py)
slack contention is weighed in SECONDS instead of op counts.
`policy="cost"` is the fully cost-driven variant: each op slides within
its window to the level that minimizes the modeled per-level makespan
(sum over levels of the busiest unit's summed seconds -- same-unit ops
time-share their engine, distinct units overlap), with a property-tested
never-worse-than-ASAP guarantee (a placement whose modeled makespan
exceeds ASAP's falls back to the plain ASAP assignment).  All policies
produce valid levelings with identical results (the parity suite pins
that); per-level engine occupancy (engine_occupancy) and the
time-weighted makespan/occupancy are the comparison metrics the serving
benchmark reports.

`merge_schedules` goes one step further, per f-CNNx: it zips TWO compiled
programs' levels onto one fabric tick stream, so the MISC-heavy levels of
an LM decode burst are filled by a co-resident CNN wave's conv levels.
The cost policy aligns the two level sequences by dynamic programming
over the joint per-tick makespan; executor.execute_interleaved consumes
the merged ticks with one environment per program (no cross-program
dataflow, so outputs stay bit-identical to isolated execution).

LM graphs level through the same pass: on an unfused graph the three QKV
projections of a block co-level on the Conv PE (and the gate/up GEMMs of a
SwiGLU pair do too); after passes.fuse_projections each group is ONE
Conv PE launch followed by free memory-level views.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple

from repro.compiler.graph import (AddOp, AttnOp, ConcatOp, ConvOp, DwcOp,
                                  EmbedOp, Graph, HeadOp, InputOp,
                                  LinearGroupOp, LinearOp, MulOp, NormOp,
                                  OpNode, PoolOp, ViewOp)

# The engine units of the fabric.  Ops mapped to different units in the same
# level model truly concurrent hardware (distinct datapaths); two same-unit
# ops in one level still time-share that unit.
CONV_PE = "conv_pe"
DWC_PE = "dwc_pe"
MISC = "misc"
LOW_CHANNEL = "low_channel"
MEM = "mem"

_COMPUTE_UNITS = (CONV_PE, DWC_PE, MISC, LOW_CHANNEL)


def engine_unit(node: OpNode) -> str:
    """Which engine executes a node (graph.py's kind -> engine mapping)."""
    if isinstance(node, ConvOp):
        return LOW_CHANNEL if node.first_layer else CONV_PE
    if isinstance(node, (LinearOp, LinearGroupOp, HeadOp)):
        return CONV_PE                     # classifier-head / LM GEMMs
    if isinstance(node, DwcOp):
        return DWC_PE
    if isinstance(node, (AddOp, PoolOp, NormOp, MulOp, AttnOp)):
        return MISC                        # non-conv operators (paper III)
    if isinstance(node, (InputOp, ConcatOp, EmbedOp, ViewOp)):
        return MEM                         # load / interleave / row gather
    raise TypeError(f"unknown op {type(node).__name__}")


@dataclass(frozen=True)
class Schedule:
    """A topological leveling of one graph.

    levels[k] holds the ids of the ops dispatched in wave k, in ascending id
    order; every input of a level-k op lives in a level < k.
    """
    levels: Tuple[Tuple[int, ...], ...]
    stats: Dict[str, int] = field(default_factory=dict)

    def order(self) -> Iterable[int]:
        for level in self.levels:
            yield from level

    @property
    def n_levels(self) -> int:
        return len(self.levels)


def level_schedule(graph: Graph, policy: str = "asap",
                   node_times: Optional[Dict[int, float]] = None
                   ) -> Schedule:
    """Level the graph into concurrent dispatch waves.

    policy="asap": level(n) = 1 + max(level(inputs)) -- ops fire as soon as
    their inputs exist.  policy="alap": within the same critical-path length,
    every op slides to the latest level its consumers allow (slack-window
    leveling), which co-schedules more cross-engine pairs.  policy="slack":
    the bounded-ALAP hybrid -- every op is placed greedily inside its
    [ASAP, ALAP] slack window at the level where its own engine unit is
    LEAST contended (same-unit ops in one level time-share the unit;
    cross-unit ops genuinely overlap), never exceeding ASAP's worst
    same-unit width.  policy="cost": cost-driven -- each op lands at the
    window level that minimizes the modeled makespan (`modeled_makespan`
    over `node_times`), never worse than ASAP's (fallback guarantee).

    `node_times` ({node_id: modeled seconds}, e.g. compiler/cost.py's
    cnn_node_times / lm_node_times) turns the slack contention measure and
    the cost objective from op counts into seconds; without it the cost
    policy prices every op at 1.0 (count-makespan) and slack keeps its
    historical count behavior.  All policies keep the critical-path level
    count and produce valid levelings with bit-identical execution.
    """
    asap: Dict[int, int] = {}
    for n in graph.nodes:
        asap[n.id] = (1 + max(asap[i] for i in n.inputs)) if n.inputs else 0
    n_levels = 1 + max(asap.values())
    if policy == "asap":
        level = asap
    elif policy == "alap":
        level = _alap_levels(graph, n_levels)
    elif policy == "slack":
        level = _slack_levels(graph, asap, n_levels, node_times)
    elif policy == "cost":
        level = _cost_levels(graph, asap, n_levels, node_times)
    else:
        raise ValueError(f"unknown leveling policy {policy!r} "
                         "(want 'asap', 'alap', 'slack' or 'cost')")
    levels = [[] for _ in range(n_levels)]
    for n in graph.nodes:                  # nodes are id-ordered already
        levels[level[n.id]].append(n.id)
    lvls = tuple(tuple(lv) for lv in levels if lv)
    stats = _levels_stats(graph, lvls)
    if node_times is not None or policy == "cost":
        times = node_times if node_times is not None else \
            {n.id: 1.0 for n in graph.nodes}
        stats["modeled_makespan"] = modeled_makespan(graph, lvls, times)
    return Schedule(lvls, stats=stats)


def _alap_levels(graph: Graph, n_levels: int) -> Dict[int, int]:
    consumers = graph.consumers()
    level: Dict[int, int] = {}
    for n in reversed(graph.nodes):        # ids are topological
        cs = consumers[n.id]
        level[n.id] = (min(level[c] for c in cs) - 1) if cs \
            else n_levels - 1
    return level


def _unit_widths(graph: Graph, level: Dict[int, int], n_levels: int):
    """Per-level per-unit op counts of an assignment."""
    counts = [dict() for _ in range(n_levels)]
    for n in graph.nodes:
        u = engine_unit(n)
        c = counts[level[n.id]]
        c[u] = c.get(u, 0) + 1
    return counts


def _slack_levels(graph: Graph, asap: Dict[int, int], n_levels: int,
                  node_times: Optional[Dict[int, float]] = None
                  ) -> Dict[int, int]:
    """Contention-aware slack leveling (the bounded-ALAP hybrid).

    Walk the nodes in topological order; each op's feasible window is
    [1 + max(placed inputs), ALAP(op)] -- every placement keeps the graph's
    critical-path level count, since an op placed at most at its ALAP level
    leaves all its consumers a non-empty window.  Within the window the op
    lands on the level where its own engine unit is least contended
    (same-unit ops time-share the unit -- the contention the policy
    minimizes), preferring levels already busy on OTHER compute units (the
    cross-engine pairing that raises occupancy), earliest level on ties.
    Contention is measured in op counts, or -- with `node_times` -- in
    modeled SECONDS, so a 1us norm no longer repels placement the way a
    1ms GEMM does.

    ASAP's worst per-unit same-level width is the hard cap: levels already
    at the cap for the op's unit are avoided while any other level in the
    window is below it, and if a placement would still exceed the cap
    anywhere the policy falls back to the plain ASAP assignment -- so slack
    never raises max same-unit ops per level above ASAP (property-tested).
    """
    alap = _alap_levels(graph, n_levels)
    cap: Dict[str, int] = {}
    for c in _unit_widths(graph, asap, n_levels):
        for u, k in c.items():
            cap[u] = max(cap.get(u, 0), k)
    counts = [dict() for _ in range(n_levels)]
    loads = [dict() for _ in range(n_levels)]   # per-unit modeled seconds
    times = node_times or {}
    compute = set(_COMPUTE_UNITS)

    def _put(lv: int, n: OpNode) -> None:
        u = engine_unit(n)
        counts[lv][u] = counts[lv].get(u, 0) + 1
        loads[lv][u] = loads[lv].get(u, 0.0) + float(times.get(n.id, 0.0))

    # Pin the zero-slack (critical-path) ops first: they can never move --
    # every predecessor's ALAP is strictly below them, so no slack placement
    # can push them -- and seeding their unit load lets the movable ops see
    # the true contention picture instead of a half-empty one.
    placed: Dict[int, int] = {}
    for n in graph.nodes:
        if asap[n.id] == alap[n.id]:
            placed[n.id] = asap[n.id]
            _put(asap[n.id], n)
    for n in graph.nodes:
        if n.id in placed:
            continue
        u = engine_unit(n)
        lo = 1 + max((placed[i] for i in n.inputs), default=-1)
        window = range(lo, alap[n.id] + 1)
        under = [lv for lv in window if counts[lv].get(u, 0) < cap[u]]
        cands = under or list(window)

        def goodness(lv: int):
            others = sum(1 for uu, k in counts[lv].items()
                         if k and uu != u and uu in compute)
            own = (loads[lv].get(u, 0.0) if node_times is not None
                   else counts[lv].get(u, 0))
            return (own, -others, lv)

        best = min(cands, key=goodness)
        placed[n.id] = best
        _put(best, n)
    for c in counts:
        for u, k in c.items():
            if k > cap.get(u, 0):
                return dict(asap)          # cap breached: fall back
    return placed


def _unit_loads(graph: Graph, level: Dict[int, int], n_levels: int,
                times: Dict[int, float]):
    """Per-level per-unit summed modeled seconds of an assignment."""
    loads = [dict() for _ in range(n_levels)]
    for n in graph.nodes:
        u = engine_unit(n)
        c = loads[level[n.id]]
        c[u] = c.get(u, 0.0) + float(times.get(n.id, 0.0))
    return loads


def _loads_makespan(loads) -> float:
    """Makespan of per-level unit loads: each level takes as long as its
    busiest unit (same-unit ops time-share; distinct units overlap)."""
    return sum(max(c.values(), default=0.0) for c in loads)


def _cost_levels(graph: Graph, asap: Dict[int, int], n_levels: int,
                 node_times: Optional[Dict[int, float]] = None
                 ) -> Dict[int, int]:
    """Cost-driven leveling: minimize the modeled makespan.

    Same window discipline as `_slack_levels` (zero-slack ops pinned
    first, then each movable op placed greedily inside
    [1 + max(placed inputs), ALAP]), but the objective is the modeled
    per-level makespan itself: an op lands at the level where it grows
    `max(unit seconds in level)` the least -- sliding a Conv-PE GEMM into
    a MISC-dominated level costs nothing until the Conv PE becomes that
    level's critical unit.  Ties break toward the level with the least
    same-unit load, then earliest.

    The never-worse-than-ASAP guarantee is checked, not assumed: if the
    greedy placement's total makespan exceeds ASAP's (possible in theory,
    since greedy placement is not optimal), the policy returns the plain
    ASAP assignment (property-tested on random DAGs).
    """
    alap = _alap_levels(graph, n_levels)
    times = (node_times if node_times is not None
             else {n.id: 1.0 for n in graph.nodes})
    loads = [dict() for _ in range(n_levels)]

    def _put(lv: int, n: OpNode) -> None:
        u = engine_unit(n)
        loads[lv][u] = loads[lv].get(u, 0.0) + float(times.get(n.id, 0.0))

    placed: Dict[int, int] = {}
    for n in graph.nodes:
        if asap[n.id] == alap[n.id]:
            placed[n.id] = asap[n.id]
            _put(asap[n.id], n)
    for n in graph.nodes:
        if n.id in placed:
            continue
        u = engine_unit(n)
        t = float(times.get(n.id, 0.0))
        lo = 1 + max((placed[i] for i in n.inputs), default=-1)
        best, best_key = None, None
        for lv in range(lo, alap[n.id] + 1):
            span0 = max(loads[lv].values(), default=0.0)
            own = loads[lv].get(u, 0.0)
            grow = max(span0, own + t) - span0    # makespan increment
            key = (grow, own, lv)
            if best_key is None or key < best_key:
                best, best_key = lv, key
        placed[n.id] = best
        _put(best, n)
    asap_span = _loads_makespan(_unit_loads(graph, asap, n_levels, times))
    if _loads_makespan(loads) > asap_span + 1e-12:
        return dict(asap)              # guarantee: never worse than ASAP
    return placed


def modeled_makespan(graph: Graph, levels, node_times: Dict[int, float]
                     ) -> float:
    """Modeled seconds of a leveling: sum over levels of the busiest
    unit's summed node seconds (same-unit ops time-share their engine,
    distinct units run concurrently).  `levels` is a Schedule or its raw
    levels tuple; `node_times` maps node id -> modeled seconds
    (compiler/cost.py).  This is the objective `policy="cost"` minimizes
    and the `modeled_makespan` stat the Schedule carries."""
    if isinstance(levels, Schedule):
        levels = levels.levels
    total = 0.0
    for lv in levels:
        per_unit: Dict[str, float] = {}
        for i in lv:
            u = engine_unit(graph.nodes[i])
            per_unit[u] = per_unit.get(u, 0.0) + float(node_times.get(i, 0.0))
        total += max(per_unit.values(), default=0.0)
    return total


def schedule_stats(graph: Graph, sched: Schedule) -> Dict[str, int]:
    """Concurrency evidence: how much overlap the leveling exposes."""
    return _levels_stats(graph, sched.levels)


def _levels_stats(graph: Graph, levels) -> Dict[str, int]:
    wide = cross = conv_dwc = 0
    max_unit = 0
    for lv in levels:
        per_unit: Dict[str, int] = {}
        for i in lv:
            u = engine_unit(graph.nodes[i])
            per_unit[u] = per_unit.get(u, 0) + 1
        units = set(per_unit)
        compute = units & set(_COMPUTE_UNITS)
        max_unit = max([max_unit] + [per_unit[u] for u in compute])
        if len(lv) > 1:
            wide += 1
        if len(compute) > 1:
            cross += 1
        if CONV_PE in units and DWC_PE in units:
            conv_dwc += 1
    return {
        "levels": len(levels),
        "ops": len(graph.nodes),
        "max_width": max(len(lv) for lv in levels),
        "wide_levels": wide,
        "cross_engine_levels": cross,
        "conv_dwc_levels": conv_dwc,
        # worst same-unit op count in any level: the contention the "slack"
        # policy levels down (same-unit ops in one wave time-share the unit)
        "max_unit_width": max_unit,
    }


def engine_occupancy(graph: Graph, sched: Schedule) -> Dict[str, float]:
    """Per-level engine occupancy: how busy each engine unit is across the
    dispatch waves.

    For every level, a compute unit is "busy" when at least one of its ops
    dispatches in that wave.  `occupancy` is the mean busy-unit fraction
    over levels that dispatch any compute at all (MEM-only levels -- the
    input load -- are excluded); per-unit entries are the fraction of those
    levels each unit works in.  ALAP's slack sliding raises this against
    ASAP on branchy graphs, which is the number the serving benchmark
    compares.
    """
    busy = {u: 0 for u in _COMPUTE_UNITS}
    compute_levels = 0
    total_busy = 0
    for lv in sched.levels:
        units = {engine_unit(graph.nodes[i]) for i in lv} & set(_COMPUTE_UNITS)
        if not units:
            continue
        compute_levels += 1
        total_busy += len(units)
        for u in units:
            busy[u] += 1
    if compute_levels == 0:
        return {"occupancy": 0.0, "levels": 0.0}
    # only rate units the graph uses at all (a pure-LM graph has no DWC work)
    used = {u for n in graph.nodes
            for u in [engine_unit(n)] if u in _COMPUTE_UNITS}
    out = {"occupancy": total_busy / (compute_levels * max(len(used), 1)),
           "levels": float(compute_levels)}
    for u in sorted(used):
        out[u] = busy[u] / compute_levels
    return out


def time_weighted_occupancy(graph: Graph, sched: Schedule,
                            node_times: Dict[int, float]) -> Dict[str, float]:
    """Time-weighted per-engine busy fractions over a leveling.

    engine_occupancy counts unit *presence* per level -- a level where the
    MISC core does 1us of norm work next to 1ms of Conv PE GEMMs rates both
    units equally.  This weights by modeled seconds instead (`node_times`:
    {node_id: seconds}, e.g. benchmarks.perf_model.lm_node_times): a
    level's span is the busiest unit's summed time in it (same-unit ops
    time-share their engine; distinct units run concurrently), the program
    span is the sum over levels, and each unit's busy fraction is its total
    time over that span.  This is the ROADMAP's "time-weighted busy
    fraction" for LM (and decode) programs, where op costs differ by
    orders of magnitude.
    """
    busy = {u: 0.0 for u in _COMPUTE_UNITS}
    span = 0.0
    for lv in sched.levels:
        per_unit: Dict[str, float] = {}
        for i in lv:
            u = engine_unit(graph.nodes[i])
            per_unit[u] = per_unit.get(u, 0.0) + float(node_times.get(i, 0.0))
        for u, t in per_unit.items():
            if u in busy:
                busy[u] += t
        span += max(per_unit.values(), default=0.0)
    used = {u for n in graph.nodes
            for u in [engine_unit(n)] if u in _COMPUTE_UNITS}
    out: Dict[str, float] = {"span_s": span}
    for u in sorted(used):
        out[u] = busy[u] / span if span > 0 else 0.0
    out["occupancy"] = (sum(busy[u] for u in used) / (span * len(used))
                        if span > 0 and used else 0.0)
    return out


# ---------------------------------------------------------------------------
# Multi-tenant fabric interleaving (f-CNNx): zip two programs' levels onto
# one tick stream
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MergedSchedule:
    """A fabric tick stream over TWO programs' schedules.

    ticks[t] = (ia, ib): at fabric tick t, program A dispatches its level
    `ia` (None = A idles this tick) and program B its level `ib`.  Each
    program's own level order is preserved (its non-None indices appear
    exactly once, ascending), so per-program execution is just its normal
    wave-by-wave dispatch -- interleaving changes WHEN levels fire, never
    what they compute (executor.execute_interleaved keeps one value
    environment per program; bit-identity to isolated execution is pinned
    in tests).
    """
    ticks: Tuple[Tuple[Optional[int], Optional[int]], ...]
    stats: Dict[str, float] = field(default_factory=dict)

    @property
    def n_ticks(self) -> int:
        return len(self.ticks)


def _level_unit_times(graph: Graph, levels, node_times: Dict[int, float]):
    """Per-level {unit: summed seconds} of one schedule."""
    out = []
    for lv in levels:
        per: Dict[str, float] = {}
        for i in lv:
            u = engine_unit(graph.nodes[i])
            per[u] = per.get(u, 0.0) + float(node_times.get(i, 0.0))
        out.append(per)
    return out


def _merged_stats(graph_a: Graph, graph_b: Graph, la, lb, ticks
                  ) -> Dict[str, float]:
    """Time-weighted stats of a merged tick stream: makespan (sum of tick
    spans, each tick as long as its busiest unit across BOTH programs),
    the serialized makespan it replaces, and the fabric occupancy
    (compute-unit busy seconds over makespan x used units -- the
    time_weighted_occupancy convention applied to the joint stream)."""
    makespan = 0.0
    for ia, ib in ticks:
        per: Dict[str, float] = {}
        for src, idx in ((la, ia), (lb, ib)):
            if idx is None:
                continue
            for u, t in src[idx].items():
                per[u] = per.get(u, 0.0) + t
        makespan += max(per.values(), default=0.0)
    serialized = (sum(max(p.values(), default=0.0) for p in la)
                  + sum(max(p.values(), default=0.0) for p in lb))
    used = {u for g in (graph_a, graph_b) for n in g.nodes
            for u in [engine_unit(n)] if u in _COMPUTE_UNITS}
    busy = {u: 0.0 for u in used}
    for src in (la, lb):
        for per in src:
            for u, t in per.items():
                if u in busy:
                    busy[u] += t
    out: Dict[str, float] = {
        "ticks": float(len(ticks)),
        "makespan": makespan,
        "serialized_makespan": serialized,
        "occupancy": (sum(busy.values()) / (makespan * len(used))
                      if makespan > 0 and used else 0.0),
    }
    for u in sorted(used):
        out[u] = busy[u] / makespan if makespan > 0 else 0.0
    return out


def merge_schedules(graph_a: Graph, sched_a: Schedule,
                    graph_b: Graph, sched_b: Schedule,
                    times_a: Optional[Dict[int, float]] = None,
                    times_b: Optional[Dict[int, float]] = None,
                    policy: str = "cost") -> MergedSchedule:
    """Zip two programs' level schedules onto one fabric tick stream.

    policy="asap" is the naive in-order zip: tick t runs A's level t next
    to B's level t until one program runs dry -- the baseline a co-tenant
    fabric gets with no alignment at all.  policy="cost" aligns the two
    level sequences by dynamic programming over the modeled joint
    makespan: at each tick the fabric may advance A alone, B alone, or
    both together, where a joint tick costs `max` over units of the
    COMBINED summed seconds -- so a MISC-heavy LM level is paired with a
    Conv-PE-heavy CNN level (their costs hide under each other) while two
    Conv-PE-heavy levels are kept apart.  The in-order zip and the fully
    serialized stream are both paths in the DP lattice, so the cost
    alignment's makespan is never worse than either (the strict win the
    serving benchmark records).

    `times_a`/`times_b` are each program's {node_id: seconds}
    (compiler/cost.py); omitted, ops are priced at 1.0.  Both programs'
    internal level orders are always preserved -- the merge only chooses
    the pairing -- which is what keeps interleaved execution bit-identical
    to isolated.  Stats carry the modeled makespan, the serialized
    makespan it replaces, and the joint time-weighted fabric occupancy.
    """
    ta = (times_a if times_a is not None
          else {n.id: 1.0 for n in graph_a.nodes})
    tb = (times_b if times_b is not None
          else {n.id: 1.0 for n in graph_b.nodes})
    la = _level_unit_times(graph_a, sched_a.levels, ta)
    lb = _level_unit_times(graph_b, sched_b.levels, tb)
    na, nb = len(la), len(lb)
    if policy == "asap":
        ticks = tuple((i if i < na else None, i if i < nb else None)
                      for i in range(max(na, nb)))
    elif policy == "cost":
        span_a = [max(p.values(), default=0.0) for p in la]
        span_b = [max(p.values(), default=0.0) for p in lb]

        def joint(i: int, j: int) -> float:
            per = dict(la[i])
            for u, t in lb[j].items():
                per[u] = per.get(u, 0.0) + t
            return max(per.values(), default=0.0)

        inf = float("inf")
        cost = [[inf] * (nb + 1) for _ in range(na + 1)]
        back = [[None] * (nb + 1) for _ in range(na + 1)]
        cost[0][0] = 0.0
        for i in range(na + 1):
            for j in range(nb + 1):
                if i == 0 and j == 0:
                    continue
                # prefer the joint step on ties: same makespan, fewer ticks
                best, step = inf, None
                if i > 0 and j > 0:
                    best, step = cost[i - 1][j - 1] + joint(i - 1, j - 1), "ab"
                if i > 0 and cost[i - 1][j] + span_a[i - 1] < best:
                    best, step = cost[i - 1][j] + span_a[i - 1], "a"
                if j > 0 and cost[i][j - 1] + span_b[j - 1] < best:
                    best, step = cost[i][j - 1] + span_b[j - 1], "b"
                cost[i][j], back[i][j] = best, step
        rev = []
        i, j = na, nb
        while i or j:
            step = back[i][j]
            if step == "ab":
                i, j = i - 1, j - 1
                rev.append((i, j))
            elif step == "a":
                i = i - 1
                rev.append((i, None))
            else:
                j = j - 1
                rev.append((None, j))
        ticks = tuple(reversed(rev))
    else:
        raise ValueError(f"unknown merge policy {policy!r} "
                         "(want 'asap' or 'cost')")
    return MergedSchedule(ticks, stats=_merged_stats(graph_a, graph_b,
                                                     la, lb, ticks))


def validate_merged(sched_a: Schedule, sched_b: Schedule,
                    merged: MergedSchedule) -> None:
    """Raise unless the merged ticks dispatch each program's levels exactly
    once, in its own order -- the invariant that makes interleaved
    execution bit-identical to isolated."""
    for name, sched, lane in (("A", sched_a, 0), ("B", sched_b, 1)):
        seq = [t[lane] for t in merged.ticks if t[lane] is not None]
        if seq != list(range(sched.n_levels)):
            raise ValueError(
                f"merged ticks break program {name}'s level order: "
                f"{seq} != {list(range(sched.n_levels))}")


def validate_schedule(graph: Graph, sched: Schedule) -> None:
    """Raise if the schedule is not a valid topological leveling that covers
    every node exactly once."""
    seen: Dict[int, int] = {}
    for k, lv in enumerate(sched.levels):
        for i in lv:
            if i in seen:
                raise ValueError(f"node {i} scheduled twice "
                                 f"(levels {seen[i]} and {k})")
            seen[i] = k
    ids = {n.id for n in graph.nodes}
    if set(seen) != ids:
        missing = sorted(ids - set(seen))
        extra = sorted(set(seen) - ids)
        raise ValueError(f"schedule coverage mismatch: missing={missing} "
                         f"extra={extra}")
    for n in graph.nodes:
        for i in n.inputs:
            if seen[i] >= seen[n.id]:
                raise ValueError(
                    f"edge {i}->{n.id} violates leveling: producer in level "
                    f"{seen[i]}, consumer in level {seen[n.id]}")
