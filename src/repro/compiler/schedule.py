"""Graph-level scheduler pass: topological leveling for concurrent PEs.

The paper's fabric runs its engines concurrently: the Low-Channel Conv Unit
proceeds while the Conv PEs work (Section V-B), the DWC PE is a separate
datapath from the Conv PE, and MISC ops execute on their own core.  The op
graph exposes that parallelism structurally -- e.g. the two expand convs of
a fire module, the skip conv of a bottleneck next to its main branch, or a
DWC branch next to a Conv branch feeding one concat -- but the executor
historically walked `graph.nodes` strictly sequentially.

This pass levels the graph ASAP-style: level(n) = 1 + max(level(inputs)).
Two ops in the same level can never depend on each other (any dependence
forces a strictly larger level), so a level is a dispatch wave the engines
may run concurrently.  The executor consumes the schedule level-by-level,
evaluating every op of a level against the *previous* levels' values only --
a same-level data dependence would fail loudly -- and the perf model credits
the overlap between engine units the same way it already credits the
Low-Channel unit's concurrency.

`policy="alap"` levels as-late-as-possible inside the same critical-path
length: ops with slack slide toward their consumers, which tends to
co-schedule *cross-engine* pairs (a MISC norm next to a Conv PE GEMM) that
ASAP leaves in separate waves.  `policy="slack"` is the bounded-ALAP
hybrid: each op slides anywhere within its [ASAP, ALAP] slack window to
the level where its own engine unit is least contended (two Conv-PE ops in
one wave time-share the Conv PE; a Conv-PE op next to a DWC-PE or MISC op
genuinely overlaps), capped so it never exceeds ASAP's worst same-unit
width.  All policies produce valid levelings with identical results (the
parity suite pins that); per-level engine occupancy (engine_occupancy) is
the comparison metric the serving benchmark reports.

LM graphs level through the same pass: on an unfused graph the three QKV
projections of a block co-level on the Conv PE (and the gate/up GEMMs of a
SwiGLU pair do too); after passes.fuse_projections each group is ONE
Conv PE launch followed by free memory-level views.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Tuple

from repro.compiler.graph import (AddOp, AttnOp, ConcatOp, ConvOp, DwcOp,
                                  EmbedOp, Graph, HeadOp, InputOp,
                                  LinearGroupOp, LinearOp, MulOp, NormOp,
                                  OpNode, PoolOp, ViewOp)

# The engine units of the fabric.  Ops mapped to different units in the same
# level model truly concurrent hardware (distinct datapaths); two same-unit
# ops in one level still time-share that unit.
CONV_PE = "conv_pe"
DWC_PE = "dwc_pe"
MISC = "misc"
LOW_CHANNEL = "low_channel"
MEM = "mem"

_COMPUTE_UNITS = (CONV_PE, DWC_PE, MISC, LOW_CHANNEL)


def engine_unit(node: OpNode) -> str:
    """Which engine executes a node (graph.py's kind -> engine mapping)."""
    if isinstance(node, ConvOp):
        return LOW_CHANNEL if node.first_layer else CONV_PE
    if isinstance(node, (LinearOp, LinearGroupOp, HeadOp)):
        return CONV_PE                     # classifier-head / LM GEMMs
    if isinstance(node, DwcOp):
        return DWC_PE
    if isinstance(node, (AddOp, PoolOp, NormOp, MulOp, AttnOp)):
        return MISC                        # non-conv operators (paper III)
    if isinstance(node, (InputOp, ConcatOp, EmbedOp, ViewOp)):
        return MEM                         # load / interleave / row gather
    raise TypeError(f"unknown op {type(node).__name__}")


@dataclass(frozen=True)
class Schedule:
    """A topological leveling of one graph.

    levels[k] holds the ids of the ops dispatched in wave k, in ascending id
    order; every input of a level-k op lives in a level < k.
    """
    levels: Tuple[Tuple[int, ...], ...]
    stats: Dict[str, int] = field(default_factory=dict)

    def order(self) -> Iterable[int]:
        for level in self.levels:
            yield from level

    @property
    def n_levels(self) -> int:
        return len(self.levels)


def level_schedule(graph: Graph, policy: str = "asap") -> Schedule:
    """Level the graph into concurrent dispatch waves.

    policy="asap": level(n) = 1 + max(level(inputs)) -- ops fire as soon as
    their inputs exist.  policy="alap": within the same critical-path length,
    every op slides to the latest level its consumers allow (slack-window
    leveling), which co-schedules more cross-engine pairs.  policy="slack":
    the bounded-ALAP hybrid -- every op is placed greedily inside its
    [ASAP, ALAP] slack window at the level where its own engine unit is
    LEAST contended (same-unit ops in one level time-share the unit;
    cross-unit ops genuinely overlap), never exceeding ASAP's worst
    same-unit width.  All policies keep the critical-path level count and
    produce valid levelings with bit-identical execution.
    """
    asap: Dict[int, int] = {}
    for n in graph.nodes:
        asap[n.id] = (1 + max(asap[i] for i in n.inputs)) if n.inputs else 0
    n_levels = 1 + max(asap.values())
    if policy == "asap":
        level = asap
    elif policy == "alap":
        level = _alap_levels(graph, n_levels)
    elif policy == "slack":
        level = _slack_levels(graph, asap, n_levels)
    else:
        raise ValueError(f"unknown leveling policy {policy!r} "
                         "(want 'asap', 'alap' or 'slack')")
    levels = [[] for _ in range(n_levels)]
    for n in graph.nodes:                  # nodes are id-ordered already
        levels[level[n.id]].append(n.id)
    lvls = tuple(tuple(lv) for lv in levels if lv)
    return Schedule(lvls, stats=_levels_stats(graph, lvls))


def _alap_levels(graph: Graph, n_levels: int) -> Dict[int, int]:
    consumers = graph.consumers()
    level: Dict[int, int] = {}
    for n in reversed(graph.nodes):        # ids are topological
        cs = consumers[n.id]
        level[n.id] = (min(level[c] for c in cs) - 1) if cs \
            else n_levels - 1
    return level


def _unit_widths(graph: Graph, level: Dict[int, int], n_levels: int):
    """Per-level per-unit op counts of an assignment."""
    counts = [dict() for _ in range(n_levels)]
    for n in graph.nodes:
        u = engine_unit(n)
        c = counts[level[n.id]]
        c[u] = c.get(u, 0) + 1
    return counts


def _slack_levels(graph: Graph, asap: Dict[int, int],
                  n_levels: int) -> Dict[int, int]:
    """Contention-aware slack leveling (the bounded-ALAP hybrid).

    Walk the nodes in topological order; each op's feasible window is
    [1 + max(placed inputs), ALAP(op)] -- every placement keeps the graph's
    critical-path level count, since an op placed at most at its ALAP level
    leaves all its consumers a non-empty window.  Within the window the op
    lands on the level where its own engine unit has the fewest ops already
    (same-unit ops time-share the unit -- the contention the policy
    minimizes), preferring levels already busy on OTHER compute units (the
    cross-engine pairing that raises occupancy), earliest level on ties.

    ASAP's worst per-unit same-level width is the hard cap: levels already
    at the cap for the op's unit are avoided while any other level in the
    window is below it, and if a placement would still exceed the cap
    anywhere the policy falls back to the plain ASAP assignment -- so slack
    never raises max same-unit ops per level above ASAP (property-tested).
    """
    alap = _alap_levels(graph, n_levels)
    cap: Dict[str, int] = {}
    for c in _unit_widths(graph, asap, n_levels):
        for u, k in c.items():
            cap[u] = max(cap.get(u, 0), k)
    counts = [dict() for _ in range(n_levels)]
    compute = set(_COMPUTE_UNITS)
    # Pin the zero-slack (critical-path) ops first: they can never move --
    # every predecessor's ALAP is strictly below them, so no slack placement
    # can push them -- and seeding their unit load lets the movable ops see
    # the true contention picture instead of a half-empty one.
    placed: Dict[int, int] = {}
    for n in graph.nodes:
        if asap[n.id] == alap[n.id]:
            placed[n.id] = asap[n.id]
            c = counts[asap[n.id]]
            u = engine_unit(n)
            c[u] = c.get(u, 0) + 1
    for n in graph.nodes:
        if n.id in placed:
            continue
        u = engine_unit(n)
        lo = 1 + max((placed[i] for i in n.inputs), default=-1)
        window = range(lo, alap[n.id] + 1)
        under = [lv for lv in window if counts[lv].get(u, 0) < cap[u]]
        cands = under or list(window)

        def goodness(lv: int):
            others = sum(1 for uu, k in counts[lv].items()
                         if k and uu != u and uu in compute)
            return (counts[lv].get(u, 0), -others, lv)

        best = min(cands, key=goodness)
        placed[n.id] = best
        counts[best][u] = counts[best].get(u, 0) + 1
    for c in counts:
        for u, k in c.items():
            if k > cap.get(u, 0):
                return dict(asap)          # cap breached: fall back
    return placed


def schedule_stats(graph: Graph, sched: Schedule) -> Dict[str, int]:
    """Concurrency evidence: how much overlap the leveling exposes."""
    return _levels_stats(graph, sched.levels)


def _levels_stats(graph: Graph, levels) -> Dict[str, int]:
    wide = cross = conv_dwc = 0
    max_unit = 0
    for lv in levels:
        per_unit: Dict[str, int] = {}
        for i in lv:
            u = engine_unit(graph.nodes[i])
            per_unit[u] = per_unit.get(u, 0) + 1
        units = set(per_unit)
        compute = units & set(_COMPUTE_UNITS)
        max_unit = max([max_unit] + [per_unit[u] for u in compute])
        if len(lv) > 1:
            wide += 1
        if len(compute) > 1:
            cross += 1
        if CONV_PE in units and DWC_PE in units:
            conv_dwc += 1
    return {
        "levels": len(levels),
        "ops": len(graph.nodes),
        "max_width": max(len(lv) for lv in levels),
        "wide_levels": wide,
        "cross_engine_levels": cross,
        "conv_dwc_levels": conv_dwc,
        # worst same-unit op count in any level: the contention the "slack"
        # policy levels down (same-unit ops in one wave time-share the unit)
        "max_unit_width": max_unit,
    }


def engine_occupancy(graph: Graph, sched: Schedule) -> Dict[str, float]:
    """Per-level engine occupancy: how busy each engine unit is across the
    dispatch waves.

    For every level, a compute unit is "busy" when at least one of its ops
    dispatches in that wave.  `occupancy` is the mean busy-unit fraction
    over levels that dispatch any compute at all (MEM-only levels -- the
    input load -- are excluded); per-unit entries are the fraction of those
    levels each unit works in.  ALAP's slack sliding raises this against
    ASAP on branchy graphs, which is the number the serving benchmark
    compares.
    """
    busy = {u: 0 for u in _COMPUTE_UNITS}
    compute_levels = 0
    total_busy = 0
    for lv in sched.levels:
        units = {engine_unit(graph.nodes[i]) for i in lv} & set(_COMPUTE_UNITS)
        if not units:
            continue
        compute_levels += 1
        total_busy += len(units)
        for u in units:
            busy[u] += 1
    if compute_levels == 0:
        return {"occupancy": 0.0, "levels": 0.0}
    # only rate units the graph uses at all (a pure-LM graph has no DWC work)
    used = {u for n in graph.nodes
            for u in [engine_unit(n)] if u in _COMPUTE_UNITS}
    out = {"occupancy": total_busy / (compute_levels * max(len(used), 1)),
           "levels": float(compute_levels)}
    for u in sorted(used):
        out[u] = busy[u] / compute_levels
    return out


def time_weighted_occupancy(graph: Graph, sched: Schedule,
                            node_times: Dict[int, float]) -> Dict[str, float]:
    """Time-weighted per-engine busy fractions over a leveling.

    engine_occupancy counts unit *presence* per level -- a level where the
    MISC core does 1us of norm work next to 1ms of Conv PE GEMMs rates both
    units equally.  This weights by modeled seconds instead (`node_times`:
    {node_id: seconds}, e.g. benchmarks.perf_model.lm_node_times): a
    level's span is the busiest unit's summed time in it (same-unit ops
    time-share their engine; distinct units run concurrently), the program
    span is the sum over levels, and each unit's busy fraction is its total
    time over that span.  This is the ROADMAP's "time-weighted busy
    fraction" for LM (and decode) programs, where op costs differ by
    orders of magnitude.
    """
    busy = {u: 0.0 for u in _COMPUTE_UNITS}
    span = 0.0
    for lv in sched.levels:
        per_unit: Dict[str, float] = {}
        for i in lv:
            u = engine_unit(graph.nodes[i])
            per_unit[u] = per_unit.get(u, 0.0) + float(node_times.get(i, 0.0))
        for u, t in per_unit.items():
            if u in busy:
                busy[u] += t
        span += max(per_unit.values(), default=0.0)
    used = {u for n in graph.nodes
            for u in [engine_unit(n)] if u in _COMPUTE_UNITS}
    out: Dict[str, float] = {"span_s": span}
    for u in sorted(used):
        out[u] = busy[u] / span if span > 0 else 0.0
    out["occupancy"] = (sum(busy[u] for u in used) / (span * len(used))
                        if span > 0 and used else 0.0)
    return out


def validate_schedule(graph: Graph, sched: Schedule) -> None:
    """Raise if the schedule is not a valid topological leveling that covers
    every node exactly once."""
    seen: Dict[int, int] = {}
    for k, lv in enumerate(sched.levels):
        for i in lv:
            if i in seen:
                raise ValueError(f"node {i} scheduled twice "
                                 f"(levels {seen[i]} and {k})")
            seen[i] = k
    ids = {n.id for n in graph.nodes}
    if set(seen) != ids:
        missing = sorted(ids - set(seen))
        extra = sorted(set(seen) - ids)
        raise ValueError(f"schedule coverage mismatch: missing={missing} "
                         f"extra={extra}")
    for n in graph.nodes:
        for i in n.inputs:
            if seen[i] >= seen[n.id]:
                raise ValueError(
                    f"edge {i}->{n.id} violates leveling: producer in level "
                    f"{seen[i]}, consumer in level {seen[n.id]}")
