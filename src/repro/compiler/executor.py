"""Engine-program executor: runs a compiled op graph on any backend.

Programs from both frontends execute here: CNN graphs (build_graph) and LM
prefill graphs (lower_transformer), including mixed fleets sharing one
engine -- the op evaluators dispatch on node kind, not on model family.

Two execution modes, selected by whether the program carries a QuantPlan:

  * dynamic (plan=None) -- reproduces the historical eager paths exactly
    (`cnn_forward`, `T.forward`): every op dispatches through kernels/ops.py
    with the engine config's quant mode, GEMM activations are re-quantized
    dynamically per call.  This is the float/training path and the
    "dynamic-f32 pipeline" baseline of the benchmarks.

  * static (plan from passes.fold_requant) -- the paper's dataflow: for a
    CNN the input image is quantized once with its calibrated scale and
    every engine consumes and emits int8 via its fused requant epilogue; for
    an LM program every Conv PE GEMM consumes int8 at a static calibrated
    scale (the producing MISC op's requant epilogue), while the float-domain
    MISC work (attention math, residual stream, gate product) stays f32.

LM prefill programs additionally support `collect`: each AttnOp deposits its
(roped-k, v) pair keyed by layer index, which the serving layer writes into
the decode KV cache -- so one compiled program yields both the prefill
logits and the cache fill, like `T.prefill`.

Either mode consumes the program's Schedule (compiler/schedule.py) when one
is attached: ops are dispatched level-by-level, and every op of a level is
evaluated against the previous levels' values only -- concurrent-PE
semantics, where a same-level data dependence would fail loudly instead of
silently serializing.  A program without a schedule falls back to the raw
topological node order (bit-identical results either way; the parity suite
pins that).

Backend selection (ref / pallas / XVDPU-analog baseline) stays inside
kernels/ops.py: the same compiled program runs on any EngineConfig.

Compiled dynamic programs are memoized in a bounded ProgramCache
(core/program_cache.py) rather than a raw functools.lru_cache: the same
store type the serving layer keys calibrated programs with, LRU-bounded
instead of unbounded, and its hit/miss counters feed the serving
benchmarks.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compiler import passes as passes_lib
from repro.compiler.graph import (AddOp, AttnOp, ConcatOp, ConvOp, DwcOp,
                                  EmbedOp, Graph, HeadOp, InputOp,
                                  LinearGroupOp, LinearOp, MulOp, NormOp,
                                  OpNode, PoolOp, ViewOp, build_graph,
                                  get_param, lower_transformer)
from repro.compiler.passes import QuantPlan, fold_requant
from repro.compiler.schedule import (MergedSchedule, Schedule,
                                     level_schedule, merge_schedules)
from repro.core.config import ArchConfig, CNNConfig, EngineConfig
from repro.core.quant import (Q4Tensor, QTensor, quantize_act_dynamic,
                              quantize_static)
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import transformer as T
from repro.core.program_cache import ProgramCache, ProgramKey


@dataclass(frozen=True)
class Program:
    """A compiled engine program: op graph + optional static-int8 plan and
    concurrent-dispatch schedule.  `cfg` is the frontend config the graph
    was lowered from (CNNConfig or ArchConfig).

    kind="forward": stateless (image or token batch) -> logits; run it with
    `execute`.  kind="decode": a DecodeStep program -- the cache-state
    recurrence with signature (params, cache, tokens) -> (logits, cache);
    run it with `execute_decode`.  kind="chunk": a chunked partial-prefill
    program over a paged cache (prefix sharing) -- run it with
    `prefill_from(program, params, cache, tokens, eng, start=...)`."""
    graph: Graph
    cfg: Hashable
    plan: Optional[QuantPlan] = None
    schedule: Optional[Schedule] = None
    kind: str = "forward"

    @property
    def static(self) -> bool:
        return self.plan is not None

    def f32_roundtrips(self) -> int:
        """f32 edges between engines (0 for a correct static program)."""
        if self.plan is None:
            return passes_lib.dynamic_roundtrip_count(self.graph)
        return len(passes_lib.f32_roundtrip_edges(self.graph, self.plan))


# The process-wide store for compiled dynamic programs (the eager
# cnn_forward path compiles each config once).  Bounded: a long-running
# trainer or server sweeping many configs no longer grows it without limit.
_DYNAMIC_CACHE_CAPACITY = 64
_dynamic_cache = ProgramCache(capacity=_DYNAMIC_CACHE_CAPACITY)


def program_cache() -> ProgramCache:
    """The executor's dynamic-program store (shared with the serving layer's
    introspection; serving keeps its own cache for calibrated programs)."""
    return _dynamic_cache


def schedule_variant(scheduled: bool, policy: str) -> str:
    """The ProgramKey variant string for a scheduling choice."""
    if not scheduled:
        return "sequential"
    return "scheduled" if policy == "asap" else f"scheduled-{policy}"


def compile_cnn(cfg: CNNConfig,
                scales: Optional[Dict[int, float]] = None,
                scheduled: bool = True, policy: str = "asap",
                granularity: str = "per_tensor",
                fuse: bool = True) -> Program:
    """Lower a CNNConfig to an engine program.

    Without `scales` the program executes dynamically (eager-equivalent);
    that program is cached per config (CNNConfig is frozen/hashable) in the
    bounded program_cache(), so the eager cnn_forward wrapper builds each
    graph once.  With calibrated per-edge scales the requant-folding pass
    produces the static int8 plan (granularity="per_channel" keeps channel
    vectors on the DWC-consumed edges).  `scheduled=False` omits the
    concurrency schedule (sequential raw-order dispatch; the parity tests'
    baseline); `policy` selects ASAP / ALAP / slack leveling
    (schedule.level_schedule).

    `fuse` (default ON) runs passes.fuse_epilogues: Conv/DWC -> {residual
    add, pool tail} chains collapse into single fused launches, with the
    calibration scales remapped onto the fused graph (calibration itself
    always observes the UNFUSED graph, whose edges are what the scales
    describe).  fuse=False keeps the one-op-per-launch graph -- the
    fused-vs-unfused parity baseline.
    """

    def lower():
        g = build_graph(cfg)
        if fuse:
            g, _ = passes_lib.fuse_epilogues(g)
        return g

    if scales is None:
        variant = schedule_variant(scheduled, policy) + (
            "" if fuse else ":nofuse")
        key = ProgramKey(cfg, None, None, variant)
        return _dynamic_cache.get_or_compile(
            key, lambda: _finish_program(lower(), cfg, None,
                                         scheduled, policy))
    g = build_graph(cfg)
    if fuse:
        g, scales = passes_lib.fuse_epilogues(g, scales)
    return _finish_program(g, cfg, scales, scheduled, policy,
                           granularity=granularity)


def compile_lm(arch: ArchConfig,
               scales: Optional[Dict[int, float]] = None,
               scheduled: bool = True, policy: str = "asap",
               prefill: bool = False, mode: Optional[str] = None,
               granularity: str = "per_tensor",
               fuse: bool = True, page_size: int = 0) -> Program:
    """Lower a transformer ArchConfig to an engine program.

    `mode` selects the program: "full" computes full-sequence logits like
    `T.forward`; "prefill" emits only the last position's logits (the
    serving variant whose AttnOps feed the KV-cache fill via `collect`);
    "decode" is the DecodeStep program (run with `execute_decode`).  The
    legacy `prefill=True` flag is shorthand for mode="prefill".  Dynamic
    programs are memoized per (arch, variant) in the bounded
    program_cache(); calibrated ones are keyed by the serving layer.

    `fuse` (default ON, mirroring compile_cnn) runs the LM graph rewrites:
    passes.fuse_projections collapses each Q/K/V triple and gate/up pair
    into ONE multi-output Conv PE launch, then passes.fuse_epilogues folds
    the residual adds after the O/down projections into their GEMMs.
    Calibration always observes the UNFUSED graph; its per-edge scales are
    remapped through both rewrites (deterministic, so the full and decode
    twins stay node-aligned).  fuse=False keeps the one-op-per-launch
    graph -- the fused-vs-unfused parity baseline.

    `page_size` > 0 (decode / chunk modes) compiles the block-paged
    variant: global-layer AttnOps index cache["tables"] instead of a dense
    [B, max_seq] cache.  The page size rides the program variant (":pN"),
    so paged and dense programs hold distinct ProgramCache lines.

    mode="chunk" is the prefix-sharing partial-prefill program (run with
    `prefill_from`): a [B, T] prompt TAIL attends the paged cache at a
    query offset and stores only the slot's owned tail pages.  It
    requires page_size > 0 and an all-global arch.
    """
    mode = mode or ("prefill" if prefill else "full")
    if mode not in ("full", "prefill", "decode", "chunk"):
        raise ValueError(f"unknown LM program mode {mode!r}")
    if page_size and mode not in ("decode", "chunk"):
        raise ValueError("page_size applies to decode/chunk programs only")
    if mode == "chunk" and page_size <= 0:
        raise ValueError("chunk programs need page_size > 0")
    variant = (schedule_variant(scheduled, policy) + f":{mode}"
               + (f":p{page_size}" if page_size else "")
               + ("" if fuse else ":nofuse"))
    kind = mode if mode in ("decode", "chunk") else "forward"

    def lower(sc=None):
        if mode in ("decode", "chunk"):
            g = lower_transformer(arch, mode=mode, page_size=page_size)
        else:
            g = lower_transformer(arch, last_only=(mode == "prefill"))
        if fuse:
            g, sc = passes_lib.fuse_projections(g, sc)
            g, sc = passes_lib.fuse_epilogues(g, sc)
        return g, sc

    if scales is None:
        key = ProgramKey(arch, None, None, variant)
        return _dynamic_cache.get_or_compile(
            key, lambda: _finish_program(lower()[0], arch, None,
                                         scheduled, policy, kind))
    g, scales = lower(scales)
    return _finish_program(g, arch, scales, scheduled, policy, kind,
                           granularity=granularity)


def _finish_program(g: Graph, cfg, scales, scheduled: bool,
                    policy: str = "asap", kind: str = "forward",
                    granularity: str = "per_tensor") -> Program:
    plan = (fold_requant(g, scales, granularity=granularity)
            if scales is not None else None)
    sched = None
    if scheduled:
        times = None
        if policy in ("cost", "slack"):
            # Time-aware policies price each node with the analytic tile
            # model (compiler/cost.py); count-based behavior is preserved
            # when callers invoke level_schedule directly without times.
            from repro.compiler import cost as cost_lib
            times = cost_lib.default_node_times(g, cfg, kind)
        sched = level_schedule(g, policy, node_times=times)
    return Program(g, cfg, plan, sched, kind)


def execute(program: Program, params, inputs: jax.Array,
            eng: EngineConfig,
            observer: Optional[Callable[[OpNode, jax.Array], None]] = None,
            collect: Optional[dict] = None) -> jax.Array:
    """Run a stateless (kind="forward") program.  `inputs` is whatever the
    graph's InputOp consumes: [N, H, W, C] float images (CNN) or [B, L]
    int32 token ids (LM).  Returns logits.  `collect`, when given, is
    filled with each AttnOp's (k, v) pair keyed by layer index (the
    serving KV-cache fill)."""
    if program.kind == "decode":
        raise ValueError("decode programs carry cache state; run them "
                         "through execute_decode(program, params, cache, "
                         "tokens, eng)")
    if program.kind == "chunk":
        raise ValueError("chunk programs carry cache state; run them "
                         "through prefill_from(program, params, cache, "
                         "tokens, eng, start=...)")
    if program.static:
        return _execute_static(program, params, inputs, eng, collect)
    return _execute_dynamic(program, params, inputs, eng, observer, collect)


class _DecodeCtx:
    """Cache state threaded through a DecodeStep program's AttnOp updates.

    `tables` is the block table [B, max_pages] of a paged cache (None for
    dense).  `collect`, when set to a dict, flips the AttnOps into VERIFY
    mode: fresh per-token (k, v) land there instead of the cache, which
    stays untouched until `commit_decode_kv` applies the accepted prefix.
    """

    def __init__(self, cache: dict):
        self.cache = cache
        self.pos = cache["pos"]          # scalar, or [B] per-slot positions
        self.tables = cache.get("tables")
        self.collect: Optional[Dict[int, tuple]] = None
        self.new_layers: Dict[int, dict] = {}

    def entry(self, layer: int) -> dict:
        return self.cache["layers"][layer]

    def finish(self) -> dict:
        layers = [self.new_layers.get(i, e)
                  for i, e in enumerate(self.cache["layers"])]
        out = {"layers": layers, "pos": self.pos + 1}
        if self.tables is not None:
            out["tables"] = self.tables
        return out


def execute_decode(program: Program, params, cache: dict,
                   tokens: jax.Array, eng: EngineConfig
                   ) -> Tuple[jax.Array, dict]:
    """Run a DecodeStep program: one token per slot against the KV cache.

    tokens: [B, 1] int32; cache: the serving cache (T.cache_schema or
    T.paged_cache_schema layout, "pos" scalar or [B] per-slot).  Returns
    (logits [B, 1, V], new cache) -- the compiled counterpart of
    `T.decode`, jit/donation friendly."""
    if program.kind != "decode":
        raise ValueError(f"execute_decode needs a decode program, got "
                         f"kind={program.kind!r}")
    ctx = _DecodeCtx(cache)
    if program.static:
        logits = _execute_static(program, params, tokens, eng, None,
                                 decode=ctx)
    else:
        logits = _execute_dynamic(program, params, tokens, eng, None, None,
                                  decode=ctx)
    return logits, ctx.finish()


def execute_verify(program: Program, params, cache: dict,
                   tokens: jax.Array, eng: EngineConfig
                   ) -> Tuple[jax.Array, Dict[int, tuple]]:
    """Teacher-force W tokens per slot through a DecodeStep program WITHOUT
    committing cache state -- the speculative-decode verification step.

    tokens: [B, W] int32 (position i sits at cache position pos+i).  Each
    AttnOp scatters its fresh per-token (k, v) into a read-once VIEW of the
    cache, so token i attends to committed history plus drafts 0..i exactly
    as sequential decode would -- logits are bit-identical to W sequential
    `execute_decode` steps.  Returns (logits [B, W, V], kvs): per-layer
    post-RoPE fresh (k, v) [B, W, Hkv, D] for `commit_decode_kv`.
    """
    if program.kind != "decode":
        raise ValueError(f"execute_verify needs a decode program, got "
                         f"kind={program.kind!r}")
    ctx = _DecodeCtx(cache)
    ctx.collect = {}
    if program.static:
        logits = _execute_static(program, params, tokens, eng, None,
                                 decode=ctx)
    else:
        logits = _execute_dynamic(program, params, tokens, eng, None, None,
                                  decode=ctx)
    return logits, ctx.collect


def commit_decode_kv(program: Program, cache: dict,
                     kvs: Dict[int, tuple], accept: jax.Array,
                     eng: EngineConfig) -> dict:
    """Commit the accepted prefix of verified draft (k, v) into the cache.

    kvs: `execute_verify`'s per-layer fresh (k, v) [B, W, Hkv, D];
    accept: [B] int32, tokens to commit per slot (0 <= accept <= W; 0 for
    idle slots).  Draft position i commits iff i < accept[b]; rejected
    writes are redirected past the buffer end and dropped, so a rolled-back
    slot's cache is untouched.  pos advances by accept.  Returns the new
    cache dict (same schema, donation friendly)."""
    accept = jnp.asarray(accept, jnp.int32)
    b = accept.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), (b,))
    tables = cache.get("tables")
    layers = list(cache["layers"])
    for n in program.graph.nodes:
        if not (isinstance(n, AttnOp) and n.mode == "update"):
            continue
        k, v = kvs[n.layer]
        entry = layers[n.layer]
        for i in range(k.shape[1]):
            mask = i < accept
            ki, vi = k[:, i:i + 1], v[:, i:i + 1]
            if n.page_size:
                entry = T._paged_kv_store(entry, ki, vi, tables, pos + i,
                                          eng, n.page_size, mask=mask)
            elif n.layer_kind == "local":
                w = entry["k"].shape[1]
                entry = T._masked_kv_store(entry, ki, vi, (pos + i) % w,
                                           mask, eng)
            else:
                entry = T._masked_kv_store(entry, ki, vi, pos + i, mask, eng)
        layers[n.layer] = entry
    out = {"layers": layers, "pos": cache["pos"] + accept}
    if tables is not None:
        out["tables"] = tables
    return out


class _ChunkCtx:
    """Paged-cache state threaded through a chunk (partial-prefill)
    program's AttnOps.

    `start` is the STATIC absolute position of the tail's first token
    (uniform across rows -- sharing pins the padded prompt width, so
    every admitted row's tail occupies positions [start, start+T)).
    `row_starts` [B] is each row's first NON-SHARED position: stores
    below it are dropped (those pages belong to the prefix index and
    possibly other tables -- the copy-on-write boundary), but the row
    still RECOMPUTES [start, row_starts) so one fused wave can mix
    match lengths; recomputed values are bit-identical to the shared
    pages' content, so skipping their store changes nothing.
    `mask` [B] gates rows being (re)filled, like _run_paged_prefill."""

    def __init__(self, cache: dict, start: int, row_starts, mask):
        self.cache = cache
        self.tables = cache["tables"]
        self.start = start
        self.row_starts = row_starts
        self.mask = mask
        self.new_layers: Dict[int, dict] = {}

    def entry(self, layer: int) -> dict:
        return self.cache["layers"][layer]

    def finish(self, width: int) -> dict:
        layers = [self.new_layers.get(i, e)
                  for i, e in enumerate(self.cache["layers"])]
        pos = jnp.where(self.mask, self.start + width, self.cache["pos"])
        return {"layers": layers, "pos": pos, "tables": self.tables}


def prefill_from(program: Program, params, cache: dict, tokens: jax.Array,
                 eng: EngineConfig, *, start: int, row_starts, mask
                 ) -> Tuple[jax.Array, dict]:
    """Run a chunk program: prefill the TAIL of a prompt whose first
    `start` positions already sit in the paged cache (shared prefix
    pages matched by the engine's prefix index).

    tokens: [B, T] int32, the tail span (absolute positions
    [start, start+T)); cache: T.paged_cache_schema layout with "tables"
    bound.  row_starts [B] is each row's first position NOT covered by
    its matched prefix (start <= row_starts[b] <= start+T); mask [B]
    gates the rows being filled.  Returns (last-position logits
    [B, 1, V], new cache) -- masked rows' cache entries and positions
    are untouched, matching `_run_paged_prefill` semantics.

    start == 0 with row_starts == 0 reproduces a whole-prompt paged
    prefill through the same program, which is what makes any
    page-aligned split point bit-identical: the attended k/v ALWAYS
    round-trips the cache dtype, whether it came from a shared page or
    the fresh tail."""
    if program.kind != "chunk":
        raise ValueError(f"prefill_from needs a chunk program, got "
                         f"kind={program.kind!r}")
    b = tokens.shape[0]
    row_starts = jnp.broadcast_to(
        jnp.asarray(row_starts, jnp.int32), (b,))
    mask = jnp.broadcast_to(jnp.asarray(mask, bool), (b,))
    ctx = _ChunkCtx(cache, jnp.asarray(start, jnp.int32), row_starts, mask)
    if program.static:
        logits = _execute_static(program, params, tokens, eng, None,
                                 chunk=ctx)
    else:
        logits = _execute_dynamic(program, params, tokens, eng, None, None,
                                  chunk=ctx)
    return logits, ctx.finish(tokens.shape[1])


# ---------------------------------------------------------------------------
# Scheduled dispatch (shared by both modes)
# ---------------------------------------------------------------------------

def _refcounts(g: Graph) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for n in g.nodes:
        for i in n.inputs:
            counts[i] = counts.get(i, 0) + 1
    return counts


def _release(vals: Dict, counts: Dict[int, int], n: OpNode, g: Graph) -> None:
    """Drop activations after their last consumer so eager (un-jitted)
    execution keeps O(live edges) -- not O(all nodes) -- tensors alive,
    matching the replaced hand-written forward's rebinding behavior."""
    for i in n.inputs:
        counts[i] -= 1
        if counts[i] == 0 and i != g.output:
            del vals[i]


def _dispatch_waves(program: Program) -> Iterable[Tuple[OpNode, ...]]:
    """The execution order: schedule levels when present, else one op per
    wave in raw topological order."""
    g = program.graph
    if program.schedule is None:
        for n in g.nodes:
            yield (n,)
    else:
        for level in program.schedule.levels:
            yield tuple(g.nodes[i] for i in level)


def _run_scheduled(program: Program, eval_node, observer=None):
    """Evaluate the program wave-by-wave.  Each wave's ops read only values
    produced by earlier waves (`vals` is merged after the whole wave), so a
    schedule bug that co-levels dependent ops raises KeyError instead of
    silently reading a half-updated environment."""
    g = program.graph
    counts = _refcounts(g)
    vals: Dict[int, object] = {}
    for wave in _dispatch_waves(program):
        produced = [(n, eval_node(n, vals)) for n in wave]
        for n, v in produced:
            vals[n.id] = v
        for n, v in produced:
            if observer is not None:
                observer(n, v)
            _release(vals, counts, n, g)
    return vals[g.output]


# ---------------------------------------------------------------------------
# LM op evaluators (shared by both modes; the float-domain MISC work)
# ---------------------------------------------------------------------------

# Module-level bounded cos/sin table store: repeated eager executes (serve
# waves draining through un-jitted paths, calibration sweeps, tests) stop
# rebuilding the same RoPE tables on every call.  Bounded LRU so a server
# sweeping many (B, L) shapes cannot grow it without limit.
_ROPE_TABLE_CAPACITY = 32
_rope_tables: "OrderedDict[Tuple, Tuple[jax.Array, jax.Array]]" = OrderedDict()


def _rope_table(b: int, l: int, hd: int, theta: float):
    """The (cos, sin) table for (B, L, head_dim, theta).

    Concrete tables are cached module-wide (every AttnOp of every program
    with the same geometry reuses one table).  Traced values -- execute()
    running under jit -- are NEVER stored: a cached tracer would poison
    later calls, and jitted programs constant-fold the tables into their
    trace anyway, so the cache only needs to serve eager execution.
    """
    key = (b, l, hd, theta)
    hit = _rope_tables.get(key)
    if hit is not None:
        _rope_tables.move_to_end(key)
        return hit
    pos = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
    val = L.rope_angles(pos, hd, theta)
    if not isinstance(val[0], jax.core.Tracer):
        _rope_tables[key] = val
        while len(_rope_tables) > _ROPE_TABLE_CAPACITY:
            _rope_tables.popitem(last=False)
    return val


def rope_table_stats() -> Dict[str, int]:
    """Introspection for tests/benchmarks."""
    return {"entries": len(_rope_tables),
            "capacity": _ROPE_TABLE_CAPACITY}


def _rope_decode_memo(pos):
    """Decode-step RoPE: angles at the cache position(s), one table per
    (B, W, head_dim, theta) per execute_decode() call.  `pos` is a scalar
    or [B] per-slot position vector (both traced under jit); draft token i
    of a W-wide verify burst sits at position pos + i (W == 1 reproduces
    the single-token table bitwise: + arange(1) is the integer identity)."""
    cache: Dict[Tuple, Tuple[jax.Array, jax.Array]] = {}

    def rope(b: int, w: int, hd: int, theta: float):
        key = (b, w, hd, theta)
        if key not in cache:
            base = (pos[:, None] if jnp.asarray(pos).ndim == 1
                    else jnp.broadcast_to(pos[None, None], (b, 1)))
            positions = base + jnp.arange(w, dtype=jnp.int32)[None, :]
            cache[key] = L.rope_angles(positions, hd, theta)
        return cache[key]

    return rope


def _embed_eval(n: EmbedOp, tokens: jax.Array, params) -> jax.Array:
    emb = get_param(params, n.w)
    if isinstance(emb, QTensor):
        rows = jnp.take(emb.q, tokens, axis=0).astype(jnp.float32)
        x = rows * jnp.take(emb.scale, tokens, axis=0)
    else:
        x = jnp.take(emb, tokens, axis=0).astype(jnp.float32)
    if n.emb_scale:
        x = x * jnp.asarray(n.emb_scale, jnp.float32)
    return x


def _cache_roundtrip(val: jax.Array, eng: EngineConfig) -> jax.Array:
    """Cast a fresh k/v slice [B, 1, Hkv, D] exactly as a cache store+read
    roundtrip would: int8 caches quantize per-token and dequantize back to
    bf16; bf16 caches just downcast.  The speculative verify path scatters
    these into the read-once cache view, so each draft token sees the SAME
    bits sequential store-then-read decode would produce."""
    if eng.kv_cache_dtype == "int8":
        q = quantize_act_dynamic(val, per_token=True)
        return (q.q.astype(jnp.float32) * q.scale).astype(jnp.bfloat16)
    return val.astype(jnp.bfloat16)


def _attn_update_eval(n: AttnOp, q: jax.Array, k: jax.Array, v: jax.Array,
                      rope_d, ctx: "_DecodeCtx", eng: EngineConfig
                      ) -> jax.Array:
    """AttnOp in `update` mode: write this token's (k, v) into the cache at
    the slot position, then attend against the cache -- the op-level twin
    of the attention body of `T.decode` (bit-identical cache layout).

    Three variants share this evaluator:
      * dense commit (page_size == 0, width 1): the historical path, byte
        for byte unchanged.
      * paged commit (n.page_size > 0): the store goes through the block
        table into the shared pool; the read gathers the slot-ordered
        dense view, so attention math is identical to the dense cache.
      * verify (ctx.collect set, width W >= 1): NOTHING commits.  Fresh
        (k, v) scatter into a read-once view and draft token i attends to
        committed history + drafts 0..i -- teacher-forced sequential
        decode, restartable because the real cache never moved.
    """
    b, width = q.shape[0], q.shape[1]
    g = n.n_heads // n.n_kv_heads
    q = q.reshape(b, width, n.n_kv_heads, g, n.head_dim)
    k = k.reshape(b, width, n.n_kv_heads, n.head_dim)
    v = v.reshape(b, width, n.n_kv_heads, n.head_dim)
    cos, sin = rope_d(b, width, n.head_dim, n.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    entry = ctx.entry(n.layer)
    paged = bool(n.page_size)
    ring = n.layer_kind == "local"
    if ctx.collect is not None:
        ctx.collect[n.layer] = (k, v)
        if paged:
            kc, vc = T._paged_kv_read(entry, ctx.tables, eng)
        else:
            kc, vc = T._kv_read(entry, eng)
        s = kc.shape[1]
        pos = jnp.broadcast_to(jnp.asarray(ctx.pos, jnp.int32), (b,))
        rows = jnp.arange(b)
        outs = []
        for i in range(width):
            slot = (pos + i) % s if ring else pos + i
            ki = _cache_roundtrip(k[:, i:i + 1], eng)[:, 0]
            vi = _cache_roundtrip(v[:, i:i + 1], eng)[:, 0]
            kc = kc.at[rows, slot].set(ki.astype(kc.dtype), mode="drop")
            vc = vc.at[rows, slot].set(vi.astype(vc.dtype), mode="drop")
            outs.append(L.decode_attention(
                q[:, i:i + 1], kc, vc, pos + i + 1, window=n.window,
                logit_softcap=n.softcap, ring=ring))
        out = jnp.concatenate(outs, axis=1)
        return out.reshape(b, width, n.n_heads * n.head_dim
                           ).astype(jnp.float32)
    if paged:
        entry = T._paged_kv_store(entry, k, v, ctx.tables, ctx.pos, eng,
                                  n.page_size)
        ctx.new_layers[n.layer] = entry
        kc, vc = T._paged_kv_read(entry, ctx.tables, eng)
    else:
        if ring:
            w = entry["k"].shape[1]
            entry = T._kv_store(entry, k, v, ctx.pos % w, eng)
        else:
            entry = T._kv_store(entry, k, v, ctx.pos, eng)
        ctx.new_layers[n.layer] = entry
        kc, vc = T._kv_read(entry, eng)
    out = L.decode_attention(q, kc, vc, ctx.pos + 1, window=n.window,
                             logit_softcap=n.softcap, ring=ring)
    return out.reshape(b, 1, n.n_heads * n.head_dim).astype(jnp.float32)


def _attn_chunk_eval(n: AttnOp, q: jax.Array, k: jax.Array, v: jax.Array,
                     rope_c, ctx: "_ChunkCtx", eng: EngineConfig
                     ) -> jax.Array:
    """AttnOp in `chunk` mode: the prefix-sharing partial prefill.

    The tail's fresh (k, v) is RoPE'd at its absolute positions
    (start + j), scattered through the block table into the slot's OWNED
    tail pages only (positions < row_starts[b] drop -- those pages are
    shared, read-only), then the tail queries attend the gathered cache
    view at q_offset=start.  Reading back AFTER the store means every
    attended key -- shared prefix and fresh tail alike -- has
    round-tripped the cache dtype, so logits are invariant to WHERE the
    page-aligned split fell (the bit-identity contract the golden test
    pins).  Rows whose match extends past `start` recompute those
    positions; the recomputed bits equal the shared pages' content, and
    their store is masked off, so nothing shared is ever written."""
    b, t = q.shape[0], q.shape[1]
    g = n.n_heads // n.n_kv_heads
    q = q.reshape(b, t, n.n_kv_heads, g, n.head_dim)
    k = k.reshape(b, t, n.n_kv_heads, n.head_dim)
    v = v.reshape(b, t, n.n_kv_heads, n.head_dim)
    cos, sin = rope_c(b, t, n.head_dim, n.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    entry = ctx.entry(n.layer)
    entry = T._paged_tail_store(entry, k, v, ctx.tables, ctx.mask, eng,
                                n.page_size, ctx.start, ctx.row_starts)
    ctx.new_layers[n.layer] = entry
    kc, vc = T._paged_kv_read(entry, ctx.tables, eng)
    out = L.flash_attention(q, kc, vc, causal=True, window=n.window,
                            logit_softcap=n.softcap, q_offset=ctx.start)
    return out.reshape(b, t, n.n_heads * n.head_dim).astype(jnp.float32)


def _attn_eval(n: AttnOp, q: jax.Array, k: jax.Array, v: jax.Array,
               rope, collect: Optional[dict]) -> jax.Array:
    b, l = q.shape[0], q.shape[1]
    g = n.n_heads // n.n_kv_heads
    q = q.reshape(b, l, n.n_kv_heads, g, n.head_dim)
    k = k.reshape(b, l, n.n_kv_heads, n.head_dim)
    v = v.reshape(b, l, n.n_kv_heads, n.head_dim)
    cos, sin = rope(b, l, n.head_dim, n.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    out = L.flash_attention(q, k, v, causal=True, window=n.window,
                            logit_softcap=n.softcap)
    if collect is not None:
        collect[n.layer] = (k, v)          # post-RoPE k, like T.prefill
    return out.reshape(b, l, n.n_heads * n.head_dim)


def _head_eval(n: HeadOp, x: jax.Array, params) -> jax.Array:
    w = get_param(params, n.w)
    xf = x.astype(jnp.float32)
    if n.last_only:
        xf = xf[:, -1:]
    sig = "bld,vd->blv" if n.tied else "bld,dv->blv"
    if isinstance(w, QTensor):
        logits = jnp.einsum(sig, xf, w.q.astype(jnp.float32))
        logits = logits * w.scale.reshape(1, 1, -1)
    else:
        logits = jnp.einsum(sig, xf, w.astype(jnp.float32))
    if n.softcap > 0:
        logits = jnp.tanh(logits / n.softcap) * n.softcap
    return logits


# ---------------------------------------------------------------------------
# Dynamic mode (eager-equivalent; also the calibration vehicle)
# ---------------------------------------------------------------------------

def _dynamic_eval(program: Program, params, images, eng: EngineConfig,
                  collect: Optional[dict] = None,
                  decode: Optional[_DecodeCtx] = None,
                  chunk: Optional["_ChunkCtx"] = None):
    """The dynamic-mode eval_node closure for one program invocation.

    Factored out of _execute_dynamic so execute_interleaved can drive two
    programs' evaluators on one merged tick stream."""
    rope = _rope_table
    rope_d = _rope_decode_memo(decode.pos) if decode is not None else None
    rope_c = (_rope_decode_memo(jnp.asarray(chunk.start, jnp.int32))
              if chunk is not None else None)

    def eval_node(n: OpNode, vals: Dict[int, jax.Array]) -> jax.Array:
        if isinstance(n, InputOp):
            return images
        if isinstance(n, ConvOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            ep = n.epilogue
            res = (vals[n.inputs[-1]] if ep is not None and ep.add
                   else None)
            if n.first_layer:
                v = ops.first_layer_conv(vals[n.inputs[0]], w, b, n.stride,
                                         n.padding, n.act, eng,
                                         epilogue=ep, residual=res)
                return v.astype(jnp.float32)
            return ops.conv2d_pe(vals[n.inputs[0]], w, b, n.stride,
                                 n.padding, n.act, eng,
                                 epilogue=ep, residual=res)
        if isinstance(n, DwcOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            ep = n.epilogue
            res = (vals[n.inputs[-1]] if ep is not None and ep.add
                   else None)
            return ops.dwc2d(vals[n.inputs[0]], w, b, n.stride, n.padding,
                             n.act, eng, epilogue=ep, residual=res)
        if isinstance(n, AddOp):
            return ops.misc_add(vals[n.inputs[0]], vals[n.inputs[1]],
                                n.act, eng)
        if isinstance(n, PoolOp):
            x = vals[n.inputs[0]]
            if n.pool == "global":
                return ref.global_avgpool(x)
            if n.pool == "avg":
                return ops.avgpool2d(x, n.kernel, n.stride, eng)
            return ref.maxpool2d(x, n.kernel, n.stride)
        if isinstance(n, ConcatOp):
            return jnp.concatenate([vals[i] for i in n.inputs], axis=-1)
        if isinstance(n, LinearOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            ep = n.epilogue
            if ep is not None and ep.add:
                return ops.linear_ep(vals[n.inputs[0]], w, b, n.act, ep,
                                     vals[n.inputs[-1]], eng,
                                     out_dtype=jnp.float32)
            return ops.linear(vals[n.inputs[0]], w, b, n.act, eng,
                              out_dtype=jnp.float32)
        if isinstance(n, LinearGroupOp):
            ws = [get_param(params, w) for w in n.ws]
            bs = [get_param(params, b) for b in n.bs]
            return ops.linear_group(vals[n.inputs[0]], ws, bs, n.acts, eng,
                                    out_dtype=jnp.float32)
        if isinstance(n, ViewOp):
            return vals[n.inputs[0]][n.index]
        if isinstance(n, EmbedOp):
            return _embed_eval(n, vals[n.inputs[0]], params)
        if isinstance(n, NormOp):
            return L.rms_norm(vals[n.inputs[0]], get_param(params, n.w),
                              n.eps)
        if isinstance(n, MulOp):
            return (vals[n.inputs[0]] * vals[n.inputs[1]]
                    ).astype(jnp.float32)
        if isinstance(n, AttnOp):
            if n.mode == "update":
                return _attn_update_eval(n, vals[n.inputs[0]],
                                         vals[n.inputs[1]], vals[n.inputs[2]],
                                         rope_d, decode, eng)
            if n.mode == "chunk":
                return _attn_chunk_eval(n, vals[n.inputs[0]],
                                        vals[n.inputs[1]], vals[n.inputs[2]],
                                        rope_c, chunk, eng)
            return _attn_eval(n, vals[n.inputs[0]], vals[n.inputs[1]],
                              vals[n.inputs[2]], rope, collect)
        if isinstance(n, HeadOp):
            return _head_eval(n, vals[n.inputs[0]], params)
        raise TypeError(f"unknown op {type(n).__name__}")

    return eval_node


def _execute_dynamic(program: Program, params, images, eng: EngineConfig,
                     observer=None, collect: Optional[dict] = None,
                     decode: Optional[_DecodeCtx] = None,
                     chunk: Optional["_ChunkCtx"] = None) -> jax.Array:
    eval_node = _dynamic_eval(program, params, images, eng, collect, decode,
                              chunk)
    return _run_scheduled(program, eval_node, observer)


# ---------------------------------------------------------------------------
# Static mode (calibrated end-to-end int8 dataflow)
# ---------------------------------------------------------------------------

def _require_qtensor(w, n: OpNode, path=None):
    if not isinstance(w, (QTensor, Q4Tensor)):
        raise ValueError(
            f"static program: {type(n).__name__} #{n.id} expects quantized "
            f"(QTensor / Q4Tensor) weights at "
            f"{path if path is not None else getattr(n, 'w', None)}; "
            "quantize params with core.engine.quantize_params first")
    return w


def _static_eval(program: Program, params, images,
                 eng: EngineConfig, collect: Optional[dict] = None,
                 decode: Optional[_DecodeCtx] = None,
                 chunk: Optional["_ChunkCtx"] = None):
    """The static-mode eval_node closure for one program invocation (the
    counterpart of _dynamic_eval; shared by _execute_static and
    execute_interleaved)."""
    plan = program.plan
    scale_of = plan.out_scale
    rope = _rope_table
    rope_d = _rope_decode_memo(decode.pos) if decode is not None else None
    rope_c = (_rope_decode_memo(jnp.asarray(chunk.start, jnp.int32))
              if chunk is not None else None)

    def out_scale_for(n: OpNode):
        return scale_of[n.id] if plan.emit_int8[n.id] else None

    def _as_scale(nid: int, os):
        """The node's out-scale as an array: the compile-time constant the
        plan precomputed (passes.fold_requant), falling back to a fresh
        conversion only for plans built before scale_arr existed."""
        arr = plan.scale_arr.get(nid)
        return arr if arr is not None else jnp.asarray(os, jnp.float32)

    def _q_or_raw(r, n: OpNode, os):
        """A float-domain MISC op's requant epilogue: int8 when the plan
        carries the edge int8 (all consumers are GEMM engines), f32 else."""
        if os is None:
            return r
        return QTensor(quantize_static(r, _as_scale(n.id, os)), os)

    def _raw(v):
        return v.dequant() if isinstance(v, QTensor) else v

    def _scaled(v):
        return (v.q, float(v.scale)) if isinstance(v, QTensor) else (v, 1.0)

    def eval_node(n: OpNode, vals: Dict[int, QTensor]):
        os = out_scale_for(n)
        if isinstance(n, InputOp):
            if os is None:
                return images              # token ids pass through raw
            # One static quantization at the boundary; int8 from here on.
            return QTensor(quantize_static(images, _as_scale(n.id, os)), os)
        if isinstance(n, ConvOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            ep = n.epilogue
            res, res_s = None, 1.0
            if ep is not None and ep.add:
                res, res_s = _scaled(vals[n.inputs[-1]])
            fn = ops.first_layer_conv if n.first_layer else ops.conv2d_pe
            r = fn(vals[n.inputs[0]], w, b, n.stride, n.padding, n.act, eng,
                   out_scale=os, epilogue=ep, residual=res, res_scale=res_s)
            return QTensor(r, os)
        if isinstance(n, DwcOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            ep = n.epilogue
            res, res_s = None, 1.0
            if ep is not None and ep.add:
                res, res_s = _scaled(vals[n.inputs[-1]])
            r = ops.dwc2d(vals[n.inputs[0]], w, b, n.stride, n.padding,
                          n.act, eng, out_scale=os, epilogue=ep,
                          residual=res, res_scale=res_s)
            return QTensor(r, os)
        if isinstance(n, AddOp):
            # Mixed domains compose: a CNN residual add sees two int8 edges,
            # an LM residual add sees the f32 stream + the block's int8
            # GEMM output (dequantized by its static scale in this pass).
            a, sa = _scaled(vals[n.inputs[0]])
            b, sb = _scaled(vals[n.inputs[1]])
            r = ops.misc_add(a, b, n.act, eng, sa=sa, sb=sb, out_scale=os)
            return QTensor(r, os) if os is not None else r
        if isinstance(n, PoolOp):
            x = vals[n.inputs[0]]
            if n.pool == "max":
                # Order-preserving on int8: values and scale pass through.
                return QTensor(ref.maxpool2d(x.q, n.kernel, n.stride), os)
            if n.pool == "global":
                # Sum in int32 (like every engine accumulator), then one
                # fused scale+requant epilogue -- no f32 fmap materialized.
                acc = jnp.sum(x.q.astype(jnp.int32), axis=(1, 2))
                px = x.q.shape[1] * x.q.shape[2]
                r = acc.astype(jnp.float32) * (float(x.scale) / px)
                return (QTensor(quantize_static(r, _as_scale(n.id, os)), os)
                        if os is not None else r)
            acc = jax.lax.reduce_window(
                x.q.astype(jnp.int32), 0, jax.lax.add,
                (1, n.kernel, n.kernel, 1), (1, n.stride, n.stride, 1),
                "VALID")
            r = acc.astype(jnp.float32) * (float(x.scale) / n.kernel ** 2)
            return QTensor(quantize_static(r, _as_scale(n.id, os)), os)
        if isinstance(n, ConcatOp):
            parts = []
            for i in n.inputs:
                xi = vals[i]
                if xi.scale == os:            # requant folded into producer
                    parts.append(xi.q)
                else:                         # MISC-side int8->int8 rescale
                    parts.append(_rescale_int8(xi.q, float(xi.scale), os))
            return QTensor(jnp.concatenate(parts, axis=-1), os)
        if isinstance(n, LinearOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            x = vals[n.inputs[0]]
            ep = n.epilogue
            if ep is not None and ep.add:
                res, res_s = _scaled(vals[n.inputs[-1]])
                r = ops.linear_ep(x, w, b, n.act, ep, res, eng,
                                  res_scale=res_s, out_scale=os,
                                  out_dtype=jnp.float32)
            else:
                r = ops.linear(x, w, b, n.act, eng, out_dtype=jnp.float32,
                               out_scale=os)
            return QTensor(r, os) if os is not None else r
        if isinstance(n, LinearGroupOp):
            ws = [_require_qtensor(get_param(params, p), n, p)
                  for p in n.ws]
            bs = [get_param(params, b) for b in n.bs]
            # One launch, tuple value; member edges stay f32 (their
            # consumers -- attention, the gate product -- are float-domain
            # MISC ops, so the views never requantize).
            return ops.linear_group(vals[n.inputs[0]], ws, bs, n.acts, eng,
                                    out_dtype=jnp.float32)
        if isinstance(n, ViewOp):
            return vals[n.inputs[0]][n.index]
        if isinstance(n, EmbedOp):
            return _q_or_raw(_embed_eval(n, _raw(vals[n.inputs[0]]),
                                         params), n, os)
        if isinstance(n, NormOp):
            # f32 norm math on the MISC core; the requant epilogue is what
            # hands the consumer GEMMs their static-int8 activations.
            r = L.rms_norm(_raw(vals[n.inputs[0]]), get_param(params, n.w),
                           n.eps)
            return _q_or_raw(r, n, os)
        if isinstance(n, MulOp):
            r = (_raw(vals[n.inputs[0]]) * _raw(vals[n.inputs[1]])
                 ).astype(jnp.float32)
            return _q_or_raw(r, n, os)
        if isinstance(n, AttnOp):
            if n.mode == "update":
                r = _attn_update_eval(n, _raw(vals[n.inputs[0]]),
                                      _raw(vals[n.inputs[1]]),
                                      _raw(vals[n.inputs[2]]),
                                      rope_d, decode, eng)
            elif n.mode == "chunk":
                r = _attn_chunk_eval(n, _raw(vals[n.inputs[0]]),
                                     _raw(vals[n.inputs[1]]),
                                     _raw(vals[n.inputs[2]]),
                                     rope_c, chunk, eng)
            else:
                r = _attn_eval(n, _raw(vals[n.inputs[0]]),
                               _raw(vals[n.inputs[1]]),
                               _raw(vals[n.inputs[2]]), rope, collect)
            return _q_or_raw(r, n, os)
        if isinstance(n, HeadOp):
            return _head_eval(n, _raw(vals[n.inputs[0]]), params)
        raise TypeError(f"unknown op {type(n).__name__}")

    return eval_node


def _execute_static(program: Program, params, images,
                    eng: EngineConfig, collect: Optional[dict] = None,
                    decode: Optional[_DecodeCtx] = None,
                    chunk: Optional["_ChunkCtx"] = None) -> jax.Array:
    eval_node = _static_eval(program, params, images, eng, collect, decode,
                             chunk)
    out = _run_scheduled(program, eval_node)
    return out.dequant() if isinstance(out, QTensor) else out


def _rescale_int8(q: jax.Array, s_in: float, s_out: float) -> jax.Array:
    """int8 -> int8 rescale without materializing an f32 tensor between
    engines (a MISC-core epilogue in hardware)."""
    r = jnp.clip(jnp.round(q.astype(jnp.float32) * (s_in / s_out)),
                 -127, 127)
    return r.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Fabric-interleaved execution (multi-tenant co-mapping, f-CNNx style)
# ---------------------------------------------------------------------------

def _eval_for(program: Program, params, inputs, eng: EngineConfig,
              collect: Optional[dict] = None,
              decode: Optional[_DecodeCtx] = None):
    if program.static:
        return _static_eval(program, params, inputs, eng, collect, decode)
    return _dynamic_eval(program, params, inputs, eng, collect, decode)


class _Lane:
    """One program's value environment advancing level-by-level under an
    external tick driver (execute_interleaved).  Same wave semantics as
    _run_scheduled: a level's ops read only earlier levels' values, merged
    after the whole wave, with last-consumer release."""

    def __init__(self, program: Program, eval_node):
        self.g = program.graph
        self.eval_node = eval_node
        self.counts = _refcounts(self.g)
        self.vals: Dict[int, object] = {}
        self.waves = tuple(_dispatch_waves(program))

    def step(self, k: int) -> None:
        produced = [(n, self.eval_node(n, self.vals))
                    for n in self.waves[k]]
        for n, v in produced:
            self.vals[n.id] = v
        for n, _ in produced:
            _release(self.vals, self.counts, n, self.g)

    def result(self):
        out = self.vals[self.g.output]
        return out.dequant() if isinstance(out, QTensor) else out


def execute_interleaved(program_a: Program, params_a, inputs_a,
                        program_b: Program, params_b, cache_b,
                        tokens_b, eng_a: EngineConfig,
                        eng_b: Optional[EngineConfig] = None,
                        merged: Optional[MergedSchedule] = None,
                        collect_a: Optional[dict] = None):
    """Run a forward program (lane A: CNN wave or LM prefill) and a
    DecodeStep program (lane B) on ONE fabric tick stream.

    Each merged tick evaluates at most one level of each lane, aligned by
    merge_schedules: a conv-heavy CNN level rides alongside a MISC-heavy
    LM decode level, so the units one tenant leaves idle are filled by the
    other (the f-CNNx co-mapping).  The lanes keep separate value
    environments and share no dataflow, so outputs are bit-identical to
    isolated execution -- what is shared is the dispatch stream (and,
    under jit, the fused per-tick computation).

    Returns (logits_a, logits_b, new_cache_b)."""
    if program_a.kind != "forward":
        raise ValueError(f"lane A must be a forward program, got "
                         f"kind={program_a.kind!r}")
    if program_b.kind != "decode":
        raise ValueError(f"lane B must be a decode program, got "
                         f"kind={program_b.kind!r}")
    if program_a.schedule is None or program_b.schedule is None:
        raise ValueError("execute_interleaved needs scheduled programs "
                         "(compile with scheduled=True)")
    eng_b = eng_b if eng_b is not None else eng_a
    ctx = _DecodeCtx(cache_b)
    lane_a = _Lane(program_a, _eval_for(program_a, params_a, inputs_a,
                                        eng_a, collect=collect_a))
    lane_b = _Lane(program_b, _eval_for(program_b, params_b, tokens_b,
                                        eng_b, decode=ctx))
    if merged is None:
        merged = merge_schedules(program_a.graph, program_a.schedule,
                                 program_b.graph, program_b.schedule,
                                 policy="asap")
    for ia, ib in merged.ticks:
        if ia is not None:
            lane_a.step(ia)
        if ib is not None:
            lane_b.step(ib)
    return lane_a.result(), lane_b.result(), ctx.finish()
