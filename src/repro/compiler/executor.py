"""Engine-program executor: runs a compiled op graph on any backend.

Two execution modes, selected by whether the program carries a QuantPlan:

  * dynamic (plan=None) -- reproduces the historical eager `cnn_forward`
    exactly: every op dispatches through kernels/ops.py with the engine
    config's quant mode, activations round-trip through f32 between ops and
    are re-quantized dynamically per call.  This is the float/training path
    and the "dynamic-f32 pipeline" baseline of the benchmarks.

  * static (plan from passes.fold_requant) -- the paper's dataflow: the
    input image is quantized once with its calibrated scale, every engine
    consumes int8 and emits int8 via its fused requant epilogue, and the
    only f32 tensor materialized is the logits.

Either mode consumes the program's Schedule (compiler/schedule.py) when one
is attached: ops are dispatched level-by-level, and every op of a level is
evaluated against the previous levels' values only -- concurrent-PE
semantics, where a same-level data dependence would fail loudly instead of
silently serializing.  A program without a schedule falls back to the raw
topological node order (bit-identical results either way; the parity suite
pins that).

Backend selection (ref / pallas / XVDPU-analog baseline) stays inside
kernels/ops.py: the same compiled program runs on any EngineConfig.

Compiled dynamic programs are memoized in a bounded ProgramCache
(core/program_cache.py) rather than a raw functools.lru_cache: the same
store type the serving layer keys calibrated programs with, LRU-bounded
instead of unbounded, and its hit/miss counters feed the serving
benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compiler import passes as passes_lib
from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, Graph,
                                  InputOp, LinearOp, OpNode, PoolOp,
                                  build_graph, get_param)
from repro.compiler.passes import QuantPlan, fold_requant
from repro.compiler.schedule import Schedule, level_schedule
from repro.core.config import CNNConfig, EngineConfig
from repro.core.quant import QTensor, quantize_static
from repro.kernels import ops, ref
from repro.core.program_cache import ProgramCache, ProgramKey


@dataclass(frozen=True)
class Program:
    """A compiled engine program: op graph + optional static-int8 plan and
    concurrent-dispatch schedule."""
    graph: Graph
    cfg: CNNConfig
    plan: Optional[QuantPlan] = None
    schedule: Optional[Schedule] = None

    @property
    def static(self) -> bool:
        return self.plan is not None

    def f32_roundtrips(self) -> int:
        """f32 edges between engines (0 for a correct static program)."""
        if self.plan is None:
            return passes_lib.dynamic_roundtrip_count(self.graph)
        return len(passes_lib.f32_roundtrip_edges(self.graph, self.plan))


# The process-wide store for compiled dynamic programs (the eager
# cnn_forward path compiles each config once).  Bounded: a long-running
# trainer or server sweeping many configs no longer grows it without limit.
_DYNAMIC_CACHE_CAPACITY = 64
_dynamic_cache = ProgramCache(capacity=_DYNAMIC_CACHE_CAPACITY)


def program_cache() -> ProgramCache:
    """The executor's dynamic-program store (shared with the serving layer's
    introspection; serving keeps its own cache for calibrated programs)."""
    return _dynamic_cache


def compile_cnn(cfg: CNNConfig,
                scales: Optional[Dict[int, float]] = None,
                scheduled: bool = True) -> Program:
    """Lower a CNNConfig to an engine program.

    Without `scales` the program executes dynamically (eager-equivalent);
    that program is cached per config (CNNConfig is frozen/hashable) in the
    bounded program_cache(), so the eager cnn_forward wrapper builds each
    graph once.  With calibrated per-edge scales the requant-folding pass
    produces the static int8 plan.  `scheduled=False` omits the concurrency
    schedule (sequential raw-order dispatch; the parity tests' baseline).
    """
    if scales is None:
        key = ProgramKey(cfg, None, None,
                         "scheduled" if scheduled else "sequential")
        return _dynamic_cache.get_or_compile(
            key, lambda: _build_program(cfg, None, scheduled))
    return _build_program(cfg, scales, scheduled)


def _build_program(cfg: CNNConfig, scales, scheduled: bool) -> Program:
    g = build_graph(cfg)
    plan = fold_requant(g, scales) if scales is not None else None
    sched = level_schedule(g) if scheduled else None
    return Program(g, cfg, plan, sched)


def execute(program: Program, params, images: jax.Array,
            eng: EngineConfig,
            observer: Optional[Callable[[OpNode, jax.Array], None]] = None
            ) -> jax.Array:
    """Run the program.  images: [N, H, W, C] float.  Returns logits."""
    if program.static:
        return _execute_static(program, params, images, eng)
    return _execute_dynamic(program, params, images, eng, observer)


# ---------------------------------------------------------------------------
# Scheduled dispatch (shared by both modes)
# ---------------------------------------------------------------------------

def _refcounts(g: Graph) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for n in g.nodes:
        for i in n.inputs:
            counts[i] = counts.get(i, 0) + 1
    return counts


def _release(vals: Dict, counts: Dict[int, int], n: OpNode, g: Graph) -> None:
    """Drop activations after their last consumer so eager (un-jitted)
    execution keeps O(live edges) -- not O(all nodes) -- tensors alive,
    matching the replaced hand-written forward's rebinding behavior."""
    for i in n.inputs:
        counts[i] -= 1
        if counts[i] == 0 and i != g.output:
            del vals[i]


def _dispatch_waves(program: Program) -> Iterable[Tuple[OpNode, ...]]:
    """The execution order: schedule levels when present, else one op per
    wave in raw topological order."""
    g = program.graph
    if program.schedule is None:
        for n in g.nodes:
            yield (n,)
    else:
        for level in program.schedule.levels:
            yield tuple(g.nodes[i] for i in level)


def _run_scheduled(program: Program, eval_node, observer=None):
    """Evaluate the program wave-by-wave.  Each wave's ops read only values
    produced by earlier waves (`vals` is merged after the whole wave), so a
    schedule bug that co-levels dependent ops raises KeyError instead of
    silently reading a half-updated environment."""
    g = program.graph
    counts = _refcounts(g)
    vals: Dict[int, object] = {}
    for wave in _dispatch_waves(program):
        produced = [(n, eval_node(n, vals)) for n in wave]
        for n, v in produced:
            vals[n.id] = v
        for n, v in produced:
            if observer is not None:
                observer(n, v)
            _release(vals, counts, n, g)
    return vals[g.output]


# ---------------------------------------------------------------------------
# Dynamic mode (eager-equivalent; also the calibration vehicle)
# ---------------------------------------------------------------------------

def _execute_dynamic(program: Program, params, images, eng: EngineConfig,
                     observer=None) -> jax.Array:

    def eval_node(n: OpNode, vals: Dict[int, jax.Array]) -> jax.Array:
        if isinstance(n, InputOp):
            return images
        if isinstance(n, ConvOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            if n.first_layer:
                v = ops.first_layer_conv(vals[n.inputs[0]], w, b, n.stride,
                                         n.padding, n.act, eng)
                return v.astype(jnp.float32)
            return ops.conv2d_pe(vals[n.inputs[0]], w, b, n.stride,
                                 n.padding, n.act, eng)
        if isinstance(n, DwcOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            return ops.dwc2d(vals[n.inputs[0]], w, b, n.stride, n.padding,
                             n.act, eng)
        if isinstance(n, AddOp):
            return ops.misc_add(vals[n.inputs[0]], vals[n.inputs[1]],
                                n.act, eng)
        if isinstance(n, PoolOp):
            x = vals[n.inputs[0]]
            if n.pool == "global":
                return ref.global_avgpool(x)
            if n.pool == "avg":
                return ops.avgpool2d(x, n.kernel, n.stride, eng)
            return ref.maxpool2d(x, n.kernel, n.stride)
        if isinstance(n, ConcatOp):
            return jnp.concatenate([vals[i] for i in n.inputs], axis=-1)
        if isinstance(n, LinearOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            return ops.linear(vals[n.inputs[0]], w, b, n.act, eng,
                              out_dtype=jnp.float32)
        raise TypeError(f"unknown op {type(n).__name__}")

    return _run_scheduled(program, eval_node, observer)


# ---------------------------------------------------------------------------
# Static mode (calibrated end-to-end int8 dataflow)
# ---------------------------------------------------------------------------

def _require_qtensor(w, n: OpNode):
    if not isinstance(w, QTensor):
        raise ValueError(
            f"static program: {type(n).__name__} #{n.id} expects int8 "
            f"QTensor weights at {n.w}; quantize params with "
            "core.engine.quantize_params first")
    return w


def _execute_static(program: Program, params, images,
                    eng: EngineConfig) -> jax.Array:
    g, plan = program.graph, program.plan
    scale_of = plan.out_scale

    def out_scale_for(n: OpNode):
        return scale_of[n.id] if plan.emit_int8[n.id] else None

    def eval_node(n: OpNode, vals: Dict[int, QTensor]):
        os = out_scale_for(n)
        if isinstance(n, InputOp):
            # One static quantization at the boundary; int8 from here on.
            return QTensor(quantize_static(images, jnp.float32(os)), os)
        if isinstance(n, ConvOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            fn = ops.first_layer_conv if n.first_layer else ops.conv2d_pe
            r = fn(vals[n.inputs[0]], w, b, n.stride, n.padding, n.act, eng,
                   out_scale=os)
            return QTensor(r, os)
        if isinstance(n, DwcOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            r = ops.dwc2d(vals[n.inputs[0]], w, b, n.stride, n.padding,
                          n.act, eng, out_scale=os)
            return QTensor(r, os)
        if isinstance(n, AddOp):
            a, bq = vals[n.inputs[0]], vals[n.inputs[1]]
            r = ops.misc_add(a.q, bq.q, n.act, eng,
                             sa=float(a.scale), sb=float(bq.scale),
                             out_scale=os)
            return QTensor(r, os)
        if isinstance(n, PoolOp):
            x = vals[n.inputs[0]]
            if n.pool == "max":
                # Order-preserving on int8: values and scale pass through.
                return QTensor(ref.maxpool2d(x.q, n.kernel, n.stride), os)
            if n.pool == "global":
                # Sum in int32 (like every engine accumulator), then one
                # fused scale+requant epilogue -- no f32 fmap materialized.
                acc = jnp.sum(x.q.astype(jnp.int32), axis=(1, 2))
                px = x.q.shape[1] * x.q.shape[2]
                r = acc.astype(jnp.float32) * (float(x.scale) / px)
                return (QTensor(quantize_static(r, jnp.float32(os)), os)
                        if os is not None else r)
            acc = jax.lax.reduce_window(
                x.q.astype(jnp.int32), 0, jax.lax.add,
                (1, n.kernel, n.kernel, 1), (1, n.stride, n.stride, 1),
                "VALID")
            r = acc.astype(jnp.float32) * (float(x.scale) / n.kernel ** 2)
            return QTensor(quantize_static(r, jnp.float32(os)), os)
        if isinstance(n, ConcatOp):
            parts = []
            for i in n.inputs:
                xi = vals[i]
                if xi.scale == os:            # requant folded into producer
                    parts.append(xi.q)
                else:                         # MISC-side int8->int8 rescale
                    parts.append(_rescale_int8(xi.q, float(xi.scale), os))
            return QTensor(jnp.concatenate(parts, axis=-1), os)
        if isinstance(n, LinearOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            x = vals[n.inputs[0]]
            r = ops.linear(x, w, b, n.act, eng, out_dtype=jnp.float32,
                           out_scale=os)
            return QTensor(r, os) if os is not None else r
        raise TypeError(f"unknown op {type(n).__name__}")

    out = _run_scheduled(program, eval_node)
    return out.dequant() if isinstance(out, QTensor) else out


def _rescale_int8(q: jax.Array, s_in: float, s_out: float) -> jax.Array:
    """int8 -> int8 rescale without materializing an f32 tensor between
    engines (a MISC-core epilogue in hardware)."""
    r = jnp.clip(jnp.round(q.astype(jnp.float32) * (s_in / s_out)),
                 -127, 127)
    return r.astype(jnp.int8)
