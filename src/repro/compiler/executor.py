"""Engine-program executor: runs a compiled op graph on any backend.

Two execution modes, selected by whether the program carries a QuantPlan:

  * dynamic (plan=None) -- reproduces the historical eager `cnn_forward`
    exactly: every op dispatches through kernels/ops.py with the engine
    config's quant mode, activations round-trip through f32 between ops and
    are re-quantized dynamically per call.  This is the float/training path
    and the "dynamic-f32 pipeline" baseline of the benchmarks.

  * static (plan from passes.fold_requant) -- the paper's dataflow: the
    input image is quantized once with its calibrated scale, every engine
    consumes int8 and emits int8 via its fused requant epilogue, and the
    only f32 tensor materialized is the logits.

Backend selection (ref / pallas / XVDPU-analog baseline) stays inside
kernels/ops.py: the same compiled program runs on any EngineConfig.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.compiler import passes as passes_lib
from repro.compiler.graph import (AddOp, ConcatOp, ConvOp, DwcOp, Graph,
                                  InputOp, LinearOp, OpNode, PoolOp,
                                  build_graph, get_param)
from repro.compiler.passes import QuantPlan, fold_requant
from repro.core.config import CNNConfig, EngineConfig
from repro.core.quant import QTensor, quantize_static
from repro.kernels import ops, ref


@dataclass(frozen=True)
class Program:
    """A compiled engine program: op graph + optional static-int8 plan."""
    graph: Graph
    cfg: CNNConfig
    plan: Optional[QuantPlan] = None

    @property
    def static(self) -> bool:
        return self.plan is not None

    def f32_roundtrips(self) -> int:
        """f32 edges between engines (0 for a correct static program)."""
        if self.plan is None:
            return passes_lib.dynamic_roundtrip_count(self.graph)
        return len(passes_lib.f32_roundtrip_edges(self.graph, self.plan))


@functools.lru_cache(maxsize=None)
def _dynamic_program(cfg: CNNConfig) -> Program:
    return Program(build_graph(cfg), cfg, None)


def compile_cnn(cfg: CNNConfig,
                scales: Optional[Dict[int, float]] = None) -> Program:
    """Lower a CNNConfig to an engine program.

    Without `scales` the program executes dynamically (eager-equivalent);
    that program is cached per config (CNNConfig is frozen/hashable), so
    the eager cnn_forward wrapper builds each graph once.  With calibrated
    per-edge scales the requant-folding pass produces the static int8 plan.
    """
    if scales is None:
        return _dynamic_program(cfg)
    g = build_graph(cfg)
    return Program(g, cfg, fold_requant(g, scales))


def execute(program: Program, params, images: jax.Array,
            eng: EngineConfig,
            observer: Optional[Callable[[OpNode, jax.Array], None]] = None
            ) -> jax.Array:
    """Run the program.  images: [N, H, W, C] float.  Returns logits."""
    if program.static:
        return _execute_static(program, params, images, eng)
    return _execute_dynamic(program, params, images, eng, observer)


# ---------------------------------------------------------------------------
# Dynamic mode (eager-equivalent; also the calibration vehicle)
# ---------------------------------------------------------------------------

def _refcounts(g: Graph) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for n in g.nodes:
        for i in n.inputs:
            counts[i] = counts.get(i, 0) + 1
    return counts


def _release(vals: Dict, counts: Dict[int, int], n: OpNode, g: Graph) -> None:
    """Drop activations after their last consumer so eager (un-jitted)
    execution keeps O(live edges) -- not O(all nodes) -- tensors alive,
    matching the replaced hand-written forward's rebinding behavior."""
    for i in n.inputs:
        counts[i] -= 1
        if counts[i] == 0 and i != g.output:
            del vals[i]


def _execute_dynamic(program: Program, params, images, eng: EngineConfig,
                     observer=None) -> jax.Array:
    g = program.graph
    counts = _refcounts(g)
    vals: Dict[int, jax.Array] = {}
    for n in g.nodes:
        if isinstance(n, InputOp):
            v = images
        elif isinstance(n, ConvOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            if n.first_layer:
                v = ops.first_layer_conv(vals[n.inputs[0]], w, b, n.stride,
                                         n.padding, n.act, eng)
                v = v.astype(jnp.float32)
            else:
                v = ops.conv2d_pe(vals[n.inputs[0]], w, b, n.stride,
                                  n.padding, n.act, eng)
        elif isinstance(n, DwcOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            v = ops.dwc2d(vals[n.inputs[0]], w, b, n.stride, n.padding,
                          n.act, eng)
        elif isinstance(n, AddOp):
            v = ops.misc_add(vals[n.inputs[0]], vals[n.inputs[1]], n.act, eng)
        elif isinstance(n, PoolOp):
            x = vals[n.inputs[0]]
            if n.pool == "global":
                v = ref.global_avgpool(x)
            elif n.pool == "avg":
                v = ops.avgpool2d(x, n.kernel, n.stride, eng)
            else:
                v = ref.maxpool2d(x, n.kernel, n.stride)
        elif isinstance(n, ConcatOp):
            v = jnp.concatenate([vals[i] for i in n.inputs], axis=-1)
        elif isinstance(n, LinearOp):
            w, b = get_param(params, n.w), get_param(params, n.b)
            v = ops.linear(vals[n.inputs[0]], w, b, n.act, eng,
                           out_dtype=jnp.float32)
        else:
            raise TypeError(f"unknown op {type(n).__name__}")
        vals[n.id] = v
        if observer is not None:
            observer(n, v)
        _release(vals, counts, n, g)
    return vals[g.output]


# ---------------------------------------------------------------------------
# Static mode (calibrated end-to-end int8 dataflow)
# ---------------------------------------------------------------------------

def _require_qtensor(w, n: OpNode):
    if not isinstance(w, QTensor):
        raise ValueError(
            f"static program: {type(n).__name__} #{n.id} expects int8 "
            f"QTensor weights at {n.w}; quantize params with "
            "core.engine.quantize_params first")
    return w


def _execute_static(program: Program, params, images,
                    eng: EngineConfig) -> jax.Array:
    g, plan = program.graph, program.plan
    scale_of = plan.out_scale
    counts = _refcounts(g)
    vals: Dict[int, QTensor] = {}

    def out_scale_for(n: OpNode):
        return scale_of[n.id] if plan.emit_int8[n.id] else None

    for n in g.nodes:
        os = out_scale_for(n)
        if isinstance(n, InputOp):
            # One static quantization at the boundary; int8 from here on.
            v = QTensor(quantize_static(images, jnp.float32(os)), os)
        elif isinstance(n, ConvOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            fn = ops.first_layer_conv if n.first_layer else ops.conv2d_pe
            r = fn(vals[n.inputs[0]], w, b, n.stride, n.padding, n.act, eng,
                   out_scale=os)
            v = QTensor(r, os)
        elif isinstance(n, DwcOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            r = ops.dwc2d(vals[n.inputs[0]], w, b, n.stride, n.padding,
                          n.act, eng, out_scale=os)
            v = QTensor(r, os)
        elif isinstance(n, AddOp):
            a, bq = vals[n.inputs[0]], vals[n.inputs[1]]
            r = ops.misc_add(a.q, bq.q, n.act, eng,
                             sa=float(a.scale), sb=float(bq.scale),
                             out_scale=os)
            v = QTensor(r, os)
        elif isinstance(n, PoolOp):
            x = vals[n.inputs[0]]
            if n.pool == "max":
                # Order-preserving on int8: values and scale pass through.
                v = QTensor(ref.maxpool2d(x.q, n.kernel, n.stride), os)
            elif n.pool == "global":
                # Sum in int32 (like every engine accumulator), then one
                # fused scale+requant epilogue -- no f32 fmap materialized.
                acc = jnp.sum(x.q.astype(jnp.int32), axis=(1, 2))
                px = x.q.shape[1] * x.q.shape[2]
                r = acc.astype(jnp.float32) * (float(x.scale) / px)
                v = (QTensor(quantize_static(r, jnp.float32(os)), os)
                     if os is not None else r)
            else:
                acc = jax.lax.reduce_window(
                    x.q.astype(jnp.int32), 0, jax.lax.add,
                    (1, n.kernel, n.kernel, 1), (1, n.stride, n.stride, 1),
                    "VALID")
                r = acc.astype(jnp.float32) * (float(x.scale) / n.kernel ** 2)
                v = QTensor(quantize_static(r, jnp.float32(os)), os)
        elif isinstance(n, ConcatOp):
            parts = []
            for i in n.inputs:
                xi = vals[i]
                if xi.scale == os:            # requant folded into producer
                    parts.append(xi.q)
                else:                         # MISC-side int8->int8 rescale
                    parts.append(_rescale_int8(xi.q, float(xi.scale), os))
            v = QTensor(jnp.concatenate(parts, axis=-1), os)
        elif isinstance(n, LinearOp):
            w = _require_qtensor(get_param(params, n.w), n)
            b = get_param(params, n.b)
            x = vals[n.inputs[0]]
            r = ops.linear(x, w, b, n.act, eng, out_dtype=jnp.float32,
                           out_scale=os)
            v = QTensor(r, os) if os is not None else r
        else:
            raise TypeError(f"unknown op {type(n).__name__}")
        vals[n.id] = v
        _release(vals, counts, n, g)

    out = vals[g.output]
    return out.dequant() if isinstance(out, QTensor) else out


def _rescale_int8(q: jax.Array, s_in: float, s_out: float) -> jax.Array:
    """int8 -> int8 rescale without materializing an f32 tensor between
    engines (a MISC-core epilogue in hardware)."""
    r = jnp.clip(jnp.round(q.astype(jnp.float32) * (s_in / s_out)),
                 -127, 127)
    return r.astype(jnp.int8)
