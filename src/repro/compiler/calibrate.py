"""Calibration pass: record per-edge activation scales from real batches.

The Vitis-AI step of the paper's flow (Section III-A): run representative
inputs through the float model and derive a static symmetric int8 scale for
every activation edge.  We reuse core.quant.Calibrator (running absmax) and
observe every graph edge by executing the program in dynamic float mode with
an observer hook -- so the recorded ranges are exactly the tensors the
engines will carry.

Scales are returned as plain Python floats keyed by node id: they become
compile-time constants of the static program (closure constants under jit,
`functools.partial` statics inside the Pallas epilogues), never traced
values.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax

from repro.compiler import executor as ex
from repro.compiler.graph import Graph
from repro.core.config import CNNConfig, EngineConfig
from repro.core.quant import Calibrator


def calibrate(graph: Graph, params, batches: Iterable[jax.Array],
              cfg: CNNConfig,
              eng: Optional[EngineConfig] = None) -> Dict[int, float]:
    """Run `batches` (each [N, H, W, C] float) through the float ref path and
    return {node_id: activation scale}.

    `params` must be the FLOAT parameter tree: calibration measures the
    ranges quantized inference must reproduce, so it runs before (and
    independently of) weight quantization.
    """
    eng = eng or EngineConfig(quant="none", backend="ref")
    if eng.quant != "none":
        raise ValueError("calibration runs on the float path (quant='none')")
    cal = Calibrator()
    prog = ex.Program(graph, cfg, None)

    def observe(node, value):
        cal.observe(str(node.id), value)

    ran = False
    for images in batches:
        ran = True
        ex.execute(prog, params, images, eng, observer=observe)
    if not ran:
        raise ValueError("calibration needs at least one batch")
    return {int(k): float(v) for k, v in cal.scales().items()}
