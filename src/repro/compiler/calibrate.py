"""Calibration pass: record per-edge activation scales from real batches.

The Vitis-AI step of the paper's flow (Section III-A): run representative
inputs through the float model and derive a static symmetric int8 scale for
every activation edge.  We observe every graph edge by executing the program
in dynamic float mode with an observer hook -- so the recorded ranges are
exactly the tensors the engines will carry.  The same pass calibrates CNN
programs (image batches) and LM prefill programs (token batches): the
observer walks whatever graph the frontend lowered.

Two calibrators, selected by `method`:

  * "absmax" (default) -- running max |x| over all batches
    (core.quant.Calibrator); the historical Vitis-AI-style choice.
  * "pXX.X" (e.g. "p99.9") -- percentile of |x| over all observed elements,
    via a streaming power-of-two-rescaling histogram.  Robust to activation
    outliers (one huge element no longer wastes the whole int8 range), at
    the cost of clipping the tail.

`granularity` selects the scale shape per edge:

  * "per_tensor" (default) -- one scale per activation edge.
  * "per_channel" -- one scale per last-dim channel per edge (absmax only).
    The requant-folding pass keeps the vector only on edges the engines can
    actually carry per-channel (channelwise DWC consumers); every other
    edge collapses to the channel max, i.e. exactly the per-tensor scale.

Both method and granularity are part of the serving calibration-id, so
ProgramCache entries for different calibrators never collide.

Scales are returned as plain Python floats keyed by node id: they become
compile-time constants of the static program (closure constants under jit,
`functools.partial` statics inside the Pallas epilogues), never traced
values.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax
import numpy as np

from repro.compiler import executor as ex
from repro.compiler.graph import Graph
from repro.core.config import EngineConfig
from repro.core.quant import INT8_MAX, Calibrator

_MIN_SCALE = 1e-8


class PercentileCalibrator:
    """Streaming |x| percentile over batches (per-tensor, like Calibrator).

    Keeps a fixed-bin histogram per edge; when a batch exceeds the current
    range the histogram is rescaled by a power of two (bins merged in pairs),
    so memory stays O(bins) however many batches stream through.
    """

    def __init__(self, q: float = 99.9, bins: int = 2048):
        if not 0.0 < q <= 100.0:
            raise ValueError(f"percentile {q} out of (0, 100]")
        if bins < 2 or bins % 2:
            raise ValueError(f"bins must be even and >= 2 "
                             f"(the range rescale merges bin pairs), got {bins}")
        self.q = q
        self.bins = bins
        self._hist: Dict[str, np.ndarray] = {}
        self._range: Dict[str, float] = {}

    def observe(self, name: str, x) -> None:
        a = np.abs(np.asarray(x, dtype=np.float32)).ravel()
        amax = float(a.max()) if a.size else 0.0
        rng = self._range.get(name, 0.0)
        hist = self._hist.get(name)
        if hist is None:
            hist = np.zeros(self.bins, np.int64)
            rng = max(amax, _MIN_SCALE)
        while amax > rng:                     # power-of-two rescale
            hist = hist.reshape(self.bins // 2, 2).sum(axis=1)
            hist = np.concatenate([hist, np.zeros(self.bins // 2, np.int64)])
            rng *= 2.0
        hist += np.histogram(a, bins=self.bins, range=(0.0, rng))[0]
        self._hist[name] = hist
        self._range[name] = rng

    def scales(self) -> dict:
        out = {}
        for name, hist in self._hist.items():
            total = hist.sum()
            cum = np.cumsum(hist)
            idx = int(np.searchsorted(cum, self.q / 100.0 * total))
            idx = min(idx, self.bins - 1)
            amax = (idx + 1) / self.bins * self._range[name]
            out[name] = max(amax / INT8_MAX, _MIN_SCALE)
        return out


class ChannelCalibrator:
    """Per-channel (last-dim) running absmax over batches.

    The per-channel twin of core.quant.Calibrator: one |x| max per channel
    of every observed edge.  scales() returns a TUPLE of floats per edge
    (compile-time constants, hashable into program metadata); the requant
    pass decides which edges keep the vector and which collapse to max().
    """

    def __init__(self):
        self.amax: Dict[str, np.ndarray] = {}

    def observe(self, name: str, x) -> None:
        a = np.abs(np.asarray(x, dtype=np.float32))
        if a.ndim == 0:
            a = a.reshape(1, 1)
        ch = a.reshape(-1, a.shape[-1]).max(axis=0)
        prev = self.amax.get(name)
        self.amax[name] = ch if prev is None else np.maximum(prev, ch)

    def scales(self) -> dict:
        return {name: tuple(max(float(v) / INT8_MAX, _MIN_SCALE)
                            for v in a)
                for name, a in self.amax.items()}


def make_calibrator(method: str, granularity: str = "per_tensor"):
    """"absmax" -> running-absmax; "pXX.X" -> percentile calibrator.
    granularity="per_channel" selects the per-channel absmax collector
    (the streaming percentile histogram is per-tensor only)."""
    if granularity not in ("per_tensor", "per_channel"):
        raise ValueError(f"unknown granularity {granularity!r} "
                         "(want 'per_tensor' or 'per_channel')")
    if granularity == "per_channel":
        if method != "absmax":
            raise ValueError(
                "per-channel calibration requires method='absmax' "
                f"(per-channel streaming percentiles not supported, "
                f"got {method!r})")
        return ChannelCalibrator()
    if method == "absmax":
        return Calibrator()
    if method.startswith("p"):
        return PercentileCalibrator(q=float(method[1:]))
    raise ValueError(f"unknown calibration method {method!r} "
                     "(want 'absmax' or e.g. 'p99.9')")


def calibrate(graph: Graph, params, batches: Iterable[jax.Array],
              cfg,
              eng: Optional[EngineConfig] = None,
              method: str = "absmax",
              granularity: str = "per_tensor") -> Dict[int, object]:
    """Run `batches` through the float ref path and return
    {node_id: activation scale}.  Batches are whatever the graph's InputOp
    consumes: [N, H, W, C] images for a CNN graph, [B, L] token ids for an
    LM prefill graph.  Scale values are floats (per-tensor) or tuples of
    per-channel floats (granularity="per_channel").

    `params` must be the FLOAT parameter tree: calibration measures the
    ranges quantized inference must reproduce, so it runs before (and
    independently of) weight quantization.
    """
    eng = eng or EngineConfig(quant="none", backend="ref")
    if eng.quant != "none":
        raise ValueError("calibration runs on the float path (quant='none')")
    cal = make_calibrator(method, granularity)
    prog = ex.Program(graph, cfg, None)

    def observe(node, value):
        cal.observe(str(node.id), value)

    ran = False
    for batch in batches:
        ran = True
        ex.execute(prog, params, batch, eng, observer=observe)
    if not ran:
        raise ValueError("calibration needs at least one batch")
    return {int(k): (v if isinstance(v, tuple) else float(v))
            for k, v in cal.scales().items()}
