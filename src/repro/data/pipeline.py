"""Deterministic synthetic-token data pipeline with restart semantics.

A real deployment would stream from a tokenized corpus; here the pipeline is
a seeded generator so that (a) training runs are reproducible, (b) restart
from a checkpoint resumes the exact stream position (skip-restore is O(1):
the batch for step k is a pure function of (seed, k)), and (c) every host in
a multi-host launch can produce exactly its own shard of the global batch
without coordination (shard-aware addressing).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.core.config import ArchConfig, ShapeConfig


@dataclass
class PipelineConfig:
    seed: int = 0
    prefetch: int = 2
    host_index: int = 0
    host_count: int = 1


class SyntheticTokens:
    """Batch for step k = f(seed, k).  Mildly structured (zipf-ish) tokens so
    CE losses are non-degenerate."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cfg: PipelineConfig = PipelineConfig()):
        self.arch, self.shape, self.cfg = arch, shape, cfg
        assert shape.global_batch % cfg.host_count == 0
        self.local_batch = shape.global_batch // cfg.host_count

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_index]))
        b, l = self.local_batch, self.shape.seq_len
        v = self.arch.vocab_size
        # zipf-ish marginal over a capped alphabet
        alpha = rng.zipf(1.3, size=(b, l + 1))
        tokens = (alpha % v).astype(np.int32)
        batch = {"tokens": tokens[:, :l], "labels": tokens[:, 1:]}
        d = self.arch.d_model
        if self.arch.family == "vlm":
            batch = {
                "embeds": rng.standard_normal((b, l, d)).astype(np.float32) * 0.02,
                "positions": np.broadcast_to(
                    np.arange(l, dtype=np.int32)[None, :, None], (b, l, 3)).copy(),
                "labels": tokens[:, 1:],
            }
        elif self.arch.family == "audio":
            batch["enc_embeds"] = rng.standard_normal(
                (b, self.arch.encoder_seq, d)).astype(np.float32) * 0.02
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch with restart-at-step support."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 prefetch: int = 2):
        self.source = source
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch_at(s), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __next__(self) -> dict:
        batch = self.q.get()
        self.step += 1
        return batch

    def close(self):
        self._stop.set()
