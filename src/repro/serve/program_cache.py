"""Serving-facing alias of the program store (see core/program_cache.py).

The implementation lives in repro.core so the compiler's executor can
memoize dynamic programs without importing the serving package (keeping
compiler -> core one-way); this module is the serving layer's canonical
import path for it.
"""
from repro.core.program_cache import CacheStats, ProgramCache, ProgramKey

__all__ = ["CacheStats", "ProgramCache", "ProgramKey"]
