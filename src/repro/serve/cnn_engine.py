"""CNN serving engine: cached programs + continuous wave batching +
concurrent PEs.

The CNN instance of the shared program-serving pipeline (serve/base.py);
the LM `ServeEngine` (serve/engine.py) rides the same base -- and the same
SlotScheduler -- for transformer prefill + decode.  One engine serves many
registered CNNs on one fabric (the f-CNNx setting):

  * compile  -- each (model, engine, calibration) triple lowers once to a
    static-int8 (or dynamic) engine program, epilogue-FUSED by default:
    conv/dwc -> {residual add, pool} chains execute as single launches
    (passes.fuse_epilogues), so a served wave dispatches ~25% fewer
    kernels per ResNet-style image with bit-identical logits;
  * cache    -- programs live in a keyed LRU ProgramCache, so a request
    trace that revisits models never re-traces or re-calibrates;
  * batch    -- incoming single-image requests queue in the shared
    SlotScheduler keyed by INPUT SHAPE, not by model: models with identical
    shapes draw slots from one queue, so a tail wave packs requests from
    several models into one buffer.  `pump()` dispatches only FULL waves
    and leaves partial waves queued for later arrivals to top up
    (continuous batching); `flush()` drains, padding the final partial
    wave per shape (the only place pad slots are charged);
  * schedule -- the programs carry the level schedule from
    compiler/schedule.py (ASAP or ALAP), so execution dispatches
    independent ops (a DWC branch next to a Conv branch, MISC alongside
    Conv) per concurrent wave;
  * fold     -- the first time a model's program is bound, its weight
    layout transforms (im2col reshape, DWC lane padding) are constant-
    folded into the param tree (passes.fold_weight_layouts), so traced
    programs stop re-laying-out weights per call;
  * shard    -- with `mesh=` the engine serves data-parallel across the
    mesh (serve/mesh_exec.py): the physical wave grows to one wave_size
    slot pool PER data replica, the buffer shards over the batch axis
    (weights replicate), and the SlotScheduler's locality-aware refill
    packs each model's requests into its sticky replica's pool first.
    Sharded logits are bit-identical to single-device (int8 GEMMs
    accumulate in int32, so replica-local rows are exact);
  * async    -- dispatch launches every wave and keeps logits as device
    arrays in flight; the host syncs (one np.asarray per wave-model
    execution) only at the response edges of pump()/flush()/infer(), so
    assembling wave N+1 overlaps the device executing wave N.

A multi-model wave executes the shared buffer once per distinct model in
it and each request reads its own slot's logits (CNN programs are
batch-row independent, so foreign slots cannot perturb a request's
output -- the wave parity test pins that).  `wave_stats.waves` counts
physical buffers; `program_execs` counts program runs.

Usage (examples/serve_cnn_int8.py is the runnable version):

    engine = CNNServeEngine(eng_lib.paper_engine(), wave_size=4)
    engine.register(cfg, params, calib_batches=[batch])
    for img in images:
        engine.submit(cfg.name, img)
        engine.pump()                # dispatch full waves only
    logits = engine.flush()          # drain; per-request, submission order
    print(engine.stats())            # cache hit-rate, wave fill-rate
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.compiler.executor import Program
from repro.core import engine as eng_lib
from repro.core.config import CNNConfig, EngineConfig
from repro.serve.base import (ProgramServeBase, SlotScheduler,
                              calibration_digest)
from repro.serve.program_cache import ProgramCache

__all__ = ["CNNServeEngine", "calibration_digest"]


@dataclasses.dataclass
class _Model:
    cfg: CNNConfig
    params: object                    # float tree (calibration input)
    qparams: object                   # engine-quantized tree (execution)
    calib_batches: Optional[List[jax.Array]]
    calib_id: Optional[str]
    calibrator: str = "absmax"
    granularity: str = "per_tensor"
    folded: Optional[Tuple[Program, object]] = None   # layout-folded qparams


@dataclasses.dataclass
class WaveStats:
    requests: int = 0
    waves: int = 0                    # physical wave buffers dispatched
    padded: int = 0                   # empty slots across drained waves
    program_execs: int = 0            # program runs (>= waves: multi-model
                                      # waves run once per distinct model)
    refilled_waves: int = 0           # waves topped up across pump epochs

    @property
    def occupancy(self) -> float:
        slots = self.requests + self.padded
        return self.requests / slots if slots else 0.0

    # fill-rate over physical buffers: the continuous-batching metric the
    # serving benchmark compares against the pad-and-mask baseline
    fill_rate = occupancy


class CNNServeEngine(ProgramServeBase):
    """Serve registered CNNs as cached, batched, scheduled engine programs."""

    def __init__(self, eng: EngineConfig, wave_size: int = 4,
                 cache_capacity: int = 8, scheduled: bool = True,
                 cache: Optional[ProgramCache] = None,
                 schedule_policy: str = "asap", mesh=None):
        if wave_size < 1:
            raise ValueError("wave_size must be >= 1")
        super().__init__(eng, cache_capacity=cache_capacity,
                         scheduled=scheduled, cache=cache,
                         schedule_policy=schedule_policy, mesh=mesh)
        # wave_size is PER-DEVICE slots; with a mesh the physical wave is
        # one slot pool per data replica and the buffer shards over the
        # batch axis, so each replica executes exactly its own pool's rows
        self.wave_size = wave_size
        pools = self.mexec.n_data if self.mexec is not None else 1
        self.wave_stats = WaveStats()
        self.execs_by_model: Dict[str, int] = {}
        self._models: Dict[str, _Model] = {}
        self._sched = SlotScheduler(wave_size, pools=pools)

    @property
    def wave_rows(self) -> int:
        """Rows per physical wave buffer (= wave_size x data replicas)."""
        return self._sched.wave_slots

    # -- model registry ------------------------------------------------------

    def register(self, cfg: CNNConfig, params,
                 calib_batches: Optional[Sequence[jax.Array]] = None,
                 calib_id: Optional[str] = None,
                 calibrator: str = "absmax",
                 granularity: str = "per_tensor") -> str:
        """Register a model under cfg.name.  `params` is the FLOAT tree;
        weights are engine-quantized here, and `calib_batches` (when given
        and the engine is quantized) select the static-int8 program under
        the chosen `calibrator` ("absmax" or a percentile like "p99.9")
        and `granularity` ("per_tensor", or "per_channel" to keep channel
        scale vectors on the DWC-consumed edges) -- both are part of the
        calibration-id, so no two settings share a cache entry.  The
        program itself compiles lazily on first request."""
        batches = list(calib_batches) if calib_batches is not None else None
        if self.eng.quant == "none":
            batches = None            # float fabric: dynamic program only
        if batches is not None and calib_id is None:
            calib_id = calibration_digest(batches, params, calibrator,
                                          granularity)
        self._models[cfg.name] = _Model(
            cfg=cfg, params=params,
            qparams=eng_lib.quantize_params(params, self.eng),
            calib_batches=batches, calib_id=calib_id, calibrator=calibrator,
            granularity=granularity)
        return cfg.name

    def models(self) -> List[str]:
        return sorted(self._models)

    # -- program cache -------------------------------------------------------

    def _key(self, m: _Model):
        return self._program_key(m.cfg, m.calib_id)

    def _compile(self, m: _Model) -> Program:
        if m.calib_batches is None:
            return compiler.compile_cnn(m.cfg, scheduled=self.scheduled,
                                        policy=self.schedule_policy)
        return compiler.compile_calibrated(
            m.cfg, m.params, m.calib_batches, scheduled=self.scheduled,
            policy=self.schedule_policy, method=m.calibrator,
            granularity=m.granularity)

    def program_for(self, name: str) -> Program:
        """The model's compiled program: cache hit, or compile-and-insert."""
        m = self._models[name]
        return self._cached_program(self._key(m), lambda: self._compile(m))

    def _executor_for(self, name: str):
        """A jitted batched execute for the model's program.  The wave shape
        is fixed ([wave_size, H, W, C]), so each cached program traces once;
        eviction drops the trace alongside the program."""
        m = self._models[name]
        program = self.program_for(name)
        run = self._jitted_for(
            self._key(m), program,
            lambda prog: jax.jit(
                lambda p, im: compiler.execute(prog, p, im, self.eng)))
        return run, self._exec_params(m, program)

    def _exec_params(self, m: _Model, program: Program):
        """The model's execution param tree with weight layouts folded at
        compile time (im2col reshape, DWC lane padding) -- computed once per
        (model, program) binding."""
        if m.folded is None or m.folded[0] is not program:
            qp = compiler.fold_weight_layouts(program.graph, m.qparams)
            if self.mexec is not None:
                # data-parallel waves: weights replicate across the mesh
                # once per (model, program) binding, not per dispatch
                qp = self.mexec.replicate(qp)
            m.folded = (program, qp)
        return m.folded[1]

    # -- request batching ----------------------------------------------------

    def submit(self, name: str, image: np.ndarray) -> int:
        """Queue one [H, W, C] image request; returns its ticket: the key
        of its logits in a pump() result dict, and the SUBMISSION-ORDER
        rank within a flush() result list (flush returns only requests
        still queued when it runs, ordered by ticket -- use infer() or
        pump() when you need ticket-keyed results)."""
        if name not in self._models:
            raise KeyError(f"model {name!r} not registered "
                           f"(have {self.models()})")
        image = np.asarray(image)
        cfg = self._models[name].cfg
        want = (cfg.input_hw, cfg.input_hw, cfg.input_ch)
        if image.shape != want:
            # reject at submission: a bad request must not reach dispatch,
            # where the queue is already drained and a shape error would
            # drop every other pending request with it
            raise ValueError(f"submit() takes one {want} image per "
                             f"{name!r} request, got shape {image.shape}")
        # slot groups are keyed by shape: same-shape models share waves;
        # the model name is the pool-locality key on multi-device meshes
        ticket = self._sched.submit(want, (name, image), affinity=name)
        self.latency.submitted(ticket)
        return ticket

    def pending(self) -> int:
        return self._sched.pending()

    def pump(self) -> Dict[int, np.ndarray]:
        """Dispatch every FULL wave and return its results; partial waves
        stay queued for later arrivals to refill (continuous batching)."""
        return self._dispatch(force=False)

    def flush(self) -> List[np.ndarray]:
        """Run every queued request and return logits in submission order.

        Full waves dispatch as-is; each shape group's final partial wave is
        drained with zero-padded (masked-away) slots -- the pad-and-mask
        cost continuous pump() avoids."""
        results = self._dispatch(force=True)
        return [results[t] for t in sorted(results)]

    def _dispatch(self, force: bool) -> Dict[int, np.ndarray]:
        """Async dispatch with response-edge sync: every wave-model
        execution is launched first (results stay device arrays in
        flight, so host-side assembly of the next wave buffer overlaps
        device compute), then ONE np.asarray per execution materializes
        the logits at the response edge."""
        in_flight: List[Tuple[object, List[Tuple[int, int]]]] = []
        for group in self._sched.groups():
            while True:
                wave = self._sched.take_wave(group, force=force)
                if wave is None:
                    break
                self._run_wave(wave, group, in_flight)
        self._sched.next_epoch()
        results: Dict[int, np.ndarray] = {}
        for dev_logits, slots in in_flight:      # response edge: host sync
            logits = np.asarray(dev_logits)
            for slot, ticket in slots:
                results[ticket] = logits[slot]   # mask foreign/pad slots
                self.latency.completed(ticket)
        return results

    def _run_wave(self, wave, shape, in_flight) -> None:
        """Launch one wave buffer.  Slots may belong to different models
        (same shape): the buffer runs once per distinct model and each
        ticket reads its own slot's row.  Appends (device logits, slots)
        per execution without blocking; _dispatch materializes."""
        buf = np.zeros((self.wave_rows,) + shape, np.float32)
        slots_of: Dict[str, List[Tuple[int, int]]] = {}
        for slot, (ticket, (name, img)) in enumerate(wave):
            buf[slot] = img
            slots_of.setdefault(name, []).append((slot, ticket))
        jbuf = jnp.asarray(buf)
        if self.mexec is not None:
            jbuf = self.mexec.place_wave(jbuf)   # rows shard over replicas
        for name, slots in slots_of.items():
            run, qparams = self._executor_for(name)
            in_flight.append((run(qparams, jbuf), slots))
            self.wave_stats.program_execs += 1
            self.execs_by_model[name] = self.execs_by_model.get(name, 0) + 1
        self.wave_stats.requests += len(wave)
        self.wave_stats.waves += 1
        self.wave_stats.padded += self.wave_rows - len(wave)

    def infer(self, name: str, images) -> np.ndarray:
        """Convenience: submit a [N, H, W, C] batch as N requests and flush.
        Returns logits [N, num_classes]."""
        images = np.asarray(images)
        tickets = [self.submit(name, img) for img in images]
        results = self._dispatch(force=True)
        return np.stack([results[t] for t in tickets])

    # -- stats ---------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        out = {"models": len(self._models)}
        out.update(self.cache_stats())
        # launch accounting of the bound (epilogue-fused) programs: peek so
        # monitoring never perturbs cache recency or compiles anything
        fused: Dict[str, Dict[str, int]] = {}
        for name, m in self._models.items():
            prog = self.cache.peek(self._key(m))
            if prog is not None:
                fs = compiler.fusion_stats(prog.graph)
                fused[name] = {"launches": fs["launches"],
                               "fused_ops": fs["fused_ops"]}
        out["fused_programs"] = fused
        self.wave_stats.refilled_waves = self._sched.stats.refilled_waves
        out.update({
            "waves": self.wave_stats.waves,
            "requests": self.wave_stats.requests,
            "padded_slots": self.wave_stats.padded,
            "wave_occupancy": self.wave_stats.occupancy,
            "wave_fill_rate": self.wave_stats.occupancy,
            "program_execs": self.wave_stats.program_execs,
            "execs_by_model": dict(self.execs_by_model),
            "refilled_waves": self._sched.stats.refilled_waves,
            "queued": self._sched.pending(),
            "latency_ms": self.latency.percentiles(),
        })
        if self.mexec is not None:
            out["mesh"] = self.mexec.describe()
            out["wave_rows"] = self.wave_rows
            out["pool_locality_rate"] = self._sched.stats.locality_rate
            out["pool_locality_hits"] = self._sched.stats.locality_hits
        return out
