"""Serving layer: continuous-batching request engines over compiled
programs.

Both engines ride the shared program-serving base (serve/base.py):
compile -> keyed ProgramCache -> jit-once -> scheduled dispatch, with one
slot-based request queue (`SlotScheduler`) feeding the fabric -- the LM
engine refills finished decode slots from it between bursts, the CNN
engine refills partial same-shape waves from it across arrivals.

Import the submodules directly (this initializer stays empty so importing
one engine never drags in the other's model stack):

    from repro.serve.engine import ServeEngine            # LM decode slots
    from repro.serve.cnn_engine import CNNServeEngine     # CNN shape waves
    from repro.serve.base import ProgramServeBase, SlotScheduler
    from repro.serve.program_cache import ProgramCache
"""
