"""Serving layer: batched request engines over compiled programs.

Both engines ride the shared program-serving base (serve/base.py):
compile -> keyed ProgramCache -> jit-once -> scheduled dispatch.

Import the submodules directly (this initializer stays empty so importing
one engine never drags in the other's model stack):

    from repro.serve.engine import ServeEngine            # LM slot scheduler
    from repro.serve.cnn_engine import CNNServeEngine     # CNN wave scheduler
    from repro.serve.base import ProgramServeBase         # shared pipeline
    from repro.serve.program_cache import ProgramCache
"""
