"""Serving layer: batched request engines over compiled programs.

Import the submodules directly (this initializer stays empty so importing
one engine never drags in the other's model stack):

    from repro.serve.engine import ServeEngine            # LM slot scheduler
    from repro.serve.cnn_engine import CNNServeEngine     # CNN wave scheduler
    from repro.serve.program_cache import ProgramCache
"""
