"""KV-cache block allocator: admission by actual occupancy, not envelopes.

The dense serving cache reserves `max_seq` positions per slot regardless of
a request's real length -- the worst-case envelope the paper's bandwidth
argument warns about (PAPER.md §III: size transfers by what moves, not by
what could move).  BlockAllocator manages the shared block pool behind
`T.paged_cache_schema`: a request holds exactly
ceil((prompt + max_new_tokens) / page_size) blocks, and `ServeEngine`
admits by free blocks instead of free slots alone, so sustainable
concurrency at fixed memory scales with the MEASURED request footprint.

A free-list allocator is enough: all blocks are interchangeable (one page
of every global layer's pool), so there is no external fragmentation --
any `n <= len(free)` request is satisfiable.  The free list is LIFO, which
keeps the working set of hot blocks dense.

Blocks are REFCOUNTED so one physical block can back many block tables
(prefix sharing): `alloc()` hands out blocks at refcount 1, `share()`
bumps an in-use block's count, and `free()` decrements -- a block returns
to the free list only when its count reaches zero.  Shared blocks are
read-only by convention (the engine writes a request's KV only into pages
it allocated itself -- copy-on-write at the page boundary), so the
allocator needs no copy machinery, just ownership counting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class AllocStats:
    """Lifetime counters (across run() calls)."""
    allocs: int = 0            # satisfied allocation requests
    frees: int = 0             # released allocations
    blocks_served: int = 0     # total blocks handed out
    denied: int = 0            # can_allocate=False probes (backpressure)
    peak_in_use: int = 0
    shares: int = 0            # share() calls (prefix-sharing joins)
    shared_blocks: int = 0     # total refcount bumps across share() calls


class BlockAllocator:
    """Free-list allocator over `num_blocks` interchangeable cache blocks."""

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        # per-block owner count: 0 = on the free list, >= 1 = in use by
        # that many block tables (or the prefix index)
        self._refs: List[int] = [0] * num_blocks
        self.stats = AllocStats()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def utilization(self) -> float:
        return self.in_use / self.num_blocks

    def refcount(self, block: int) -> int:
        """Current owner count of one block (0 = free)."""
        if not 0 <= block < self.num_blocks:
            raise ValueError(f"block id {block} out of range "
                             f"[0, {self.num_blocks})")
        return self._refs[block]

    def can_allocate(self, n: int) -> bool:
        """Admission probe; a False result is counted as backpressure."""
        ok = n <= len(self._free)
        if not ok:
            self.stats.denied += 1
        return ok

    def alloc(self, n: int) -> List[int]:
        """Pop `n` block ids at refcount 1, or raise -- callers gate on
        can_allocate."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise RuntimeError(
                f"out of KV blocks: want {n}, have {len(self._free)} free "
                f"of {self.num_blocks} (admission must gate on "
                "can_allocate)")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        self.stats.allocs += 1
        self.stats.blocks_served += n
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.in_use)
        return out

    def share(self, blocks: List[int]) -> List[int]:
        """Add one owner to each in-use block (prefix-sharing join).

        Returns the same ids so call sites can bind the result like an
        alloc.  Sharing a free block is a bug: the caller's prefix index
        held a stale pointer."""
        for b in blocks:
            if not 0 <= b < self.num_blocks:
                raise ValueError(f"block id {b} out of range "
                                 f"[0, {self.num_blocks})")
            if self._refs[b] == 0:
                raise ValueError(f"cannot share free block {b}")
        for b in blocks:
            self._refs[b] += 1
        if blocks:
            self.stats.shares += 1
            self.stats.shared_blocks += len(blocks)
        return list(blocks)

    def free(self, blocks: List[int]) -> None:
        """Drop one owner per block; blocks reaching refcount zero return
        to the pool (releasing an already-free block is a bug)."""
        for b in blocks:
            if not 0 <= b < self.num_blocks:
                raise ValueError(f"block id {b} out of range "
                                 f"[0, {self.num_blocks})")
            if self._refs[b] == 0:
                raise ValueError(f"double free of block {b}")
        released = []
        for b in blocks:
            self._refs[b] -= 1
            if self._refs[b] == 0:
                released.append(b)
        self._free.extend(released)
        if blocks:
            self.stats.frees += 1

    def describe(self) -> Dict[str, object]:
        return {
            "num_blocks": self.num_blocks,
            "free_blocks": self.free_blocks,
            "in_use": self.in_use,
            "utilization": self.utilization(),
            "peak_in_use": self.stats.peak_in_use,
            "allocs": self.stats.allocs,
            "frees": self.stats.frees,
            "denied": self.stats.denied,
            "shares": self.stats.shares,
            "shared_blocks": self.stats.shared_blocks,
        }
